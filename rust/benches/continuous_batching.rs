//! Continuous-batching engine benchmark — `BENCH_continuous_batching.json`.
//!
//! Claims under test (PR 4):
//!   * stacking N concurrent sequences into one M=N step amortises every
//!     weight stream across the batch, so aggregate decode tok/s grows
//!     with concurrency while the serial PR 3 baseline (one request at a
//!     time, M=1 steps) stays flat — the acceptance bar is ≥ 3× aggregate
//!     throughput at 16 concurrent sessions;
//!   * engine outputs are **bit-identical** to sequential
//!     `generate_greedy` for both the dynamic-CrossQuant serving path
//!     (native fake-quant) and calibrated static CrossQuant (true-integer
//!     GEMM), which the harness asserts before writing any number.
//!
//! Sessions at 1 / 4 / 16 concurrency; per-token latency is the mean
//! client-observed wall time per decoded token.
//!
//! PR 9 adds a tracing-overhead gate: a fully traced run (per-stage
//! spans for queue wait, admission, prefill, every decode token, plus
//! GEMM timing) must keep ≥ 98% of the untraced decode throughput at
//! the widest session count; the measured overhead is appended to
//! `BENCH_TREND.json` as a `crossquant-traced` row.
//!
//!     cargo bench --bench continuous_batching

mod support;

use std::path::PathBuf;
use std::time::Instant;

use crossquant::coordinator::scheduler::CoordinatorConfig;
use crossquant::coordinator::{ActScheme, EngineConfig, EvalCoordinator, EvalRequest};
use crossquant::corpus::CorpusGen;
use crossquant::eval::generation::{generate_serial, NativeDecoder, QuantizedDecoder};
use crossquant::model::weights::synthetic_weights;
use crossquant::model::{
    IdentitySite, ModelConfig, NativeModel, QuantPath, QuantSite, QuantizedModel,
};
use crossquant::quant::crossquant::CrossQuant;
use crossquant::quant::Bits;
use crossquant::runtime::ArtifactStore;
use crossquant::tensor::par;
use crossquant::util::Json;

const PROMPT_TOKENS: usize = 16;
const NEW_TOKENS: usize = 32;
const ALPHA: f32 = 0.15;
const SESSIONS: [usize; 3] = [1, 4, 16];

struct Cell {
    sessions: usize,
    engine_tok_s: f64,
    serial_tok_s: f64,
    engine_token_latency_ms: f64,
    serial_token_latency_ms: f64,
    bit_identical: bool,
}

impl Cell {
    fn speedup(&self) -> f64 {
        self.engine_tok_s / self.serial_tok_s.max(1e-12)
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("sessions", Json::num(self.sessions as f64)),
            ("engine_tok_s", Json::num(self.engine_tok_s)),
            ("serial_tok_s", Json::num(self.serial_tok_s)),
            ("speedup", Json::num(self.speedup())),
            ("engine_token_latency_ms", Json::num(self.engine_token_latency_ms)),
            ("serial_token_latency_ms", Json::num(self.serial_token_latency_ms)),
            ("bit_identical", Json::Bool(self.bit_identical)),
        ])
    }
}

fn prompts_for(n: usize, cfg: ModelConfig) -> Vec<Vec<u32>> {
    (0..n).map(|i| CorpusGen::new(cfg.vocab, 100 + i as u64).sequence(PROMPT_TOKENS)).collect()
}

/// Run `n` concurrent sessions through the engine; returns (wall seconds,
/// outputs). All requests are submitted up front — the engine admits them
/// into one running batch — and the clock stops when the last resolves.
fn run_engine(
    coordinator: &EvalCoordinator,
    scheme: ActScheme,
    prompts: &[Vec<u32>],
) -> (f64, Vec<Vec<u32>>) {
    let t0 = Instant::now();
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            coordinator
                .submit(EvalRequest::generate(p.clone(), scheme, "w16", NEW_TOKENS))
                .expect("submit")
        })
        .collect();
    let outputs: Vec<Vec<u32>> =
        handles.into_iter().map(|h| h.wait().expect("generate").generated).collect();
    (t0.elapsed().as_secs_f64(), outputs)
}

fn measure(
    name: &str,
    coordinator: &EvalCoordinator,
    scheme: ActScheme,
    cfg: ModelConfig,
    mut serial: impl FnMut(&[Vec<u32>]) -> (Vec<Vec<u32>>, f64),
) -> Json {
    println!("--- {name} ---");
    let cells: Vec<Cell> = SESSIONS
        .iter()
        .map(|&n| {
            let prompts = prompts_for(n, cfg);
            // warm the engine's model/calibration caches out of the timing
            let _ = run_engine(coordinator, scheme, &prompts[..1]);
            let (serial_outs, serial_wall) = serial(&prompts);
            let (engine_wall, engine_outs) = run_engine(coordinator, scheme, &prompts);
            let bit_identical = engine_outs == serial_outs;
            assert!(bit_identical, "{name}@{n}: engine must match sequential decode exactly");
            let total = (n * NEW_TOKENS) as f64;
            // client-observed per-token latency: engine sessions decode
            // concurrently (all finish ≈ at the wall), while a serial
            // client waits behind every earlier session — session i
            // completes after i+1 generations, so the mean completion is
            // wall·(n+1)/(2n)
            let serial_mean_completion = serial_wall * (n as f64 + 1.0) / (2.0 * n as f64);
            let cell = Cell {
                sessions: n,
                engine_tok_s: total / engine_wall.max(1e-12),
                serial_tok_s: total / serial_wall.max(1e-12),
                engine_token_latency_ms: engine_wall * 1e3 / NEW_TOKENS as f64,
                serial_token_latency_ms: serial_mean_completion * 1e3 / NEW_TOKENS as f64,
                bit_identical,
            };
            println!(
                "  {n:2} sessions: engine {:8.0} tok/s, serial {:8.0} tok/s, speedup {:.2}x",
                cell.engine_tok_s,
                cell.serial_tok_s,
                cell.speedup()
            );
            cell
        })
        .collect();
    Json::obj(vec![
        ("scheme", Json::str(name)),
        ("sessions", Json::arr(cells.iter().map(|c| c.json()).collect())),
    ])
}

fn main() {
    let cfg = ModelConfig::default_build();
    let weights = synthetic_weights(cfg, 77);
    assert!(PROMPT_TOKENS + NEW_TOKENS <= cfg.seq_len);

    println!(
        "continuous batching, {} prompt + {} new tokens, model d={} L={} vocab={} — {} worker \
         threads\n",
        PROMPT_TOKENS,
        NEW_TOKENS,
        cfg.d_model,
        cfg.n_layers,
        cfg.vocab,
        par::max_threads()
    );

    // the coordinator under test: native executor (no artifacts on disk),
    // engine wide enough for the largest session count
    let dir = std::env::temp_dir().join(format!("cq-cb-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench tempdir");
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir: dir.clone() },
        cfg,
        vec![("w16".to_string(), weights.flat.clone())],
        CoordinatorConfig {
            engine: EngineConfig {
                max_active_seqs: *SESSIONS.iter().max().unwrap(),
                kv_pool_bytes: None,
                max_waiting: 64,
                ..EngineConfig::default()
            },
            ..Default::default()
        },
    );

    // serial PR 3 baselines share the engine's exact model construction
    let native = NativeModel::new(weights.clone());
    let dynamic_scheme = ActScheme::CrossQuant { alpha: ALPHA, qmax: 127.0 };
    let dyn_json = measure("crossquant-dynamic", &coordinator, dynamic_scheme, cfg, |prompts| {
        let mut site = QuantSite::new(CrossQuant::new(ALPHA, Bits::Int8));
        let mut dec = NativeDecoder { model: &native, site: &mut site };
        let (outs, wall) = generate_serial(&mut dec, prompts, NEW_TOKENS).expect("serial");
        (outs, wall.as_secs_f64())
    });

    let mut qstat = QuantizedModel::new(
        &weights,
        Bits::Int8,
        Bits::Int8,
        QuantPath::CrossQuant { alpha: ALPHA },
    )
    .expect("static model");
    // identical calibration stream to the executor's (scheduler.rs), so
    // the serial reference and the served model share their scale folds
    let mut gen = CorpusGen::new(cfg.vocab, 0x5CA1E);
    let calib: Vec<Vec<u32>> = (0..8).map(|_| gen.sequence(cfg.seq_len)).collect();
    qstat.calibrate_static(ALPHA, &calib).expect("calibration");
    let static_scheme = ActScheme::CrossQuantStatic { alpha: ALPHA, qmax: 127.0 };
    let stat_json = measure("crossquant-static", &coordinator, static_scheme, cfg, |prompts| {
        let mut dec = QuantizedDecoder(&qstat);
        let (outs, wall) = generate_serial(&mut dec, prompts, NEW_TOKENS).expect("serial");
        (outs, wall.as_secs_f64())
    });

    // fp rounds out the picture (and exercises the engine's IdentitySite path)
    let fp_json = measure("fp", &coordinator, ActScheme::Fp, cfg, |prompts| {
        let mut site = IdentitySite;
        let mut dec = NativeDecoder { model: &native, site: &mut site };
        let (outs, wall) = generate_serial(&mut dec, prompts, NEW_TOKENS).expect("serial");
        (outs, wall.as_secs_f64())
    });

    // --- tracing overhead: traced vs untraced decode, best-of-5 each ---
    // span recording is a handful of relaxed atomics per stage, so a
    // fully traced request must stay within 2% of untraced throughput
    let n = *SESSIONS.iter().max().unwrap();
    let prompts = prompts_for(n, cfg);
    let _ = run_engine(&coordinator, dynamic_scheme, &prompts[..1]); // warm
    let total = (n * NEW_TOKENS) as f64;
    let best_tok_s = |traced: bool| -> f64 {
        (0..5)
            .map(|_| {
                let t0 = Instant::now();
                let handles: Vec<_> = prompts
                    .iter()
                    .map(|p| {
                        let mut req =
                            EvalRequest::generate(p.clone(), dynamic_scheme, "w16", NEW_TOKENS);
                        if traced {
                            req = req.with_trace(crossquant::obs::next_trace_id());
                        }
                        coordinator.submit(req).expect("submit")
                    })
                    .collect();
                for h in handles {
                    h.wait().expect("generate");
                }
                total / t0.elapsed().as_secs_f64().max(1e-12)
            })
            .fold(0.0f64, f64::max)
    };
    let untraced_tok_s = best_tok_s(false);
    let traced_tok_s = best_tok_s(true);
    let overhead = 1.0 - traced_tok_s / untraced_tok_s.max(1e-12);
    println!(
        "\ntracing overhead @ {n} sessions: untraced {untraced_tok_s:.0} tok/s, \
         traced {traced_tok_s:.0} tok/s ({:+.2}%)",
        overhead * 100.0
    );
    assert!(
        traced_tok_s >= 0.98 * untraced_tok_s,
        "tracing overhead above 2%: untraced {untraced_tok_s:.0} tok/s vs traced \
         {traced_tok_s:.0} tok/s"
    );

    let occupancy = coordinator.metrics.batch_occupancy();
    println!("\nengine batch occupancy over the run: {occupancy:.2}");
    coordinator.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    let json = Json::obj(vec![
        ("bench", Json::str("continuous_batching")),
        ("prompt_tokens", Json::num(PROMPT_TOKENS as f64)),
        ("new_tokens", Json::num(NEW_TOKENS as f64)),
        ("threads", Json::num(par::max_threads() as f64)),
        ("batch_occupancy", Json::num(occupancy)),
        ("tracing_overhead", Json::num(overhead)),
        ("schemes", Json::arr(vec![dyn_json, stat_json, fp_json])),
    ]);
    let path: PathBuf =
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_continuous_batching.json"));
    match std::fs::write(&path, json.render_pretty()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }

    // append the traced-decode datapoint to the cross-PR trend file, so
    // the history shows if span recording ever gets expensive
    let trend_path: PathBuf =
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_TREND.json"));
    let mut rows: Vec<Json> = match std::fs::read_to_string(&trend_path) {
        Ok(s) => match Json::parse(&s) {
            Ok(Json::Arr(v)) => v,
            _ => Vec::new(),
        },
        Err(_) => Vec::new(),
    };
    let run_id = rows.len();
    rows.push(Json::obj(vec![
        ("run", Json::num(run_id as f64)),
        ("scheme", Json::str("crossquant-traced")),
        ("isa", Json::str(crossquant::quant::gemm::dispatch::active().name())),
        ("decode_tok_s", Json::num(traced_tok_s)),
        ("untraced_tok_s", Json::num(untraced_tok_s)),
        ("tracing_overhead", Json::num(overhead)),
    ]));
    match std::fs::write(&trend_path, Json::Arr(rows).render_pretty()) {
        Ok(()) => println!("appended crossquant-traced row to {}", trend_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", trend_path.display()),
    }
}
