//! Hot-path micro-benchmarks for the quantization library — the
//! EXPERIMENTS.md §Perf L3 numbers.
//!
//! Paper claims under test:
//!   * CrossQuant costs "one extra division" over per-token — same O(TI);
//!     here: CQ fake-quant should be ≤ 2× per-token on a 2048×4096 matrix.
//!   * CrossQuant stores only one extra length-I vector (delta_field).
//!
//! Engine claims under test (PR 1):
//!   * row-parallelism: fake-quant / kernel-scan / matmul vs their serial
//!     (1-worker) references;
//!   * fusion: `quantize_with_report` (1 field + 1 sweep) vs the seed's
//!     3-sweep QuantSite path (field, kernel scan, field again, quant).
//!
//! Engine claims under test (PR 2):
//!   * packed-panel int8 GEMM (`quant::gemm`) ≥2× the seed scalar kernel
//!     at the serving shape 512×2048×2048;
//!   * static-scale CrossQuant forward ≈ per-token cost (no per-batch
//!     O(I·O) weight rescale), vs the dynamic path which pays it.
//!
//! Results are also written to `BENCH_quant_hot_path.json` and
//! `BENCH_qlinear_gemm.json` at the repo root so the perf trajectory is
//! tracked across PRs.
//!
//!     cargo bench --bench quant_hot_path

mod support;

use std::time::Duration;

use crossquant::activations::{ActivationGen, FamilyProfile};
use crossquant::analysis::{
    kernel_fraction_threads, quantize_with_report, KernelReport,
};
use crossquant::quant::crossquant::col_pow_scales;
use crossquant::quant::gemm::{self, PackedInt8};
use crossquant::quant::qlinear::{QuantizedLinear, ScaleMode};
use crossquant::quant::{
    clipping::ClippedPerToken, crossquant::CrossQuant, fake_quant_with, fake_quant_with_threads,
    pack::PackedMatrix, per_channel::GroupWise, per_token::PerToken, smoothquant::SmoothQuant,
    ActQuantizer, Bits,
};
use crossquant::tensor::{par, Matrix, SplitMix64};
use crossquant::util::Json;
use support::{bench, header, BenchResult};

fn json_entry(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("name", Json::str(r.name.clone())),
        ("mean_ns", Json::num(r.mean.as_nanos() as f64)),
        ("min_ns", Json::num(r.min.as_nanos() as f64)),
        ("p50_ns", Json::num(r.p50.as_nanos() as f64)),
        ("iters", Json::num(r.iters as f64)),
    ])
}

fn main() {
    let budget = Duration::from_millis(400);
    // the paper's canonical activation shape: T×I = 2048×4096
    let profile = FamilyProfile::by_name("opt-13b").expect("profile");
    let x = ActivationGen::new(profile, 1).matrix(2048, 4096);
    let elems = (x.rows * x.cols) as f64;
    let mut results: Vec<BenchResult> = Vec::new();
    let mut record = |r: BenchResult| results.push(r);

    println!(
        "activation 2048×4096, OPT-13B profile — {} worker threads (CROSSQUANT_THREADS to override)\n",
        par::max_threads()
    );
    header();

    let pt = PerToken::new(Bits::Int8);
    let cq = CrossQuant::new(0.15, Bits::Int8);

    let r_pt = bench("per-token fake-quant (eq.1)", budget, || {
        std::hint::black_box(pt.fake_quant(&x));
    });
    r_pt.print_throughput(elems, "elem");
    let r_cq = bench("crossquant fake-quant (eq.5, α=0.15)", budget, || {
        std::hint::black_box(cq.fake_quant(&x));
    });
    r_cq.print_throughput(elems, "elem");
    println!(
        "  -> crossquant / per-token cost ratio: {:.2}x (paper: 'one extra division', target ≤2x)\n",
        r_cq.mean.as_secs_f64() / r_pt.mean.as_secs_f64()
    );

    // ---- serial vs parallel, on the same precomputed field ----
    let field = cq.delta_field(&x);
    let qmax = cq.qmax();
    let r_fq_serial = bench("fake_quant_with serial (1 worker)", budget, || {
        std::hint::black_box(fake_quant_with_threads(&x, &field, qmax, 1));
    });
    r_fq_serial.print_throughput(elems, "elem");
    let r_fq_par = bench("fake_quant_with parallel (auto workers)", budget, || {
        std::hint::black_box(fake_quant_with(&x, &field, qmax));
    });
    r_fq_par.print_throughput(elems, "elem");
    let fq_speedup = r_fq_serial.mean.as_secs_f64() / r_fq_par.mean.as_secs_f64();
    println!("  -> parallel fake-quant speedup: {fq_speedup:.2}x\n");

    let r_kf_serial = bench("kernel_fraction serial (Definition 1 scan)", budget, || {
        std::hint::black_box(kernel_fraction_threads(&x, &field, 1));
    });
    r_kf_serial.print();
    let r_kf_par = bench("kernel_fraction parallel", budget, || {
        std::hint::black_box(kernel_fraction_threads(
            &x,
            &field,
            par::workers_for(x.rows, x.len()),
        ));
    });
    r_kf_par.print();
    let kf_speedup = r_kf_serial.mean.as_secs_f64() / r_kf_par.mean.as_secs_f64();
    println!("  -> parallel kernel-scan speedup: {kf_speedup:.2}x\n");

    // ---- fused vs the seed's separate 3-sweep hot path ----
    // seed QuantSite::apply: delta_field + kernel scan, then fake_quant
    // (which recomputes the delta field) — all serial
    let r_seed = bench("seed hot path: 2×field + scan + quant, serial", budget, || {
        let f = cq.delta_field(&x);
        std::hint::black_box(kernel_fraction_threads(&x, &f, 1));
        let f2 = cq.delta_field(&x);
        std::hint::black_box(fake_quant_with_threads(&x, &f2, qmax, 1));
    });
    r_seed.print_throughput(elems, "elem");
    let r_fused = bench("fused quantize_with_report, parallel", budget, || {
        std::hint::black_box(quantize_with_report(&x, &cq));
    });
    r_fused.print_throughput(elems, "elem");
    let fused_speedup = r_seed.mean.as_secs_f64() / r_fused.mean.as_secs_f64();
    println!(
        "  -> fused+parallel vs seed serial path: {fused_speedup:.2}x (acceptance target ≥2x)\n"
    );

    record(r_pt);
    record(r_cq);
    record(r_fq_serial);
    record(r_fq_par);
    record(r_kf_serial);
    record(r_kf_par);
    record(r_seed);
    record(r_fused);

    let r = bench("delta_field per-token (row absmax)", budget, || {
        std::hint::black_box(pt.delta_field(&x));
    });
    r.print();
    record(r);
    let r = bench("delta_field crossquant (row+col absmax+pow)", budget, || {
        std::hint::black_box(cq.delta_field(&x));
    });
    r.print();
    record(r);

    let r = bench("KernelReport::compute (stats-only scan)", budget, || {
        std::hint::black_box(KernelReport::compute(&x, &cq));
    });
    r.print();
    record(r);

    let r = bench("clipped per-token (OmniQuant step)", budget, || {
        std::hint::black_box(ClippedPerToken::new(Bits::Int8, 0.8).fake_quant(&x));
    });
    r.print();
    record(r);

    // weight-side paths on a 2048×2048 weight
    let mut rng = SplitMix64::new(9);
    let w = Matrix::randn(2048, 2048, 0.02, &mut rng);
    let r = bench("group-wise W4-g128 weight quant (2048²)", budget, || {
        std::hint::black_box(GroupWise::w4_g128().fake_quant(&w));
    });
    r.print();
    record(r);

    let xc = ActivationGen::new(FamilyProfile::by_name("opt-13b").unwrap(), 3).matrix(256, 2048);
    let r = bench("smoothquant calibrate (256×2048 calib)", budget, || {
        std::hint::black_box(SmoothQuant::calibrate(&xc, &w, 0.5));
    });
    r.print();
    record(r);

    let r = bench("pack INT8 (codes + factored scales)", budget, || {
        std::hint::black_box(PackedMatrix::pack(&x, &cq));
    });
    r.print();
    record(r);

    // native matmul — small forward-pass shape and a serving-sized block
    println!();
    let a = Matrix::randn(96, 128, 1.0, &mut rng);
    let b = Matrix::randn(128, 512, 0.05, &mut rng);
    let flops = 2.0 * 96.0 * 128.0 * 512.0;
    let r = bench("native matmul 96×128×512 (fwd hot loop)", budget, || {
        std::hint::black_box(a.matmul(&b));
    });
    r.print_throughput(flops, "flop");
    record(r);

    let am = Matrix::randn(512, 512, 1.0, &mut rng);
    let bm = Matrix::randn(512, 512, 0.05, &mut rng);
    let flops = 2.0f64 * 512.0 * 512.0 * 512.0;
    let r_mm_serial = bench("matmul 512³ serial (1 worker)", budget, || {
        std::hint::black_box(am.matmul_threads(&bm, 1));
    });
    r_mm_serial.print_throughput(flops, "flop");
    let r_mm_par = bench("matmul 512³ parallel (auto workers)", budget, || {
        std::hint::black_box(am.matmul(&bm));
    });
    r_mm_par.print_throughput(flops, "flop");
    let mm_speedup = r_mm_serial.mean.as_secs_f64() / r_mm_par.mean.as_secs_f64();
    println!("  -> parallel matmul speedup: {mm_speedup:.2}x");
    record(r_mm_serial);
    record(r_mm_par);

    // ---- packed-panel int8 GEMM vs the seed scalar kernel ----
    // serving-sized W8A8 GEMM: 512 tokens × 2048 in × 2048 out
    println!();
    let (gm, gk, gn) = (512usize, 2048usize, 2048usize);
    let gx = ActivationGen::new(FamilyProfile::by_name("opt-13b").unwrap(), 11).matrix(gm, gk);
    let gw = Matrix::randn(gk, gn, 0.02, &mut rng);
    let lin = QuantizedLinear::from_weight(&gw, Bits::Int8);
    let act = QuantizedLinear::quantize_per_token(&gx, Bits::Int8);
    let w_codes = lin.stored_codes();
    let packed = PackedInt8::from_row_major(&w_codes, gk, gn);
    let gemm_workers = par::workers_for(gm, gm * gk * gn);
    let gemm_ops = 2.0 * gm as f64 * gk as f64 * gn as f64;

    let r_seed_gemm = bench("seed gemm_i32 512×2048×2048 (scalar)", budget, || {
        std::hint::black_box(seed_gemm_i32(
            &act.codes,
            gm,
            gk,
            &w_codes,
            gn,
            &act.row_scale,
            lin.w_scales(),
        ));
    });
    r_seed_gemm.print_throughput(gemm_ops, "op");
    let r_packed_gemm = bench("packed-panel gemm 512×2048×2048 (µkernel)", budget, || {
        std::hint::black_box(gemm::gemm_dequant(
            &act.codes,
            gm,
            &packed,
            &act.row_scale,
            lin.w_scales(),
            gemm_workers,
        ));
    });
    r_packed_gemm.print_throughput(gemm_ops, "op");
    let packed_speedup = r_seed_gemm.mean.as_secs_f64() / r_packed_gemm.mean.as_secs_f64();
    println!("  -> packed vs seed kernel: {packed_speedup:.2}x (acceptance target ≥2x)\n");

    // ---- microkernel dispatch: explicit per-ISA sections, same shape ----
    // gemm_i32_packed_isa pins the kernel per section, so one process can
    // measure every path this host supports (the CROSSQUANT_ISA override
    // is read once and would pin all of them to one kernel).
    let isa_active = gemm::dispatch::active();
    println!("  active dispatch ISA: {isa_active} (CROSSQUANT_ISA to override)");
    let mut isa_gops: Vec<(&'static str, f64)> = Vec::new();
    for isa in gemm::Isa::ALL {
        if !gemm::dispatch::supported(isa) {
            continue;
        }
        let r_isa = bench(&format!("packed gemm 512×2048×2048 [{isa}]"), budget, || {
            std::hint::black_box(gemm::gemm_i32_packed_isa(
                &act.codes,
                gm,
                &packed,
                gemm_workers,
                isa,
            ));
        });
        r_isa.print_throughput(gemm_ops, "op");
        isa_gops.push((isa.name(), gemm_ops / 1e9 / r_isa.mean.as_secs_f64()));
        record(r_isa);
    }
    let scalar_gops = isa_gops.iter().find(|(n, _)| *n == "scalar").map_or(0.0, |&(_, g)| g);
    for &(name, g) in &isa_gops {
        if name != "scalar" && scalar_gops > 0.0 {
            println!("  -> {name} vs scalar microkernel: {:.2}x (target ≥2x)", g / scalar_gops);
        }
    }
    println!();

    // ---- deployment forwards: per-token vs dynamic vs static CrossQuant ----
    let r_fwd_pt = bench("qlinear fwd per-token (no weight pass)", budget, || {
        std::hint::black_box(lin.forward_per_token(&gx, Bits::Int8));
    });
    r_fwd_pt.print();
    let r_fwd_dyn = bench("qlinear fwd crossquant dynamic (rescale/batch)", budget, || {
        std::hint::black_box(lin.forward_crossquant(&gx, 0.15, Bits::Int8));
    });
    r_fwd_dyn.print();
    let mut lin_static = lin.clone();
    lin_static.set_scale_mode(ScaleMode::Static {
        alpha: 0.15,
        col_pow: col_pow_scales(&gx.col_abs_max(), 0.15),
    });
    let r_fwd_static = bench("qlinear fwd crossquant static (calibrated)", budget, || {
        std::hint::black_box(lin_static.forward_crossquant_static(&gx, Bits::Int8));
    });
    r_fwd_static.print();
    let static_speedup = r_fwd_dyn.mean.as_secs_f64() / r_fwd_static.mean.as_secs_f64();
    let static_overhead = r_fwd_static.mean.as_secs_f64() / r_fwd_pt.mean.as_secs_f64();
    println!("  -> static vs dynamic crossquant forward: {static_speedup:.2}x faster");
    println!("  -> static overhead vs per-token: {static_overhead:.2}x (target ≈1x)");

    // dedicated machine-readable dump for the deployment-path trajectory
    let mut gemm_fields = vec![
        ("bench", Json::str("qlinear_gemm")),
        ("shape", Json::str("512x2048x2048")),
        ("threads", Json::num(par::max_threads() as f64)),
        ("isa_active", Json::str(isa_active.name())),
        ("gops_seed", Json::num(gemm_ops / 1e9 / r_seed_gemm.mean.as_secs_f64())),
        ("gops_packed", Json::num(gemm_ops / 1e9 / r_packed_gemm.mean.as_secs_f64())),
        ("packed_vs_seed_speedup", Json::num(packed_speedup)),
    ];
    for &(name, g) in &isa_gops {
        gemm_fields.push(match name {
            "scalar" => ("gops_isa_scalar", Json::num(g)),
            "avx2" => ("gops_isa_avx2", Json::num(g)),
            "neon" => ("gops_isa_neon", Json::num(g)),
            _ => continue,
        });
    }
    gemm_fields.extend(vec![
        ("forward_per_token_ms", Json::num(r_fwd_pt.mean.as_secs_f64() * 1e3)),
        ("forward_dynamic_ms", Json::num(r_fwd_dyn.mean.as_secs_f64() * 1e3)),
        ("forward_static_ms", Json::num(r_fwd_static.mean.as_secs_f64() * 1e3)),
        ("static_vs_dynamic_speedup", Json::num(static_speedup)),
        ("static_overhead_vs_per_token", Json::num(static_overhead)),
    ]);
    let gemm_json = Json::obj(gemm_fields);
    let gemm_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_qlinear_gemm.json");
    match std::fs::write(gemm_path, gemm_json.render_pretty()) {
        Ok(()) => println!("\nwrote {gemm_path}"),
        Err(e) => eprintln!("\ncould not write {gemm_path}: {e}"),
    }
    record(r_seed_gemm);
    record(r_packed_gemm);
    record(r_fwd_pt);
    record(r_fwd_dyn);
    record(r_fwd_static);

    // ---- machine-readable dump for the perf trajectory ----
    let json = Json::obj(vec![
        ("bench", Json::str("quant_hot_path")),
        ("shape", Json::str("2048x4096")),
        ("threads", Json::num(par::max_threads() as f64)),
        (
            "speedups",
            Json::obj(vec![
                ("fake_quant_parallel_vs_serial", Json::num(fq_speedup)),
                ("kernel_fraction_parallel_vs_serial", Json::num(kf_speedup)),
                ("fused_parallel_vs_seed_serial", Json::num(fused_speedup)),
                ("matmul_parallel_vs_serial", Json::num(mm_speedup)),
            ]),
        ),
        ("results", Json::arr(results.iter().map(json_entry).collect())),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_quant_hot_path.json");
    match std::fs::write(path, json.render_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}

/// The seed's scalar int8 GEMM, preserved verbatim as the baseline the
/// packed-panel kernel is measured against: row-parallel, data-dependent
/// `a == 0` skip, memory-resident accumulator row re-walked per k step.
#[allow(clippy::too_many_arguments)]
fn seed_gemm_i32(
    a_codes: &[i8],
    m: usize,
    k_dim: usize,
    w_codes: &[i8],
    n: usize,
    row_scale: &[f32],
    w_scale: &[f32],
) -> Matrix {
    let mut out = Matrix::zeros(m, n);
    if out.is_empty() {
        return out;
    }
    let cost = m.saturating_mul(k_dim).saturating_mul(n);
    par::par_rows_mut(&mut out.data, n, par::workers_for(m, cost), |row0, chunk| {
        let mut acc = vec![0i32; n];
        for (local_i, dst) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + local_i;
            acc.iter_mut().for_each(|a| *a = 0);
            let a_row = &a_codes[i * k_dim..(i + 1) * k_dim];
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0 {
                    continue;
                }
                let a = a as i32;
                let w_row = &w_codes[k * n..(k + 1) * n];
                for (o, &w) in acc.iter_mut().zip(w_row) {
                    *o += a * w as i32;
                }
            }
            let rs = row_scale[i];
            for ((d, &a), &ws) in dst.iter_mut().zip(&acc).zip(w_scale) {
                *d = a as f32 * rs * ws;
            }
        }
    });
    out
}
