//! Hot-path micro-benchmarks for the quantization library — the
//! EXPERIMENTS.md §Perf L3 numbers.
//!
//! Paper claims under test:
//!   * CrossQuant costs "one extra division" over per-token — same O(TI);
//!     here: CQ fake-quant should be ≤ 2× per-token on a 2048×4096 matrix.
//!   * CrossQuant stores only one extra length-I vector (delta_field).
//!
//!     cargo bench --bench quant_hot_path

mod support;

use std::time::Duration;

use crossquant::activations::{ActivationGen, FamilyProfile};
use crossquant::analysis::kernel_fraction;
use crossquant::quant::{
    clipping::ClippedPerToken, crossquant::CrossQuant, pack::PackedMatrix,
    per_channel::GroupWise, per_token::PerToken, smoothquant::SmoothQuant, ActQuantizer, Bits,
};
use crossquant::tensor::{Matrix, SplitMix64};
use support::{bench, header};

fn main() {
    let budget = Duration::from_millis(400);
    // the paper's canonical activation shape: T×I = 2048×4096
    let profile = FamilyProfile::by_name("opt-13b").expect("profile");
    let x = ActivationGen::new(profile, 1).matrix(2048, 4096);
    let elems = (x.rows * x.cols) as f64;

    println!("activation 2048×4096, OPT-13B profile\n");
    header();

    let pt = PerToken::new(Bits::Int8);
    let cq = CrossQuant::new(0.15, Bits::Int8);

    let r_pt = bench("per-token fake-quant (eq.1)", budget, || {
        std::hint::black_box(pt.fake_quant(&x));
    });
    r_pt.print_throughput(elems, "elem");
    let r_cq = bench("crossquant fake-quant (eq.5, α=0.15)", budget, || {
        std::hint::black_box(cq.fake_quant(&x));
    });
    r_cq.print_throughput(elems, "elem");
    println!(
        "  -> crossquant / per-token cost ratio: {:.2}x (paper: 'one extra division', target ≤2x)\n",
        r_cq.mean.as_secs_f64() / r_pt.mean.as_secs_f64()
    );

    bench("delta_field per-token (row absmax)", budget, || {
        std::hint::black_box(pt.delta_field(&x));
    })
    .print();
    bench("delta_field crossquant (row+col absmax+pow)", budget, || {
        std::hint::black_box(cq.delta_field(&x));
    })
    .print();

    let field = cq.delta_field(&x);
    bench("kernel_fraction (Definition 1 scan)", budget, || {
        std::hint::black_box(kernel_fraction(&x, &field));
    })
    .print();

    bench("clipped per-token (OmniQuant step)", budget, || {
        std::hint::black_box(ClippedPerToken::new(Bits::Int8, 0.8).fake_quant(&x));
    })
    .print();

    // weight-side paths on a 4096×4096 weight
    let mut rng = SplitMix64::new(9);
    let w = Matrix::randn(2048, 2048, 0.02, &mut rng);
    bench("group-wise W4-g128 weight quant (2048²)", budget, || {
        std::hint::black_box(GroupWise::w4_g128().fake_quant(&w));
    })
    .print();

    let xc = ActivationGen::new(FamilyProfile::by_name("opt-13b").unwrap(), 3).matrix(256, 2048);
    bench("smoothquant calibrate (256×2048 calib)", budget, || {
        std::hint::black_box(SmoothQuant::calibrate(&xc, &w, 0.5));
    })
    .print();

    bench("pack INT8 (codes + factored scales)", budget, || {
        std::hint::black_box(PackedMatrix::pack(&x, &cq));
    })
    .print();

    // native matmul (the eval substrate hot loop)
    let a = Matrix::randn(96, 128, 1.0, &mut rng);
    let b = Matrix::randn(128, 512, 0.05, &mut rng);
    let flops = 2.0 * 96.0 * 128.0 * 512.0;
    bench("native matmul 96×128×512 (fwd hot loop)", budget, || {
        std::hint::black_box(a.matmul(&b));
    })
    .print_throughput(flops, "flop");
}
