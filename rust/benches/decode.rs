//! Prefill-vs-incremental-decode benchmark — the generation workload's
//! perf trajectory (`BENCH_decode.json`).
//!
//! Claims under test (PR 3):
//!   * KV-cached decode turns the per-token cost from O(S²·d) (full
//!     recompute of the growing prefix) into O(S·d): incremental
//!     tokens/s must beat full-recompute tokens/s on every path;
//!   * the decode step drives the packed `quant::gemm` microkernel with
//!     M=1, so the static CrossQuant path decodes at per-token-W8A8-like
//!     cost while dynamic CrossQuant pays its per-step weight rescale.
//!
//! Paths measured: FP (native), dynamic CrossQuant (integer), calibrated
//! static CrossQuant (integer).
//!
//!     cargo bench --bench decode

mod support;

use std::time::Duration;

use crossquant::corpus::CorpusGen;
use crossquant::eval::generation::{
    generate_timed, IncrementalDecoder, NativeDecoder, QuantizedDecoder,
};
use crossquant::model::weights::synthetic_weights;
use crossquant::model::{
    block, IdentitySite, ModelConfig, NativeModel, QuantPath, QuantizedModel,
};
use crossquant::quant::Bits;
use crossquant::tensor::par;
use crossquant::util::Json;
use support::{bench, header};

const PROMPT_TOKENS: usize = 32;
const NEW_TOKENS: usize = 64;

/// One path's numbers: incremental (KV-cached) vs full-recompute decode.
struct PathReport {
    name: &'static str,
    prefill_tok_s: f64,
    decode_tok_s: f64,
    full_recompute_tok_s: f64,
}

impl PathReport {
    fn speedup(&self) -> f64 {
        self.decode_tok_s / self.full_recompute_tok_s.max(1e-12)
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::str(self.name)),
            ("prefill_tok_s", Json::num(self.prefill_tok_s)),
            ("decode_tok_s", Json::num(self.decode_tok_s)),
            ("full_recompute_tok_s", Json::num(self.full_recompute_tok_s)),
            ("incremental_vs_full_speedup", Json::num(self.speedup())),
        ])
    }
}

/// Measure one decoder: mean prefill/decode split over `bench`-paced
/// repetitions, plus the full-recompute baseline driven by `rescore`
/// (logits of the whole growing sequence each step — what serving without
/// a KV cache would pay).
fn measure(
    name: &'static str,
    budget: Duration,
    decoder: &mut dyn IncrementalDecoder,
    prompt: &[u32],
    rescore: &mut dyn FnMut(&[u32]) -> Vec<f32>,
) -> PathReport {
    // one instrumented run for the prefill/decode split
    let (tokens, timing) = generate_timed(decoder, prompt, NEW_TOKENS).expect("generate");
    assert_eq!(tokens.len(), NEW_TOKENS);

    let r_inc = bench(&format!("{name}: incremental decode"), budget, || {
        let (t, _) = generate_timed(decoder, prompt, NEW_TOKENS).expect("generate");
        std::hint::black_box(t);
    });
    r_inc.print_throughput((PROMPT_TOKENS + NEW_TOKENS) as f64, "tok");

    let r_full = bench(&format!("{name}: full-recompute decode"), budget, || {
        let mut seq = prompt.to_vec();
        for _ in 0..NEW_TOKENS {
            let last = rescore(&seq);
            // same sampler as the cached path: divergence can only come
            // from the logits, never from tie-breaking
            seq.push(block::argmax(&last) as u32);
        }
        std::hint::black_box(seq);
    });
    r_full.print_throughput(NEW_TOKENS as f64, "tok");

    // tokens/s from the bench means: incremental spends (prefill +
    // decode) per run; attribute by the instrumented split so the decode
    // rate excludes prefill
    let split = timing.decode.as_secs_f64()
        / (timing.prefill.as_secs_f64() + timing.decode.as_secs_f64()).max(1e-12);
    let inc_total = r_inc.mean.as_secs_f64();
    let report = PathReport {
        name,
        prefill_tok_s: PROMPT_TOKENS as f64 / (inc_total * (1.0 - split)).max(1e-12),
        decode_tok_s: NEW_TOKENS as f64 / (inc_total * split).max(1e-12),
        full_recompute_tok_s: NEW_TOKENS as f64 / r_full.mean.as_secs_f64(),
    };
    println!(
        "  -> {name}: decode {:.0} tok/s (prefill {:.0} tok/s), full recompute {:.0} tok/s, \
         speedup {:.2}x\n",
        report.decode_tok_s,
        report.prefill_tok_s,
        report.full_recompute_tok_s,
        report.speedup()
    );
    report
}

fn main() {
    let budget = Duration::from_millis(400);
    let cfg = ModelConfig::default_build();
    let weights = synthetic_weights(cfg, 77);
    let prompt = CorpusGen::new(cfg.vocab, 3).sequence(PROMPT_TOKENS);
    assert!(PROMPT_TOKENS + NEW_TOKENS <= cfg.seq_len);

    println!(
        "greedy generation, {} prompt + {} new tokens, model d={} L={} vocab={} — {} worker \
         threads\n",
        PROMPT_TOKENS,
        NEW_TOKENS,
        cfg.d_model,
        cfg.n_layers,
        cfg.vocab,
        par::max_threads()
    );
    header();

    let fp = NativeModel::new(weights.clone());
    let mut fp_site = IdentitySite;
    let mut fp_dec = NativeDecoder { model: &fp, site: &mut fp_site };
    let mut fp_rescore = |seq: &[u32]| {
        let logits = fp.forward_logits(seq, &mut IdentitySite).unwrap();
        logits.row(logits.rows - 1).to_vec()
    };
    let r_fp = measure("fp", budget, &mut fp_dec, &prompt, &mut fp_rescore);

    let qdyn = QuantizedModel::new(
        &weights,
        Bits::Int8,
        Bits::Int8,
        QuantPath::CrossQuant { alpha: 0.15 },
    )
    .expect("dynamic model");
    let mut dyn_dec = QuantizedDecoder(&qdyn);
    let mut dyn_rescore = |seq: &[u32]| {
        let logits = qdyn.forward_logits(seq).unwrap();
        logits.row(logits.rows - 1).to_vec()
    };
    let r_dyn = measure("crossquant-dynamic", budget, &mut dyn_dec, &prompt, &mut dyn_rescore);

    let mut qstat = QuantizedModel::new(
        &weights,
        Bits::Int8,
        Bits::Int8,
        QuantPath::CrossQuant { alpha: 0.15 },
    )
    .expect("static model");
    let mut gen = CorpusGen::new(cfg.vocab, 9);
    let calib: Vec<Vec<u32>> = (0..8).map(|_| gen.sequence(cfg.seq_len)).collect();
    qstat.calibrate_static(0.15, &calib).expect("calibration");
    let mut stat_dec = QuantizedDecoder(&qstat);
    let mut stat_rescore = |seq: &[u32]| {
        let logits = qstat.forward_logits(seq).unwrap();
        logits.row(logits.rows - 1).to_vec()
    };
    let r_stat = measure("crossquant-static", budget, &mut stat_dec, &prompt, &mut stat_rescore);

    let json = Json::obj(vec![
        ("bench", Json::str("decode")),
        ("prompt_tokens", Json::num(PROMPT_TOKENS as f64)),
        ("new_tokens", Json::num(NEW_TOKENS as f64)),
        ("threads", Json::num(par::max_threads() as f64)),
        (
            "kv_cache_bytes_per_request",
            Json::num(fp.new_decode_state().memory_bytes() as f64),
        ),
        ("paths", Json::arr(vec![r_fp.json(), r_dyn.json(), r_stat.json()])),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json");
    match std::fs::write(path, json.render_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
