//! Hand-rolled micro-benchmark harness (the offline build has no criterion
//! — see Cargo.toml). Warmup + N timed iterations, reporting mean / min /
//! p50 / stddev, with optional throughput in user units.

// compiled once per bench target; not every target uses every helper
#![allow(dead_code)]

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub stddev: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:44} {:>12} {:>12} {:>12} {:>10}  ×{}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.min),
            fmt_dur(self.p50),
            fmt_dur(self.stddev),
            self.iters,
        );
    }

    pub fn print_throughput(&self, units: f64, unit_name: &str) {
        println!(
            "{:44} {:>12} {:>12}  {:>14.2} {unit_name}/s  ×{}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.min),
            units / self.mean.as_secs_f64(),
            self.iters,
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

pub fn header() {
    println!(
        "{:44} {:>12} {:>12} {:>12} {:>10}",
        "benchmark", "mean", "min", "p50", "stddev"
    );
    println!("{}", "-".repeat(96));
}

/// Run `f` with warmup; the iteration count adapts so the whole
/// measurement takes ~`budget`.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(100));
    let iters = ((budget.as_secs_f64() / once.as_secs_f64()).ceil() as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let mean = total / iters as u32;
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| {
            let x = d.as_secs_f64() - mean_s;
            x * x
        })
        .sum::<f64>()
        / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        min: samples[0],
        p50: samples[iters / 2],
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}
