//! Deployment cold-start benchmark — `BENCH_artifact_load.json`.
//!
//! Measures the two ways a serve process can reach a servable
//! static-scale CrossQuant model:
//!
//! * **fp load + calibrate** — read the FP32 checkpoint, build the
//!   integer model, run the calibration forwards, fold the scales (what
//!   every process paid before `quant::artifact` existed);
//! * **mmap artifact load** — open the `.cqa`, verify CRCs, borrow the
//!   int8 panels in place, rebuild the model structs.
//!
//! Reports wall time for both, the speedup, resident-memory deltas
//! (VmRSS, linux), and asserts the two models serve bit-identical NLLs.

mod support;

use std::time::Duration;

use crossquant::corpus::CorpusGen;
use crossquant::model::quantized::quantize_to_artifact;
use crossquant::model::weights::{synthetic_weights, Weights};
use crossquant::model::{ModelConfig, QuantPath, QuantizedModel};
use crossquant::quant::registry::{SchemeId, StaticSpec};
use crossquant::quant::Bits;
use crossquant::util::Json;
use support::{bench, header};

/// VmRSS in KiB from /proc/self/status (0.0 where unavailable).
fn rss_kb() -> f64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmRSS:"))
                .and_then(|l| l.split_whitespace().nth(1).and_then(|v| v.parse::<f64>().ok()))
        })
        .unwrap_or(0.0)
}

fn read_checkpoint(path: &std::path::Path, cfg: ModelConfig) -> Weights {
    let raw = std::fs::read(path).expect("read weights.bin");
    let flat: Vec<f32> = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Weights::from_config_flat(cfg, flat).expect("weights from flat")
}

fn main() {
    let cfg = ModelConfig::default_build();
    let alpha = 0.15f32;
    let weights = synthetic_weights(cfg, 0xA51);
    let mut gen = CorpusGen::new(cfg.vocab, 0x5CA1E);
    let calib: Vec<Vec<u32>> = (0..8).map(|_| gen.sequence(cfg.seq_len)).collect();

    // put both deployment units on disk so each cold start pays its read
    let dir = std::env::temp_dir().join(format!("cqa-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench tempdir");
    let wpath = dir.join("weights.bin");
    let bytes: Vec<u8> = weights.flat.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(&wpath, &bytes).expect("write weights.bin");
    let apath = dir.join("model.cqa");
    let spec = StaticSpec::new(SchemeId::CrossQuantStatic, alpha, 0);
    let report = quantize_to_artifact(&weights, Bits::Int8, Bits::Int8, &spec, &calib, &apath)
        .expect("quantize to artifact");

    // resident-memory deltas: artifact model first (freshest baseline),
    // then the fp+calibrate model on top
    let probe: Vec<u32> = (0..cfg.seq_len).map(|i| ((i * 7) % cfg.vocab) as u32).collect();
    let rss_base = rss_kb();
    let art_model = QuantizedModel::load_artifact(&apath).expect("artifact load");
    let rss_art = rss_kb() - rss_base;
    let w = read_checkpoint(&wpath, cfg);
    let mut fp_model =
        QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::CrossQuant { alpha })
            .expect("fp model");
    fp_model.calibrate_static(alpha, &calib).expect("calibrate");
    let rss_fp = rss_kb() - rss_base - rss_art;
    let nll_fp = fp_model.forward_nll(&probe).expect("fp nll");
    let nll_art = art_model.forward_nll(&probe).expect("artifact nll");
    assert_eq!(nll_fp, nll_art, "the two cold starts must serve bit-identical NLLs");
    drop(fp_model);
    drop(art_model);

    header();
    let r_fp = bench("cold-start: fp load + calibrate_static", Duration::from_secs(3), || {
        let w = read_checkpoint(&wpath, cfg);
        let mut qm =
            QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::CrossQuant { alpha })
                .expect("fp model");
        qm.calibrate_static(alpha, &calib).expect("calibrate");
        std::hint::black_box(&qm);
    });
    r_fp.print();
    let r_art = bench("cold-start: mmap artifact load", Duration::from_secs(3), || {
        let qm = QuantizedModel::load_artifact(&apath).expect("artifact load");
        std::hint::black_box(&qm);
    });
    r_art.print();

    let speedup = r_fp.mean.as_secs_f64() / r_art.mean.as_secs_f64().max(1e-12);
    println!();
    println!(
        "artifact cold start is {speedup:.1}x faster ({:.2} ms vs {:.2} ms)",
        r_art.mean.as_secs_f64() * 1e3,
        r_fp.mean.as_secs_f64() * 1e3
    );
    println!(
        "shipped bytes: {} (artifact) vs {} (fp32) — {:.2}x compression",
        report.artifact_bytes,
        report.fp_bytes,
        report.compression_ratio()
    );

    let json = Json::obj(vec![
        ("config", Json::str("default_build")),
        ("alpha", Json::num(alpha as f64)),
        ("calib_sequences", Json::num(calib.len() as f64)),
        ("fp_cold_start_ms", Json::num(r_fp.mean.as_secs_f64() * 1e3)),
        ("artifact_cold_start_ms", Json::num(r_art.mean.as_secs_f64() * 1e3)),
        ("speedup", Json::num(speedup)),
        ("fp_bytes", Json::num(report.fp_bytes as f64)),
        ("artifact_bytes", Json::num(report.artifact_bytes as f64)),
        ("compression", Json::num(report.compression_ratio())),
        ("artifact_resident_kb", Json::num(rss_art)),
        ("fp_calibrate_resident_kb", Json::num(rss_fp)),
        ("bit_identical", Json::Bool(true)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_artifact_load.json");
    std::fs::write(path, json.render_pretty()).expect("write BENCH_artifact_load.json");
    println!("wrote {path}");
    let _ = std::fs::remove_dir_all(&dir);
}
