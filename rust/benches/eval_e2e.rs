//! End-to-end evaluation benchmarks: the native forward path, the PJRT
//! artifact path (lm_fp vs lm_aq pallas vs lm_aq_jnp fused), and the
//! coordinator's batching win — EXPERIMENTS.md §Perf L2/L3 numbers.
//!
//! Requires `make artifacts`; degrades gracefully (native-only) without.
//!
//!     cargo bench --bench eval_e2e

mod support;

use std::time::Duration;

use crossquant::coordinator::scheduler::CoordinatorConfig;
use crossquant::coordinator::{ActScheme, EvalCoordinator};
use crossquant::corpus::CorpusGen;
use crossquant::model::weights::synthetic_weights;
use crossquant::model::{
    IdentitySite, ModelConfig, NativeModel, QuantPath, QuantSite, QuantizedModel,
};
use crossquant::quant::{crossquant::CrossQuant, Bits};
use crossquant::runtime::literal::{scalar_literal, tokens_literal, vec_literal};
use crossquant::runtime::{ArtifactStore, Runtime};
use crossquant::xla;
use support::{bench, header};

fn main() {
    let budget = Duration::from_millis(500);
    header();

    // ---------- native path ----------
    let store = ArtifactStore::discover(None).ok();
    let weights = store
        .as_ref()
        .and_then(|s| s.load_weights().ok())
        .unwrap_or_else(|| synthetic_weights(ModelConfig::default_build(), 1));
    let cfg = weights.config;
    let model = NativeModel::new(weights.clone());
    let mut gen = CorpusGen::new(cfg.vocab, 5);
    let seq = gen.sequence(cfg.seq_len);
    let tokens_per_fwd = cfg.seq_len as f64;

    bench("native forward FP (1 seq)", budget, || {
        std::hint::black_box(model.forward_nll(&seq, &mut IdentitySite).unwrap());
    })
    .print_throughput(tokens_per_fwd, "tok");

    bench("native forward + CrossQuant sites", budget, || {
        let mut site = QuantSite::new(CrossQuant::new(0.15, Bits::Int8));
        std::hint::black_box(model.forward_nll(&seq, &mut site).unwrap());
    })
    .print_throughput(tokens_per_fwd, "tok");

    // the true-integer deployment path (i8×i8→i32 GEMMs)
    let qmodel =
        QuantizedModel::new(&weights, Bits::Int8, Bits::Int8, QuantPath::CrossQuant { alpha: 0.15 })
            .expect("quantized model");
    bench("integer W8A8 forward (qlinear path)", budget, || {
        std::hint::black_box(qmodel.forward_nll(&seq).unwrap());
    })
    .print_throughput(tokens_per_fwd, "tok");
    let qpt = QuantizedModel::new(&weights, Bits::Int8, Bits::Int8, QuantPath::PerToken)
        .expect("quantized model");
    bench("integer W8A8 forward (per-token path)", budget, || {
        std::hint::black_box(qpt.forward_nll(&seq).unwrap());
    })
    .print_throughput(tokens_per_fwd, "tok");
    // calibrated static-scale CrossQuant: zero per-batch weight rescale
    let mut qst =
        QuantizedModel::new(&weights, Bits::Int8, Bits::Int8, QuantPath::CrossQuant { alpha: 0.15 })
            .expect("quantized model");
    let calib: Vec<Vec<u32>> = (0..4).map(|_| gen.sequence(cfg.seq_len)).collect();
    qst.calibrate_static(0.15, &calib).expect("calibrate");
    bench("integer W8A8 forward (static-scale path)", budget, || {
        std::hint::black_box(qst.forward_nll(&seq).unwrap());
    })
    .print_throughput(tokens_per_fwd, "tok");

    // ---------- PJRT path ----------
    let Some(store) = store else {
        println!("\n(no artifacts — run `make artifacts` for the PJRT benches)");
        return;
    };
    if store.validate().is_err() {
        println!("\n(artifacts incomplete — run `make artifacts` for the PJRT benches)");
        return;
    }

    let mut runtime = Runtime::new(store.clone()).expect("pjrt client");
    let mut gen = CorpusGen::new(cfg.vocab, 6);
    let rows: Vec<Vec<u32>> = (0..cfg.eval_batch).map(|_| gen.sequence(cfg.seq_len)).collect();
    let tokens = tokens_literal(&rows, cfg.seq_len, 0).unwrap();
    let w = vec_literal(&weights.flat);
    let batch_tokens = (cfg.eval_batch * cfg.seq_len) as f64;

    println!();
    for name in ["lm_fp", "lm_aq", "lm_aq_jnp", "lm_rk"] {
        runtime.prepare(name).expect("compile");
        let inputs: Vec<xla::Literal> = match name {
            "lm_fp" => vec![tokens.clone(), w.clone()],
            "lm_rk" => vec![tokens.clone(), w.clone(), scalar_literal(0.004)],
            _ => vec![tokens.clone(), w.clone(), scalar_literal(0.15), scalar_literal(127.0)],
        };
        bench(&format!("pjrt execute {name} (batch {})", cfg.eval_batch), budget, || {
            std::hint::black_box(runtime.execute(name, &inputs).unwrap());
        })
        .print_throughput(batch_tokens, "tok");
    }

    // ---------- coordinator batching win ----------
    println!();
    let mut gen = CorpusGen::new(cfg.vocab, 7);
    let seqs: Vec<Vec<u32>> = (0..32).map(|_| gen.sequence(cfg.seq_len)).collect();
    for (label, batch_size) in [("coordinator batch=1 (no batching)", 1), ("coordinator batch=8", 8)] {
        let coordinator = EvalCoordinator::start(
            store.clone(),
            cfg,
            vec![("w".into(), weights.flat.clone())],
            CoordinatorConfig {
                batch_size,
                max_batch_delay: Duration::from_millis(2),
                max_queue: 256,
                engine: Default::default(),
                artifacts: Vec::new(),
            },
        );
        let r = bench(label, Duration::from_millis(1500), || {
            coordinator
                .evaluate_stream(
                    seqs.clone(),
                    ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 },
                    "w",
                )
                .unwrap();
        });
        r.print_throughput(32.0 * cfg.seq_len as f64, "tok");
    }
}
