//! Figure 5: perplexity vs model size for Per-token vs CrossQuant (and the
//! FP16 floor), at W8A8 (top panels) and W4A8-g128 (bottom panels), both
//! families.

use anyhow::Result;

use super::common::{prepare, run_ppl, ExpOpts, Method, Setting};
use crate::activations::{Family, FamilyProfile};
use crate::corpus::CorpusKind;
use crate::eval::harness::{Row, Table};
use crate::model::weights::Weights;

pub fn run(base: &Weights, family: Family, setting: Setting, opts: &ExpOpts) -> Result<Table> {
    let profiles: Vec<FamilyProfile> = match family {
        Family::Opt => FamilyProfile::opt_family(),
        Family::Llama => FamilyProfile::llama_family(),
    };
    let columns: Vec<&str> = profiles.iter().map(|p| p.name).collect();
    let mut table = Table::new(
        format!("Figure 5 — WikiText2 perplexity, {family} family, {}", setting.label()),
        columns,
    );

    for (method, label) in [
        (Method::Fp16, "FP16"),
        (Method::PerToken, "Per-token"),
        (Method::CrossQuant { alpha: 0.15 }, "CrossQuant"),
    ] {
        let mut cells = Vec::new();
        for p in &profiles {
            let s = if method == Method::Fp16 { Setting::fp() } else { setting };
            let mut prep = prepare(base, p, method, s, opts)?;
            cells.push(run_ppl(&mut prep, CorpusKind::Wiki2, opts)?.perplexity);
        }
        let s = if method == Method::Fp16 { Setting::fp() } else { setting };
        table.push(Row::new(label, s.label(), cells));
    }
    Ok(table)
}
