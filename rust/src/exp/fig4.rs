//! Figure 4: average quantization-kernel proportion of Per-token vs
//! CrossQuant across the OPT (left) and LLaMA (right) families, measured
//! over the model's own activations on the Wiki2 corpus.

use anyhow::Result;

use crate::activations::{Family, FamilyProfile};
use crate::analysis::kernel_fraction;
use crate::eval::harness::{Row, Table};
use crate::model::forward::CaptureSite;
use crate::model::quantized::inject_profile;
use crate::model::weights::Weights;
use crate::model::NativeModel;
use crate::quant::{crossquant::CrossQuant, per_token::PerToken, ActQuantizer, Bits};

use super::common::ExpOpts;

pub fn run(base: &Weights, family: Family, opts: &ExpOpts) -> Result<Table> {
    let profiles: Vec<FamilyProfile> = match family {
        Family::Opt => FamilyProfile::opt_family(),
        Family::Llama => FamilyProfile::llama_family(),
    };
    let columns: Vec<&str> = profiles.iter().map(|p| p.name).collect();
    let mut table = Table::new(
        format!("Figure 4 — kernel proportion across the {family} family (INT8)"),
        columns,
    )
    .percent()
    .decimals(2);

    let mut pt_cells = Vec::new();
    let mut cq_cells = Vec::new();
    for p in &profiles {
        let (pt, cq) = model_kernel_fractions(base, p, opts)?;
        pt_cells.push(pt as f64);
        cq_cells.push(cq as f64);
    }
    table.push(Row::new("Per-token", "A8", pt_cells));
    table.push(Row::new("CrossQuant", "A8", cq_cells));
    Ok(table)
}

/// Average (per-token, crossquant) kernel fraction over all quantization
/// sites of the profile-injected model on the Wiki2 corpus.
pub fn model_kernel_fractions(
    base: &Weights,
    profile: &FamilyProfile,
    opts: &ExpOpts,
) -> Result<(f32, f32)> {
    let mut w = base.clone();
    inject_profile(&mut w, profile)?;
    let cfg = w.config;
    let model = NativeModel::new(w);
    let mut cap = CaptureSite::all();
    let mut gen = crate::corpus::CorpusGen::new(cfg.vocab, opts.seed ^ 0xF16_4);
    for _ in 0..opts.calib_sequences.max(2) {
        model.forward_nll(&gen.sequence(cfg.seq_len), &mut cap)?;
    }
    let pt = PerToken::new(Bits::Int8);
    let cq = CrossQuant::new(0.15, Bits::Int8);
    let (mut pt_sum, mut cq_sum, mut n) = (0.0f64, 0.0f64, 0.0f64);
    for (_, x) in &cap.captured {
        let elems = x.len() as f64;
        pt_sum += kernel_fraction(x, &pt.delta_field(x)) as f64 * elems;
        cq_sum += kernel_fraction(x, &cq.delta_field(x)) as f64 * elems;
        n += elems;
    }
    Ok(((pt_sum / n) as f32, (cq_sum / n) as f32))
}
