//! Figure 8: α sweeps — (left) OPT-6.7B W8A8 accuracy on the Lambada-like
//! task and (right) LLaMA2-13B W4A8 WikiText2 perplexity, as α runs from
//! near-0 to 1 (α = 1 ≡ per-token).

use anyhow::Result;

use super::common::{prepare, run_ppl, ExpOpts, Method, Setting};
use crate::activations::FamilyProfile;
use crate::corpus::CorpusKind;
use crate::eval::harness::{Row, Table};
use crate::eval::tasks::Task;
use crate::model::weights::Weights;

pub fn alphas() -> Vec<f32> {
    vec![0.05, 0.15, 0.25, 0.35, 0.45, 0.55, 0.65, 0.75, 0.85, 0.95, 1.0]
}

pub fn run(base: &Weights, opts: &ExpOpts) -> Result<Table> {
    let a = alphas();
    let columns: Vec<String> = a.iter().map(|v| format!("α={v}")).collect();
    let mut table = Table::new(
        "Figure 8 — α sweep: OPT-6.7B Lambada acc (W8A8) / LLaMA2-13B Wiki2 ppl (W4A8)",
        columns.iter().map(|s| s.as_str()).collect(),
    )
    .decimals(3);

    // left panel: OPT-6.7B accuracy on the lambada-like task, W8A8
    let opt = FamilyProfile::by_name("opt-6.7b").expect("profile");
    let lambada = Task::zero_shot_suite().into_iter().find(|t| t.name == "lambada").unwrap();
    let mut acc_cells = Vec::new();
    for &alpha in &a {
        let mut prep = prepare(base, &opt, Method::CrossQuant { alpha }, Setting::w8a8(), opts)?;
        let r = lambada.evaluate(&prep.model, prep.site.as_mut(), opts.task_instances, opts.seed)?;
        acc_cells.push(r.accuracy);
    }
    table.push(Row::new("OPT-6.7B lambada acc", "W8A8", acc_cells));

    // right panel: LLaMA2-13B Wiki2 perplexity, W4A8-g128
    let llama = FamilyProfile::by_name("llama2-13b").expect("profile");
    let mut ppl_cells = Vec::new();
    for &alpha in &a {
        let mut prep =
            prepare(base, &llama, Method::CrossQuant { alpha }, Setting::w4a8_g128(), opts)?;
        ppl_cells.push(run_ppl(&mut prep, CorpusKind::Wiki2, opts)?.perplexity);
    }
    table.push(Row::new("LLaMA2-13B Wiki2 ppl", "W4A8-g128", ppl_cells));

    // companion series (not in the paper's figure, but the same sweep on an
    // outlier-heavy profile, where the α trend is strongest)
    let opt13 = FamilyProfile::by_name("opt-13b").expect("profile");
    let mut opt_cells = Vec::new();
    for &alpha in &a {
        let mut prep = prepare(base, &opt13, Method::CrossQuant { alpha }, Setting::w8a8(), opts)?;
        opt_cells.push(run_ppl(&mut prep, CorpusKind::Wiki2, opts)?.perplexity);
    }
    table.push(Row::new("OPT-13B Wiki2 ppl", "W8A8", opt_cells));
    Ok(table)
}
