//! Tables 3 & 5: zero-shot accuracy of OPT models across the quantization
//! method × precision grid (Lambada, ARC-easy, PIQA, HellaSwag, BoolQ +
//! average). Table 3 covers OPT-30B/66B with all baselines; Table 5 covers
//! OPT-1.3B…13B with the Per-token / CrossQuant pair.

use anyhow::Result;

use super::common::{prepare, run_tasks, ExpOpts, Method, Setting};
use crate::activations::FamilyProfile;
use crate::eval::harness::{Row, Table};
use crate::model::quantized::WeightScheme;
use crate::model::weights::Weights;
use crate::quant::Bits;

pub fn method_grid_tab3() -> Vec<(Method, Setting)> {
    vec![
        (Method::Fp16, Setting::fp()),
        (Method::PerToken, Setting::w8a8()),
        (Method::SmoothQuant, Setting::w8a8()),
        (Method::CrossQuant { alpha: 0.15 }, Setting::w8a8()),
        (Method::PerToken, Setting::w4a8_g128()),
        (Method::Awq, Setting::w4a8_g128()),
        (Method::CrossQuant { alpha: 0.15 }, Setting::w4a8_g128()),
        (Method::PerToken, Setting::w4a4()),
        (Method::OmniQuant, Setting::w4a4()),
        (Method::CrossQuant { alpha: 0.15 }, Setting::w4a4()),
    ]
}

pub fn method_grid_tab5() -> Vec<(Method, Setting)> {
    vec![
        (Method::Fp16, Setting::fp()),
        (Method::PerToken, Setting::w8a8()),
        (Method::CrossQuant { alpha: 0.15 }, Setting::w8a8()),
        (Method::PerToken, Setting::w4a8_g128()),
        (Method::CrossQuant { alpha: 0.15 }, Setting::w4a8_g128()),
    ]
}

pub fn run(base: &Weights, models: &[&str], tab5: bool, opts: &ExpOpts) -> Result<Vec<Table>> {
    let grid = if tab5 { method_grid_tab5() } else { method_grid_tab3() };
    let mut tables = Vec::new();
    for name in models {
        let profile = FamilyProfile::by_name(name).expect("profile");
        let mut table = Table::new(
            format!(
                "Table {} — zero-shot accuracy (↑), {}",
                if tab5 { "5" } else { "3" },
                name
            ),
            vec!["Lambada", "ARC-easy", "PIQA", "HellaSwag", "BoolQ", "Avg."],
        )
        .percent()
        .decimals(2);

        for (method, mut setting) in grid.clone() {
            // Appendix B.1 corner: OPT-66B W4A4 uses CrossQuant on weights
            // too (α_W = 0.55) because per-channel weight kernels hurt.
            if *name == "opt-66b"
                && matches!(method, Method::CrossQuant { .. })
                && matches!(setting.act, Some(Bits::Int4))
            {
                setting.weight = WeightScheme::CrossQuant(Bits::Int4, 0.55);
            }
            let mut prep = prepare(base, &profile, method, setting, opts)?;
            let (per_task, avg) = run_tasks(&mut prep, opts)?;
            let mut cells: Vec<f64> = per_task.iter().map(|(_, r)| r.accuracy).collect();
            cells.push(avg);
            table.push(Row::new(method.label(), setting.label(), cells));
        }
        tables.push(table);
    }
    Ok(tables)
}
