//! Figures 6 & 7: the threshold analysis of §4.3 — quantize weights to
//! INT8, zero increasing proportions of the activation kernel ("W8-Remove
//! Kernel") and record perplexity. The knee of each curve is the model's
//! kernel-tolerance threshold (≈19–25 % for OPT, ≈1–2 % for LLaMA).

use anyhow::Result;

use super::common::{calibrate_activations, ExpOpts};
use crate::activations::{Family, FamilyProfile};
use crate::analysis::threshold::ThresholdCurve;
use crate::corpus::CorpusKind;
use crate::eval::harness::{Row, Table};
use crate::eval::perplexity::perplexity_native;
use crate::model::quantized::{inject_profile, quantize_weights, WeightScheme};
use crate::model::weights::Weights;
use crate::model::{IdentitySite, NativeModel, RemoveKernelSite};
use crate::quant::remove_kernel::RemoveKernel;
use crate::quant::Bits;
use crate::tensor::Matrix;

/// Sweep fractions per family (the paper sweeps finer near each regime).
pub fn fractions(family: Family) -> Vec<f32> {
    match family {
        Family::Opt => vec![0.0, 0.05, 0.10, 0.19, 0.25, 0.30, 0.40, 0.50, 0.65, 0.80],
        Family::Llama => vec![0.0, 0.005, 0.01, 0.02, 0.05, 0.11, 0.20, 0.35, 0.50],
    }
}

pub struct FigResult {
    pub table: Table,
    /// (profile name, threshold at 5 % ppl tolerance).
    pub thresholds: Vec<(String, Option<f32>)>,
}

pub fn run(base: &Weights, family: Family, opts: &ExpOpts) -> Result<FigResult> {
    let profiles: Vec<FamilyProfile> = match family {
        // ≥6.7B, as in Fig 6
        Family::Opt => FamilyProfile::opt_family().into_iter().skip(2).collect(),
        Family::Llama => FamilyProfile::llama_family().into_iter().take(3).collect(),
    };
    let fracs = fractions(family);
    let columns: Vec<String> = fracs.iter().map(|f| format!("{:.1}%", f * 100.0)).collect();
    let fig = if family == Family::Opt { "Figure 6" } else { "Figure 7" };
    let mut table = Table::new(
        format!("{fig} — W8-Remove-Kernel perplexity vs removed fraction ({family})"),
        columns.iter().map(|s| s.as_str()).collect(),
    );

    let mut thresholds = Vec::new();
    for p in &profiles {
        let (curve, cells) = sweep_profile(base, p, &fracs, opts)?;
        thresholds.push((p.name.to_string(), curve.threshold(0.05)));
        table.push(Row::new(p.name, "W8A16*", cells));
    }
    Ok(FigResult { table, thresholds })
}

/// Sweep one profile; returns the curve and the raw ppl cells.
pub fn sweep_profile(
    base: &Weights,
    profile: &FamilyProfile,
    fracs: &[f32],
    opts: &ExpOpts,
) -> Result<(ThresholdCurve, Vec<f64>)> {
    let mut w = base.clone();
    inject_profile(&mut w, profile)?;
    // calibrate θ per target fraction on the model's own activations
    let calib = calibrate_activations(&w, opts)?;
    let mut all = Matrix::zeros(0, calib[0].cols);
    for m in &calib {
        if m.cols == all.cols {
            all.data.extend_from_slice(&m.data);
            all.rows += m.rows;
        }
    }
    quantize_weights(&mut w, WeightScheme::PerChannel(Bits::Int8))?;
    let model = NativeModel::new(w);

    let fp = perplexity_native(
        &model,
        &mut IdentitySite,
        CorpusKind::Wiki2,
        opts.eval_sequences,
        opts.seed ^ 0xE7A1,
    )?;

    let mut cells = Vec::new();
    let curve = ThresholdCurve::sweep(fracs, fp.perplexity, |frac| {
        let rk = if frac == 0.0 {
            RemoveKernel::new(0.0)
        } else {
            RemoveKernel::for_target_fraction(&all, frac)
        };
        let mut site = RemoveKernelSite::new(rk);
        let r = perplexity_native(
            &model,
            &mut site,
            CorpusKind::Wiki2,
            opts.eval_sequences,
            opts.seed ^ 0xE7A1,
        )
        .expect("eval");
        cells.push(r.perplexity);
        r.perplexity
    });
    Ok((curve, cells))
}
