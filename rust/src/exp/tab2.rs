//! Table 2: perplexity of quantized LLaMA models (2-7B, 2-13B, 1-30B) on
//! Wiki2 + C4, in three groups — W8A8 (vs SmoothQuant), W4A8-g128 (vs AWQ,
//! plus the CrossQuant+AWQ composition), and W4A4 (vs OmniQuant).

use anyhow::Result;

use super::common::{prepare, run_ppl, ExpOpts, Method, Setting};
use crate::activations::FamilyProfile;
use crate::corpus::CorpusKind;
use crate::eval::harness::{Row, Table};
use crate::model::weights::Weights;

pub const MODELS: [&str; 3] = ["llama2-7b", "llama2-13b", "llama1-30b"];

pub fn run(base: &Weights, opts: &ExpOpts) -> Result<Table> {
    let profiles: Vec<FamilyProfile> =
        MODELS.iter().map(|n| FamilyProfile::by_name(n).expect("profile")).collect();
    let mut columns = Vec::new();
    for p in &profiles {
        columns.push(format!("{} Wiki2", p.name));
        columns.push(format!("{} C4", p.name));
    }
    let mut table = Table::new(
        "Table 2 — perplexity (↓) of quantized LLaMA models",
        columns.iter().map(|s| s.as_str()).collect(),
    );

    let groups: Vec<(Method, Setting)> = vec![
        (Method::Fp16, Setting::fp()),
        // --- W8A8 group ---
        (Method::PerToken, Setting::w8a8()),
        (Method::SmoothQuant, Setting::w8a8()),
        (Method::CrossQuant { alpha: 0.15 }, Setting::w8a8()),
        // --- W4A8-g128 group ---
        (Method::PerToken, Setting::w4a8_g128()),
        (Method::Awq, Setting::w4a8_g128()),
        (Method::CrossQuant { alpha: 0.15 }, Setting::w4a8_g128()),
        (Method::CrossQuantAwq { alpha: 0.15 }, Setting::w4a8_g128()),
        // --- W4A4 group ---
        (Method::PerToken, Setting::w4a4()),
        (Method::OmniQuant, Setting::w4a4()),
        (Method::CrossQuant { alpha: 0.15 }, Setting::w4a4()),
    ];

    for (method, setting) in groups {
        let mut cells = Vec::new();
        for p in &profiles {
            let mut prep = prepare(base, p, method, setting, opts)?;
            cells.push(run_ppl(&mut prep, CorpusKind::Wiki2, opts)?.perplexity);
            let mut prep = prepare(base, p, method, setting, opts)?;
            cells.push(run_ppl(&mut prep, CorpusKind::C4, opts)?.perplexity);
        }
        table.push(Row::new(method.label(), setting.label(), cells));
    }
    Ok(table)
}
