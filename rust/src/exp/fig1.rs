//! Figure 1 (and its W4/W8 companion Figure 9): average zero-shot accuracy
//! of OPT family models under FP16 / per-token A8 / "Remove Kernel" /
//! CrossQuant, demonstrating that (a) zeroing the kernel alone reproduces
//! A8's collapse, and (b) CrossQuant stays at FP16 level.

use anyhow::Result;

use super::common::{prepare, run_tasks, ExpOpts, Method, Setting};
use crate::activations::FamilyProfile;
use crate::eval::harness::{Row, Table};
use crate::model::quantized::{inject_profile, quantize_weights, WeightScheme};
use crate::model::weights::Weights;
use crate::model::{NativeModel, RemoveKernelSite};
use crate::quant::remove_kernel::RemoveKernel;
use crate::quant::Bits;

/// `weight_bits` selects the Figure-1 (W8) or Figure-9 (W4) companion.
pub fn run(base: &Weights, weight_bits: Bits, opts: &ExpOpts) -> Result<Table> {
    let profiles = FamilyProfile::opt_family();
    let columns: Vec<&str> = profiles.iter().map(|p| p.name).collect();
    let wlabel = match weight_bits {
        Bits::Int8 => "W8",
        Bits::Int4 => "W4",
        _ => "W?",
    };
    let mut table = Table::new(
        format!("Figure 1/9 — avg zero-shot accuracy, OPT family ({wlabel})"),
        columns,
    )
    .percent()
    .decimals(1);

    let wscheme = WeightScheme::PerChannel(weight_bits);

    // FP16 baseline
    table.push(row_for(base, &profiles, Method::Fp16, Setting::fp(), opts, "FP16")?);
    // weight-only (Wx + FP activations)
    table.push(row_for(
        base,
        &profiles,
        Method::PerToken,
        Setting { weight: wscheme, act: None },
        opts,
        &format!("{wlabel} (act FP16)"),
    )?);
    // per-token A8
    table.push(row_for(
        base,
        &profiles,
        Method::PerToken,
        Setting { weight: wscheme, act: Some(Bits::Int8) },
        opts,
        &format!("Per-token {wlabel}A8"),
    )?);
    // Remove Kernel: zero exactly the per-token INT8 kernel, nothing else
    {
        let mut cells = Vec::new();
        for p in &profiles {
            let mut w = base.clone();
            inject_profile(&mut w, p)?;
            quantize_weights(&mut w, wscheme)?;
            let model = NativeModel::new(w);
            let mut site = RemoveKernelSite::new(RemoveKernel::matching_per_token(127.0));
            let suite =
                crate::eval::tasks::TaskSuite::standard(opts.task_instances, opts.seed ^ 0x7A5C);
            let (_, avg) = suite.evaluate(&model, &mut site)?;
            cells.push(avg);
        }
        table.push(Row::new(format!("{wlabel}-Remove Kernel"), format!("{wlabel}A16*"), cells));
    }
    // CrossQuant A8
    table.push(row_for(
        base,
        &profiles,
        Method::CrossQuant { alpha: 0.15 },
        Setting { weight: wscheme, act: Some(Bits::Int8) },
        opts,
        &format!("CrossQuant {wlabel}A8"),
    )?);

    Ok(table)
}

fn row_for(
    base: &Weights,
    profiles: &[FamilyProfile],
    method: Method,
    setting: Setting,
    opts: &ExpOpts,
    label: &str,
) -> Result<Row> {
    let mut cells = Vec::new();
    for p in profiles {
        let mut prep = prepare(base, p, method, setting, opts)?;
        let (_, avg) = run_tasks(&mut prep, opts)?;
        cells.push(avg);
    }
    Ok(Row::new(label, setting.label(), cells))
}
