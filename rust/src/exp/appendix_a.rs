//! Appendix A ablation: outliers cause the kernel, the kernel causes the
//! loss. Sweeps the injected outlier magnitude on a fixed profile and
//! reports, per magnitude: the per-token kernel fraction, the CrossQuant
//! kernel fraction, and both schemes' W8A8 perplexity — making the causal
//! chain (outlier → t_i → kernel → ppl) quantitative, and showing
//! CrossQuant breaking the chain at the kernel link.

use anyhow::Result;

use super::common::{prepare, run_ppl, ExpOpts, Method, Setting};
use super::fig4::model_kernel_fractions;
use crate::activations::{Family, FamilyProfile};
use crate::corpus::CorpusKind;
use crate::eval::harness::{Row, Table};
use crate::model::weights::Weights;

pub fn outlier_scales() -> Vec<f32> {
    vec![1.0, 10.0, 25.0, 50.0, 75.0, 100.0, 127.0]
}

pub fn run(base: &Weights, opts: &ExpOpts) -> Result<Table> {
    let scales = outlier_scales();
    let columns: Vec<String> = scales.iter().map(|s| format!("{s}x")).collect();
    let mut table = Table::new(
        "Appendix A ablation — outlier magnitude → kernel → perplexity (W8A8)",
        columns.iter().map(|s| s.as_str()).collect(),
    )
    .decimals(2);

    let mut pt_kernel = Vec::new();
    let mut cq_kernel = Vec::new();
    let mut pt_ppl = Vec::new();
    let mut cq_ppl = Vec::new();
    for &scale in &scales {
        let profile = FamilyProfile::new(
            "ablate",
            Family::Opt,
            0.0,
            3,
            scale,
            0.14,
            0.0,
            0.02,
            0.0,
        );
        let (kp, kc) = model_kernel_fractions(base, &profile, opts)?;
        pt_kernel.push(kp as f64 * 100.0);
        cq_kernel.push(kc as f64 * 100.0);

        let mut prep = prepare(base, &profile, Method::PerToken, Setting::w8a8(), opts)?;
        pt_ppl.push(run_ppl(&mut prep, CorpusKind::Wiki2, opts)?.perplexity);
        let mut prep =
            prepare(base, &profile, Method::CrossQuant { alpha: 0.15 }, Setting::w8a8(), opts)?;
        cq_ppl.push(run_ppl(&mut prep, CorpusKind::Wiki2, opts)?.perplexity);
    }
    table.push(Row::new("Per-token kernel", "%", pt_kernel));
    table.push(Row::new("CrossQuant kernel", "%", cq_kernel));
    table.push(Row::new("Per-token ppl", "W8A8", pt_ppl));
    table.push(Row::new("CrossQuant ppl", "W8A8", cq_ppl));
    Ok(table)
}
