//! §4.2 observation (1): "the size of quantization kernels is positively
//! correlated with perplexity". This module makes the claim quantitative:
//! for every OPT profile it pools (kernel-fraction, log-perplexity) pairs
//! from the remove-kernel sweep and reports the Pearson correlation, plus
//! the pooled coefficient across profiles.

use anyhow::Result;

use super::common::ExpOpts;
use super::fig67::{fractions, sweep_profile};
use crate::activations::{Family, FamilyProfile};
use crate::eval::harness::{Row, Table};
use crate::model::weights::Weights;

/// Pearson correlation of x vs y.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt()).max(1e-12)
}

pub fn run(base: &Weights, opts: &ExpOpts) -> Result<Table> {
    let profiles: Vec<FamilyProfile> =
        FamilyProfile::opt_family().into_iter().skip(2).collect();
    let columns: Vec<&str> = profiles.iter().map(|p| p.name).collect();
    let mut table = Table::new(
        "§4.2 correlation — Pearson r of (kernel fraction, log ppl), remove-kernel sweep",
        columns,
    )
    .decimals(3);

    let fracs = fractions(Family::Opt);
    let mut cells = Vec::new();
    let mut pooled_x = Vec::new();
    let mut pooled_y = Vec::new();
    for p in &profiles {
        let (_, ppls) = sweep_profile(base, p, &fracs, opts)?;
        let xs: Vec<f64> = fracs.iter().map(|&f| f as f64).collect();
        let ys: Vec<f64> = ppls.iter().map(|&p| p.ln()).collect();
        cells.push(pearson(&xs, &ys));
        pooled_x.extend(xs);
        pooled_y.extend(ys);
    }
    table.push(Row::new("Pearson r", "W8A16*", cells));
    println!(
        "  pooled r over {} points: {:.3} (paper: 'positively correlated')",
        pooled_x.len(),
        pearson(&pooled_x, &pooled_y)
    );
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_line() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_anticorrelated() {
        let x = [1.0, 2.0, 3.0];
        let y = [3.0, 2.0, 1.0];
        assert!((pearson(&x, &y) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate() {
        assert!(pearson(&[1.0], &[2.0]).is_nan());
    }
}
