//! Paper-artefact reproduction modules: one per table/figure (DESIGN.md §5).
//!
//! Every module exposes `run(opts) -> Table` printing the same rows/series
//! the paper reports, regenerable via `repro reproduce <id>`.

pub mod appendix_a;
pub mod common;
pub mod correlation;
pub mod fig1;
pub mod fig4;
pub mod fig5;
pub mod fig67;
pub mod fig8;
pub mod registry_sweep;
pub mod tab1;
pub mod tab2;
pub mod tab3;
pub mod tab4;
pub mod weight_kernel;
