//! Appendix B.1's weight-kernel corner: per-channel quantization of
//! *weights* also has a quantization kernel (outliers emerge in weights of
//! large models — Dettmers 2023, Kim 2023), which is what forced the paper
//! to run CrossQuant on weights for OPT-66B W4A4 and LLaMA3-70B W8A8.
//!
//! This ablation measures, on the trained model's own weight matrices:
//! the per-channel weight kernel at W8/W4 versus CrossQuant-on-weights
//! across an α_W grid, plus the resulting W4A4 perplexity (activations
//! CrossQuant-quantized at the paper's α = 0.15 throughout).

use anyhow::Result;

use super::common::{run_ppl, ExpOpts, PreparedEval};
use crate::activations::FamilyProfile;
use crate::analysis::kernel_fraction;
use crate::corpus::CorpusKind;
use crate::eval::harness::{Row, Table};
use crate::model::quantized::{inject_profile, quantize_weights, WeightScheme};
use crate::model::weights::Weights;
use crate::model::{NativeModel, QuantSite};
use crate::quant::{
    crossquant::CrossQuant, per_channel::PerChannel, ActQuantizer, Bits,
};

pub const ALPHA_W: [f32; 5] = [0.0, 0.15, 0.55, 0.85, 1.0];

pub fn run(base: &Weights, opts: &ExpOpts) -> Result<Table> {
    let mut columns: Vec<String> = vec!["per-channel".into()];
    columns.extend(ALPHA_W.iter().map(|a| format!("cq α_W={a}")));
    let mut table = Table::new(
        "Weight-kernel ablation (App. B.1) — OPT-66B profile, W4 weights",
        columns.iter().map(|s| s.as_str()).collect(),
    )
    .decimals(2);

    let profile = FamilyProfile::by_name("opt-66b").expect("profile");
    let mut injected = base.clone();
    inject_profile(&mut injected, &profile)?;

    // --- average weight-kernel fraction across the linear weights ---
    let mut kernel_cells = Vec::new();
    {
        let names = injected.linear_names();
        let mut schemes: Vec<Box<dyn ActQuantizer>> = vec![Box::new(PerChannel::new(Bits::Int4))];
        for &a in &ALPHA_W {
            schemes.push(Box::new(CrossQuant::weight_mode(a, Bits::Int4)));
        }
        for q in &schemes {
            let (mut kern, mut total) = (0.0f64, 0.0f64);
            for name in &names {
                let w = injected.get(name)?;
                kern += kernel_fraction(&w, &q.delta_field(&w)) as f64 * w.len() as f64;
                total += w.len() as f64;
            }
            kernel_cells.push(kern / total * 100.0);
        }
    }
    table.push(Row::new("Weight kernel", "%", kernel_cells));

    // --- end-to-end W4A4 perplexity per weight scheme ---
    let mut ppl_cells = Vec::new();
    let run_scheme = |scheme: WeightScheme| -> Result<f64> {
        let mut w = injected.clone();
        quantize_weights(&mut w, scheme)?;
        let mut prep = PreparedEval {
            model: NativeModel::new(w),
            site: Box::new(QuantSite::new(CrossQuant::new(0.15, Bits::Int4))),
        };
        Ok(run_ppl(&mut prep, CorpusKind::Wiki2, opts)?.perplexity)
    };
    ppl_cells.push(run_scheme(WeightScheme::PerChannel(Bits::Int4))?);
    for &a in &ALPHA_W {
        ppl_cells.push(run_scheme(WeightScheme::CrossQuant(Bits::Int4, a))?);
    }
    table.push(Row::new("W4A4 ppl (CQ acts)", "W4A4", ppl_cells));
    Ok(table)
}
