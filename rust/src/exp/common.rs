//! Shared machinery for the table/figure reproductions: method × setting
//! preparation (weight transforms, calibration, activation sites) and the
//! evaluation drivers.

use anyhow::Result;

use crate::activations::FamilyProfile;
use crate::corpus::CorpusKind;
use crate::eval::perplexity::{perplexity_native, PerplexityResult};
use crate::eval::tasks::TaskSuite;
use crate::model::forward::CaptureSite;
use crate::model::quantized::{apply_smoothquant, inject_profile, quantize_weights, WeightScheme};
use crate::model::weights::Weights;
use crate::model::{ActSite, IdentitySite, NativeModel, QuantSite};
use crate::quant::awq::Awq;
use crate::quant::clipping::ClippedPerToken;
use crate::quant::crossquant::CrossQuant;
use crate::quant::per_token::PerToken;
use crate::quant::smoothquant::SmoothQuant;
use crate::quant::Bits;
use crate::tensor::Matrix;

/// The methods appearing as rows in the paper's tables.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    Fp16,
    PerToken,
    SmoothQuant,
    CrossQuant { alpha: f32 },
    Awq,
    CrossQuantAwq { alpha: f32 },
    OmniQuant,
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Fp16 => "FP16".into(),
            Method::PerToken => "Per-token".into(),
            Method::SmoothQuant => "SmoothQuant".into(),
            Method::CrossQuant { alpha } => {
                if (*alpha - 0.15).abs() < 1e-6 {
                    "CrossQuant".into()
                } else {
                    format!("CrossQuant α={alpha}")
                }
            }
            Method::Awq => "AWQ".into(),
            Method::CrossQuantAwq { .. } => "CrossQuant+AWQ".into(),
            Method::OmniQuant => "OmniQuant".into(),
        }
    }
}

/// A W/A precision setting (paper column "W/A").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Setting {
    pub weight: WeightScheme,
    /// Activation bits; None = FP activations (A16).
    pub act: Option<Bits>,
}

impl Setting {
    pub fn w8a8() -> Setting {
        Setting { weight: WeightScheme::PerChannel(Bits::Int8), act: Some(Bits::Int8) }
    }

    pub fn w4a8_g128() -> Setting {
        Setting { weight: WeightScheme::GroupWise(Bits::Int4, 128), act: Some(Bits::Int8) }
    }

    pub fn w4a4() -> Setting {
        Setting { weight: WeightScheme::PerChannel(Bits::Int4), act: Some(Bits::Int4) }
    }

    pub fn fp() -> Setting {
        Setting { weight: WeightScheme::None, act: None }
    }

    pub fn label(&self) -> String {
        match (self.weight, self.act) {
            (WeightScheme::None, None) => "W16A16".into(),
            (w, None) => format!("{}A16", w.label()),
            (WeightScheme::None, Some(b)) => format!("W16{b}"),
            (w, Some(b)) => format!("{}{}", w.label(), b),
        }
    }
}

/// Experiment-wide options (sizes chosen so a full table regenerates in
/// seconds-to-minutes on one CPU core; bump for paper-scale averaging).
#[derive(Clone, Copy, Debug)]
pub struct ExpOpts {
    pub eval_sequences: usize,
    pub task_instances: usize,
    pub calib_sequences: usize,
    pub seed: u64,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts { eval_sequences: 12, task_instances: 40, calib_sequences: 2, seed: 0xC0FFEE }
    }
}

/// Map each quantization-site index to the linear weights it feeds
/// (calibration bookkeeping for SmoothQuant / AWQ; also used by the
/// scheme registry's static pipeline).
pub fn site_consumers(n_layers: usize, l_site: usize) -> Vec<String> {
    let l = l_site / 4;
    if l >= n_layers {
        return vec!["w_out".into()];
    }
    match l_site % 4 {
        0 => vec![format!("layer{l}.wq"), format!("layer{l}.wk"), format!("layer{l}.wv")],
        1 => vec![format!("layer{l}.wo")],
        2 => vec![format!("layer{l}.w1")],
        _ => vec![format!("layer{l}.w2")],
    }
}

/// LN-fed sites (the smoothable edges): ln1 (4l), ln2 (4l+2), lnf (4L).
pub fn ln_site_name(n_layers: usize, site: usize) -> Option<String> {
    let l = site / 4;
    if l >= n_layers {
        return Some("lnf_g".into());
    }
    match site % 4 {
        0 => Some(format!("layer{l}.ln1_g")),
        2 => Some(format!("layer{l}.ln2_g")),
        _ => None,
    }
}

/// Capture per-site calibration activations on the FP (profile-injected)
/// model.
pub fn calibrate_activations(
    weights: &Weights,
    opts: &ExpOpts,
) -> Result<Vec<Matrix>> {
    let model = NativeModel::new(weights.clone());
    let cfg = weights.config;
    let mut cap = CaptureSite::all();
    let mut gen = crate::corpus::CorpusGen::new(cfg.vocab, opts.seed ^ 0xCA11B);
    for _ in 0..opts.calib_sequences {
        let toks = gen.sequence(cfg.seq_len);
        model.forward_nll(&toks, &mut cap)?;
    }
    // concatenate captures per site
    let n_sites = cfg.n_quant_sites();
    let mut per_site: Vec<Vec<&Matrix>> = vec![Vec::new(); n_sites];
    for (site, m) in &cap.captured {
        per_site[*site].push(m);
    }
    Ok(per_site
        .into_iter()
        .map(|mats| {
            let rows: usize = mats.iter().map(|m| m.rows).sum();
            let cols = mats.first().map(|m| m.cols).unwrap_or(0);
            let mut out = Matrix::zeros(rows, cols);
            let mut r = 0;
            for m in mats {
                out.data[r * cols..(r + m.rows) * cols].copy_from_slice(&m.data);
                r += m.rows;
            }
            out
        })
        .collect())
}

/// A fully-prepared evaluation: profile-injected + method-transformed
/// weights, and the activation site to run with.
pub struct PreparedEval {
    pub model: NativeModel,
    pub site: Box<dyn ActSite>,
}

/// Build the (model, site) pair for one (profile, method, setting) cell.
pub fn prepare(
    base: &Weights,
    profile: &FamilyProfile,
    method: Method,
    setting: Setting,
    opts: &ExpOpts,
) -> Result<PreparedEval> {
    let mut w = base.clone();
    inject_profile(&mut w, profile)?;

    let act_bits = setting.act;
    let needs_calib = matches!(
        method,
        Method::SmoothQuant | Method::Awq | Method::CrossQuantAwq { .. } | Method::OmniQuant
    );
    let calib = if needs_calib { Some(calibrate_activations(&w, opts)?) } else { None };
    let cfg = w.config;

    // ---- weight-space preparation ----
    match method {
        Method::Awq | Method::CrossQuantAwq { .. } => {
            // activation-aware weight quantization per linear
            let calib = calib.as_ref().expect("calibrated");
            let (bits, group) = match setting.weight {
                WeightScheme::GroupWise(b, g) => (b, g),
                WeightScheme::PerChannel(b) => (b, 128),
                _ => (Bits::Int4, 128),
            };
            for site in 0..cfg.n_quant_sites() {
                let x = &calib[site];
                for name in site_consumers(cfg.n_layers, site) {
                    let wm = w.get(&name)?;
                    let awq = Awq::search(x, &wm, bits, group.min(wm.len()));
                    w.set(&name, &awq.effective_weight(&wm))?;
                }
            }
        }
        Method::SmoothQuant => {
            let calib = calib.as_ref().expect("calibrated");
            // smoothing strength per family (paper App. B.1)
            let strength = match profile.family {
                crate::activations::Family::Opt => 0.5,
                crate::activations::Family::Llama => 0.8,
            };
            let mut folds = Vec::new();
            for site in 0..cfg.n_quant_sites() {
                if let Some(ln) = ln_site_name(cfg.n_layers, site) {
                    let consumer = &site_consumers(cfg.n_layers, site)[0];
                    let sq = SmoothQuant::calibrate(&calib[site], &w.get(consumer)?, strength);
                    folds.push((ln, sq.scales));
                }
            }
            // Folding is the whole deployment: the LN affine is divided by
            // s (so its output — the quantizer's input — arrives smoothed)
            // and the consuming rows are multiplied by s, exactly
            // compensating. The eval site is then a plain per-token
            // quantizer; no runtime division remains (SmoothQuant's point).
            apply_smoothquant(&mut w, &folds)?;
            quantize_weights(&mut w, setting.weight)?;
        }
        _ => {
            quantize_weights(&mut w, setting.weight)?;
        }
    }

    // ---- activation site ----
    let site: Box<dyn ActSite> = match (method, act_bits) {
        (Method::Fp16, _) | (_, None) => Box::new(IdentitySite),
        (Method::PerToken, Some(b)) | (Method::Awq, Some(b)) | (Method::SmoothQuant, Some(b)) => {
            // SmoothQuant's activation division is already folded into the
            // LN affines above; per-token quantization runs on the smoothed
            // activations (Xiao et al. §4).
            Box::new(QuantSite::new(PerToken::new(b)))
        }
        (Method::CrossQuant { alpha }, Some(b)) | (Method::CrossQuantAwq { alpha }, Some(b)) => {
            Box::new(QuantSite::new(CrossQuant::new(alpha, b)))
        }
        (Method::OmniQuant, Some(b)) => {
            let _ = calib; // (element-wise search is too weak at W4A4)
            // OmniQuant learns its clipping end-to-end; the grid-search
            // equivalent minimises calibration-stream NLL over γ, which is
            // the block-loss objective without SGD (DESIGN.md §7).
            let model = NativeModel::new(w.clone());
            let mut gen =
                crate::corpus::CorpusGen::new(cfg.vocab, opts.seed ^ 0x0421);
            let calib_seq: Vec<Vec<u32>> =
                (0..opts.calib_sequences.max(1)).map(|_| gen.sequence(cfg.seq_len)).collect();
            let mut best = (f64::INFINITY, 1.0f32);
            for step in 3..=10 {
                let gamma = step as f32 / 10.0;
                let mut site = QuantSite::new(ClippedPerToken::new(b, gamma));
                let mut nll_sum = 0.0f64;
                for seq in &calib_seq {
                    nll_sum += model
                        .forward_nll(seq, &mut site)?
                        .iter()
                        .map(|&v| v as f64)
                        .sum::<f64>();
                }
                if nll_sum < best.0 {
                    best = (nll_sum, gamma);
                }
            }
            Box::new(QuantSite::new(ClippedPerToken::new(b, best.1)))
        }
    };

    Ok(PreparedEval { model: NativeModel::new(w), site })
}

/// Perplexity of one prepared cell.
pub fn run_ppl(
    prepared: &mut PreparedEval,
    kind: CorpusKind,
    opts: &ExpOpts,
) -> Result<PerplexityResult> {
    perplexity_native(
        &prepared.model,
        prepared.site.as_mut(),
        kind,
        opts.eval_sequences,
        opts.seed ^ 0xE7A1,
    )
}

/// Zero-shot suite average of one prepared cell.
pub fn run_tasks(
    prepared: &mut PreparedEval,
    opts: &ExpOpts,
) -> Result<(Vec<(String, crate::eval::tasks::TaskResult)>, f64)> {
    let suite = TaskSuite::standard(opts.task_instances, opts.seed ^ 0x7A5C);
    suite.evaluate(&prepared.model, prepared.site.as_mut())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::synthetic_weights as test_weights;

    fn small_base() -> Weights {
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 24,
            eval_batch: 2,
        };
        test_weights(cfg, 77)
    }

    fn small_opts() -> ExpOpts {
        ExpOpts { eval_sequences: 2, task_instances: 4, calib_sequences: 1, seed: 3 }
    }

    #[test]
    fn every_method_prepares_and_runs() {
        let base = small_base();
        let profile = FamilyProfile::by_name("opt-6.7b").unwrap();
        let opts = small_opts();
        for method in [
            Method::Fp16,
            Method::PerToken,
            Method::SmoothQuant,
            Method::CrossQuant { alpha: 0.15 },
            Method::Awq,
            Method::CrossQuantAwq { alpha: 0.15 },
            Method::OmniQuant,
        ] {
            let setting = if method == Method::Fp16 { Setting::fp() } else { Setting::w8a8() };
            let mut prep = prepare(&base, &profile, method, setting, &opts).unwrap();
            let r = run_ppl(&mut prep, CorpusKind::Wiki2, &opts).unwrap();
            assert!(r.perplexity.is_finite(), "{method:?}");
        }
    }

    #[test]
    fn setting_labels() {
        assert_eq!(Setting::w8a8().label(), "W8A8");
        assert_eq!(Setting::w4a8_g128().label(), "W4-g128A8");
        assert_eq!(Setting::w4a4().label(), "W4A4");
        assert_eq!(Setting::fp().label(), "W16A16");
    }

    #[test]
    fn site_consumer_map() {
        assert_eq!(site_consumers(2, 0).len(), 3);
        assert_eq!(site_consumers(2, 1), vec!["layer0.wo"]);
        assert_eq!(site_consumers(2, 6), vec!["layer1.w1"]);
        assert_eq!(site_consumers(2, 8), vec!["w_out"]);
    }

    #[test]
    fn ln_sites() {
        assert_eq!(ln_site_name(2, 0).as_deref(), Some("layer0.ln1_g"));
        assert_eq!(ln_site_name(2, 1), None);
        assert_eq!(ln_site_name(2, 2).as_deref(), Some("layer0.ln2_g"));
        assert_eq!(ln_site_name(2, 8).as_deref(), Some("lnf_g"));
    }
}
