//! Registry sweep — a Table-1-style panel over *every* served scheme:
//! the CrossQuant kernel fraction its activation grid exhibits and the
//! mean NLL it serves on a fixed synthetic stream. The FP and dynamic
//! rows run the native forward with their activation site; the static
//! rows (crossquant-static / smoothquant / awq / gptq / lorc) are built
//! through the registry's one pipeline
//! ([`crate::quant::registry::build_static_model`]) — the same models
//! the coordinator serves, so this table is the eval-side conformance
//! view of the registry.

use anyhow::Result;

use super::common::ExpOpts;
use crate::corpus::CorpusGen;
use crate::eval::harness::{Row, Table};
use crate::model::weights::Weights;
use crate::model::{IdentitySite, NativeModel, QuantSite};
use crate::quant::crossquant::CrossQuant;
use crate::quant::registry::{self, effective_alpha, SchemeId, StaticSpec};
use crate::quant::Bits;

/// LoRC correction rank used by the sweep (and `repro quantize` default).
pub const DEFAULT_RANK: usize = 8;

/// Every scheme with a runtime serving path: the FP reference, the two
/// dynamic quantizers, and the five registry-built static schemes.
pub fn served_schemes() -> Vec<SchemeId> {
    vec![
        SchemeId::Fp,
        SchemeId::PerToken,
        SchemeId::CrossQuant,
        SchemeId::CrossQuantStatic,
        SchemeId::SmoothQuant,
        SchemeId::Awq,
        SchemeId::Gptq,
        SchemeId::Lorc,
    ]
}

pub fn run(base: &Weights, opts: &ExpOpts) -> Result<Table> {
    let cfg = base.config;
    let alpha = 0.15f32;
    let mut table = Table::new(
        "Scheme registry — kernel fraction and served NLL per scheme (synthetic stream)",
        vec!["Kernel %", "NLL"],
    )
    .decimals(3);

    let mut egen = CorpusGen::new(cfg.vocab, opts.seed ^ 0xE7A1);
    let eval: Vec<Vec<u32>> =
        (0..opts.eval_sequences.max(1)).map(|_| egen.sequence(cfg.seq_len)).collect();
    let mut cgen = CorpusGen::new(cfg.vocab, opts.seed ^ 0x5CA1E);
    let calib: Vec<Vec<u32>> =
        (0..opts.calib_sequences.max(1)).map(|_| cgen.sequence(cfg.seq_len)).collect();
    let native = NativeModel::new(base.clone());

    // mean NLL + kernel fraction of one dynamic (native-forward) run
    let dynamic = |site_alpha: f32| -> Result<(f64, f64)> {
        let mut site = QuantSite::new(CrossQuant::new(site_alpha, Bits::Int8));
        let (mut total, mut count) = (0.0f64, 0usize);
        for seq in &eval {
            let nll = native.forward_nll(seq, &mut site)?;
            total += nll.iter().map(|&v| v as f64).sum::<f64>();
            count += nll.len();
        }
        Ok((total / count.max(1) as f64, site.kernel_fraction() as f64))
    };

    for id in served_schemes() {
        let (setting, kernel, nll) = match id {
            SchemeId::Fp => {
                let mut site = IdentitySite;
                let (mut total, mut count) = (0.0f64, 0usize);
                for seq in &eval {
                    let nll = native.forward_nll(seq, &mut site)?;
                    total += nll.iter().map(|&v| v as f64).sum::<f64>();
                    count += nll.len();
                }
                ("W16A16", f64::NAN, total / count.max(1) as f64)
            }
            SchemeId::PerToken | SchemeId::CrossQuant => {
                let (nll, kernel) = dynamic(effective_alpha(id, alpha))?;
                ("W16A8", kernel, nll)
            }
            _ => {
                // static rows: the registry-built integer model serves the
                // NLL; the kernel fraction is measured on the dynamic grid
                // the static fold approximates (same α, same Bits)
                let rank = if id == SchemeId::Lorc { DEFAULT_RANK } else { 0 };
                let spec = StaticSpec::new(id, alpha, rank);
                let qm =
                    registry::build_static_model(base, Bits::Int8, Bits::Int8, &spec, &calib)?;
                let (mut total, mut count) = (0.0f64, 0usize);
                for seq in &eval {
                    let nll = qm.forward_nll(seq)?;
                    total += nll.iter().map(|&v| v as f64).sum::<f64>();
                    count += nll.len();
                }
                let (_, kernel) = dynamic(effective_alpha(id, alpha))?;
                ("W8A8", kernel, total / count.max(1) as f64)
            }
        };
        let label = match id {
            SchemeId::Lorc => format!("{} (r={DEFAULT_RANK})", id.name()),
            _ => id.name().to_string(),
        };
        table.push(Row::new(label, setting, vec![kernel * 100.0, nll]));
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::synthetic_weights;

    #[test]
    fn sweep_covers_every_served_scheme_with_finite_nll() {
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            eval_batch: 2,
        };
        let base = synthetic_weights(cfg, 11);
        let opts = ExpOpts { eval_sequences: 2, task_instances: 1, calib_sequences: 2, seed: 5 };
        let table = run(&base, &opts).unwrap();
        assert_eq!(table.rows.len(), served_schemes().len());
        for row in &table.rows {
            let nll = row.cells[1];
            assert!(nll.is_finite(), "{}: NLL {nll}", row.method);
        }
        // the FP row has no quantization kernel; every quantized row does
        assert!(table.rows[0].cells[0].is_nan());
        assert!(table.rows[1..].iter().all(|r| r.cells[0].is_finite()));
    }
}
