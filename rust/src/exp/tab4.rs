//! Table 4: the α ablation on LLaMA3-8B and 3-70B — Wiki2 perplexity and
//! 5-shot MMLU accuracy at W8A8, for CrossQuant α ∈ {0.15, 0.45, 0.75}
//! against FP16 / Per-token / SmoothQuant.
//!
//! Appendix B.1 corner: for LLaMA3-70B W8A8 the paper applies CrossQuant
//! to weights too with α_W = 0 (per-channel weight kernels hurt at 70B).

use anyhow::Result;

use super::common::{prepare, run_ppl, ExpOpts, Method, Setting};
use crate::activations::FamilyProfile;
use crate::corpus::CorpusKind;
use crate::eval::harness::{Row, Table};
use crate::eval::tasks::Task;
use crate::model::quantized::WeightScheme;
use crate::model::weights::Weights;
use crate::quant::Bits;

pub const MODELS: [&str; 2] = ["llama3-8b", "llama3-70b"];

pub fn run(base: &Weights, opts: &ExpOpts) -> Result<Table> {
    let profiles: Vec<FamilyProfile> =
        MODELS.iter().map(|n| FamilyProfile::by_name(n).expect("profile")).collect();
    let mut columns = Vec::new();
    for p in &profiles {
        columns.push(format!("{} Wiki2", p.name));
        columns.push(format!("{} MMLU%", p.name));
    }
    let mut table = Table::new(
        "Table 4 — α ablation, LLaMA3-8B / 3-70B (W8A8)",
        columns.iter().map(|s| s.as_str()).collect(),
    );

    let rows: Vec<(Method, Setting)> = vec![
        (Method::Fp16, Setting::fp()),
        (Method::PerToken, Setting::w8a8()),
        (Method::SmoothQuant, Setting::w8a8()),
        (Method::CrossQuant { alpha: 0.15 }, Setting::w8a8()),
        (Method::CrossQuant { alpha: 0.45 }, Setting::w8a8()),
        (Method::CrossQuant { alpha: 0.75 }, Setting::w8a8()),
    ];

    for (method, setting) in rows {
        let mut cells = Vec::new();
        for p in &profiles {
            let mut s = setting;
            if p.name == "llama3-70b" && matches!(method, Method::CrossQuant { .. }) {
                s.weight = WeightScheme::CrossQuant(Bits::Int8, 0.0);
            }
            let mut prep = prepare(base, p, method, s, opts)?;
            cells.push(run_ppl(&mut prep, CorpusKind::Wiki2, opts)?.perplexity);
            let mut prep = prepare(base, p, method, s, opts)?;
            let mmlu = Task::mmlu_five_shot().evaluate(
                &prep.model,
                prep.site.as_mut(),
                opts.task_instances,
                opts.seed ^ 0x4444,
            )?;
            cells.push(mmlu.accuracy * 100.0);
        }
        table.push(Row::new(method.label(), setting.label(), cells));
    }
    Ok(table)
}
