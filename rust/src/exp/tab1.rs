//! Table 1: the cross-scale statistics on OPT-13B activations as α varies —
//! %(c_j ≥ t_i), %(B̃ < B), the CrossQuant kernel proportion, and the
//! resulting W8A8 perplexity (α = 1 is per-token, whose ppl explodes).

use anyhow::Result;

use super::common::{prepare, run_ppl, ExpOpts, Method, Setting};
use crate::activations::{ActivationGen, FamilyProfile};
use crate::analysis::CrossStats;
use crate::corpus::CorpusKind;
use crate::eval::harness::{Row, Table};
use crate::model::weights::Weights;
use crate::quant::Bits;

pub const ALPHAS: [f32; 4] = [0.15, 0.45, 0.75, 1.0];

pub fn run(base: &Weights, opts: &ExpOpts) -> Result<Table> {
    let profile = FamilyProfile::by_name("opt-13b").expect("profile");
    let columns: Vec<String> = ALPHAS.iter().map(|a| format!("α={a}")).collect();
    let mut table = Table::new(
        "Table 1 — cross-scale statistics, OPT-13B activations (WikiText2)",
        columns.iter().map(|s| s.as_str()).collect(),
    )
    .decimals(3);

    // statistics measured on profile-matched activation matrices
    let mut gen = ActivationGen::new(profile.clone(), opts.seed);
    let x = gen.matrix(1024, 512);
    let stats: Vec<CrossStats> =
        ALPHAS.iter().map(|&a| CrossStats::compute(&x, a, Bits::Int8)).collect();

    table.push(Row::new(
        "c_j ≥ t_i",
        "%",
        stats.iter().map(|s| s.frac_col_ge_row as f64 * 100.0).collect(),
    ));
    table.push(Row::new(
        "B̃ < B",
        "%",
        stats
            .iter()
            .map(|s| if s.alpha < 1.0 { s.frac_bound_smaller as f64 * 100.0 } else { f64::NAN })
            .collect(),
    ));
    table.push(Row::new(
        "Quantization kernel",
        "%",
        stats.iter().map(|s| s.kernel_fraction as f64 * 100.0).collect(),
    ));

    // W8A8 perplexity on the injected model per α
    let mut ppls = Vec::new();
    for &alpha in &ALPHAS {
        let mut prep =
            prepare(base, &profile, Method::CrossQuant { alpha }, Setting::w8a8(), opts)?;
        ppls.push(run_ppl(&mut prep, CorpusKind::Wiki2, opts)?.perplexity);
    }
    table.push(Row::new("W8A8 perplexity", "ppl", ppls));
    Ok(table)
}
