//! Weight-space transforms applied to the loaded flat weights before they
//! are fed to either the native forward pass or the AOT HLOs.
//!
//! * [`quantize_weights`] — fake-quantize every linear weight (per-channel,
//!   group-wise, or CrossQuant-on-weights per Appendix B.1);
//! * [`inject_profile`] — function-preserving outlier injection that makes
//!   the tiny LM's activations exhibit a [`FamilyProfile`]'s statistics
//!   (LayerNorm gains scaled up on the profile's outlier channels, the
//!   consuming linear rows scaled down by the same factor);
//! * [`apply_smoothquant`] — fold calibrated SmoothQuant scales into the
//!   ln gains and consuming weights (the standard deployment trick: the
//!   per-channel division of activations is absorbed by the preceding
//!   LayerNorm's affine, so the runtime graph is unchanged);
//! * [`quantize_to_artifact`] — the calibrate-once deployment pipeline:
//!   FP weights → static-scale CrossQuant calibration → persisted `.cqa`
//!   artifact (`quant::artifact`), the unit `repro quantize` ships and
//!   `repro serve --artifact` boots from.

use std::path::Path;

use anyhow::Result;

use super::weights::Weights;
use crate::quant::registry::{self, StaticSpec};
use crate::quant::{
    crossquant::CrossQuant, per_channel::GroupWise, per_channel::PerChannel, ActQuantizer, Bits,
};
use crate::activations::FamilyProfile;

/// Which weight quantizer to apply to the linear weights.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightScheme {
    /// FP16/FP32 — leave untouched (the paper's "W16").
    None,
    /// Per-output-channel, eq. (2) — the paper's default for CrossQuant rows.
    PerChannel(Bits),
    /// Group-wise with group size g (the W4-g128 rows).
    GroupWise(Bits, usize),
    /// CrossQuant applied to weights with exponent α_W (Appendix B.1).
    CrossQuant(Bits, f32),
}

impl WeightScheme {
    pub fn label(&self) -> String {
        match self {
            WeightScheme::None => "W16".into(),
            WeightScheme::PerChannel(Bits::Int8) => "W8".into(),
            WeightScheme::PerChannel(Bits::Int4) => "W4".into(),
            WeightScheme::PerChannel(b) => format!("W{b}"),
            WeightScheme::GroupWise(Bits::Int4, g) => format!("W4-g{g}"),
            WeightScheme::GroupWise(b, g) => format!("W{b}-g{g}"),
            WeightScheme::CrossQuant(Bits::Int8, a) => format!("W8-cq(α={a})"),
            WeightScheme::CrossQuant(Bits::Int4, a) => format!("W4-cq(α={a})"),
            WeightScheme::CrossQuant(b, a) => format!("W{b}-cq(α={a})"),
        }
    }
}

/// Fake-quantize all linear weights in place.
pub fn quantize_weights(w: &mut Weights, scheme: WeightScheme) -> Result<()> {
    let names = w.linear_names();
    for name in names {
        let m = w.get(&name)?;
        let q = match scheme {
            WeightScheme::None => continue,
            WeightScheme::PerChannel(bits) => PerChannel::new(bits).fake_quant(&m),
            WeightScheme::GroupWise(bits, g) => GroupWise::new(bits, g).fake_quant(&m),
            WeightScheme::CrossQuant(bits, alpha) => {
                CrossQuant::weight_mode(alpha, bits).fake_quant(&m)
            }
        };
        w.set(&name, &q)?;
    }
    Ok(())
}

/// What [`quantize_to_artifact`] produced, for reporting (`repro
/// quantize` prints it; benches log it).
#[derive(Clone, Debug)]
pub struct ArtifactBuildReport {
    pub alpha: f32,
    pub weight_bits: Bits,
    pub calib_sequences: usize,
    /// Bytes of the FP32 flat checkpoint the artifact replaces.
    pub fp_bytes: usize,
    /// Bytes of the written `.cqa` file (header + table + payloads).
    pub artifact_bytes: usize,
    pub sections: usize,
}

impl ArtifactBuildReport {
    /// Shipped-bytes compression vs the FP32 checkpoint.
    pub fn compression_ratio(&self) -> f64 {
        self.fp_bytes as f64 / self.artifact_bytes.max(1) as f64
    }
}

/// The calibrate-once deployment pipeline: build the calibrated integer
/// model for any registered static scheme
/// ([`registry::build_static_model`] — plain crossquant-static,
/// smoothquant/awq folds, gptq rounding, lorc correction) and persist
/// the `.cqa` artifact at `path`, scheme ID stamped in the header.
/// Serving then boots from the artifact alone —
/// `QuantizedModel::load_artifact` — without FP weights or calibration.
pub fn quantize_to_artifact(
    weights: &Weights,
    weight_bits: Bits,
    act_bits: Bits,
    spec: &StaticSpec,
    calib: &[Vec<u32>],
    path: &Path,
) -> Result<ArtifactBuildReport> {
    let t0 = std::time::Instant::now();
    let qm = registry::build_static_model(weights, weight_bits, act_bits, spec, calib)?;
    let sections = qm.write_artifact(path)?;
    let artifact_bytes = std::fs::metadata(path)?.len() as usize;
    crate::obs::log::info(
        "artifact",
        "quantized model artifact written",
        &[
            ("path", path.display().to_string()),
            ("scheme", spec.id.to_string()),
            ("bytes", artifact_bytes.to_string()),
            ("calib_sequences", calib.len().to_string()),
            ("build_ms", t0.elapsed().as_millis().to_string()),
        ],
    );
    Ok(ArtifactBuildReport {
        alpha: registry::effective_alpha(spec.id, spec.alpha),
        weight_bits,
        calib_sequences: calib.len(),
        fp_bytes: weights.flat.len() * 4,
        artifact_bytes,
        sections,
    })
}

/// Inject a family profile's outlier channels into the model,
/// function-preservingly:
///
/// for each layer, scale `outlier_channels` entries of ln1_g/ln2_g (and the
/// matching ln_b entries) by `outlier_scale`, and divide the corresponding
/// *rows* of the consuming linear weights (wq/wk/wv for ln1, w1 for ln2) by
/// the same factor. Post-LN activations then carry systematic outlier
/// channels — exactly the OPT phenomenon — while the FP forward function is
/// unchanged (quantizers, of course, see the difference).
pub fn inject_profile(w: &mut Weights, profile: &FamilyProfile) -> Result<()> {
    if profile.outlier_channels == 0 || profile.outlier_scale <= 1.0 {
        return Ok(());
    }
    let cfg = w.config;
    let d = cfg.d_model;
    // The tiny LM spreads each site's information across far fewer channels
    // than a 7B–70B model, so matching the paper's *measured* kernel
    // regimes (Figure 4) requires a denser, stronger injection than the raw
    // profile statistics — calibrated via `repro analyze` (DESIGN.md §4).
    let n_out = (profile.outlier_channels * 3).clamp(1, d / 8);
    let channels: Vec<usize> =
        (0..n_out).map(|k| (k * d) / n_out.max(1) + d / (2 * n_out.max(1))).collect();
    let s = profile.outlier_scale * 2.0;

    for l in 0..cfg.n_layers {
        // LayerNorm-fed sites: scale the LN affine, compensate consumers.
        for (ln, consumers) in [
            (format!("layer{l}.ln1_g"), vec![format!("layer{l}.wq"), format!("layer{l}.wk"), format!("layer{l}.wv")]),
            (format!("layer{l}.ln2_g"), vec![format!("layer{l}.w1")]),
        ] {
            scale_ln_site(w, &ln, &consumers, &channels, s)?;
        }
        // Attention-context site: scale wv output channels (the context is
        // linear in V), divide the matching wo rows — function-preserving,
        // and it puts outliers into the ctx quantization site too.
        let mut wv = w.get(&format!("layer{l}.wv"))?;
        let mut wo = w.get(&format!("layer{l}.wo"))?;
        for &c in &channels {
            for r in 0..wv.rows {
                let v = wv.get(r, c);
                wv.set(r, c, v * s);
            }
            for v in wo.row_mut(c) {
                *v /= s;
            }
        }
        w.set(&format!("layer{l}.wv"), &wv)?;
        w.set(&format!("layer{l}.wo"), &wo)?;
    }
    // Final LN site feeding the output head.
    scale_ln_site(w, "lnf_g", &["w_out".to_string()], &channels, s)?;
    Ok(())
}

fn scale_ln_site(
    w: &mut Weights,
    ln: &str,
    consumers: &[String],
    channels: &[usize],
    s: f32,
) -> Result<()> {
    let mut g = w.get(ln)?;
    let mut b = w.get(&ln.replace("_g", "_b"))?;
    for &c in channels {
        g.set(0, c, g.get(0, c) * s);
        b.set(0, c, b.get(0, c) * s);
    }
    w.set(ln, &g)?;
    w.set(&ln.replace("_g", "_b"), &b)?;
    for cons in consumers {
        let mut m = w.get(cons)?;
        for &c in channels {
            for v in m.row_mut(c) {
                *v /= s;
            }
        }
        w.set(cons, &m)?;
    }
    Ok(())
}

/// Fold SmoothQuant smoothing scales (one vector per smoothable site) into
/// the LN affine feeding the site and the consuming weight rows. Only the
/// LN-fed sites (ln1 → wq/wk/wv, ln2 → w1, lnf → w_out) are smoothable —
/// matching SmoothQuant's deployment, which smooths exactly the
/// LayerNorm-to-linear edges.
pub fn apply_smoothquant(w: &mut Weights, site_scales: &[(String, Vec<f32>)]) -> Result<()> {
    for (ln_name, scales) in site_scales {
        let consumers: Vec<String> = if ln_name.contains("ln1") {
            let l = ln_name.trim_start_matches("layer").split('.').next().unwrap();
            vec![format!("layer{l}.wq"), format!("layer{l}.wk"), format!("layer{l}.wv")]
        } else if ln_name.contains("ln2") {
            let l = ln_name.trim_start_matches("layer").split('.').next().unwrap();
            vec![format!("layer{l}.w1")]
        } else {
            vec!["w_out".to_string()]
        };
        let mut g = w.get(ln_name)?;
        let mut b = w.get(&ln_name.replace("_g", "_b"))?;
        for (c, &s) in scales.iter().enumerate() {
            g.set(0, c, g.get(0, c) / s);
            b.set(0, c, b.get(0, c) / s);
        }
        w.set(ln_name, &g)?;
        w.set(&ln_name.replace("_g", "_b"), &b)?;
        for cons in consumers {
            let mut m = w.get(&cons)?;
            for (c, &s) in scales.iter().enumerate() {
                for v in m.row_mut(c) {
                    *v *= s;
                }
            }
            w.set(&cons, &m)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::synthetic_weights as test_weights;

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 8,
            eval_batch: 2,
        }
    }

    #[test]
    fn quantize_weights_changes_linears_only() {
        let mut w = test_weights(cfg(), 3);
        let emb_before = w.get("tok_emb").unwrap();
        let wq_before = w.get("layer0.wq").unwrap();
        quantize_weights(&mut w, WeightScheme::PerChannel(Bits::Int4)).unwrap();
        assert_eq!(w.get("tok_emb").unwrap(), emb_before);
        assert_ne!(w.get("layer0.wq").unwrap(), wq_before);
    }

    #[test]
    fn w8_error_smaller_than_w4() {
        let base = test_weights(cfg(), 4);
        let err = |scheme| {
            let mut w = base.clone();
            quantize_weights(&mut w, scheme).unwrap();
            base.flat
                .iter()
                .zip(&w.flat)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum::<f64>()
                .sqrt()
        };
        assert!(
            err(WeightScheme::PerChannel(Bits::Int8)) < err(WeightScheme::PerChannel(Bits::Int4))
        );
    }

    #[test]
    fn inject_profile_scales_gains() {
        let mut w = test_weights(cfg(), 5);
        let g_before = w.get("layer0.ln1_g").unwrap();
        let prof = FamilyProfile::new("test", crate::activations::Family::Opt, 13.0, 2, 50.0, 0.14, 0.0, 0.02, 0.0);
        inject_profile(&mut w, &prof).unwrap();
        let g_after = w.get("layer0.ln1_g").unwrap();
        let grown = (0..16).filter(|&c| g_after.get(0, c) > g_before.get(0, c) * 10.0).count();
        assert_eq!(grown, 2);
    }

    #[test]
    fn smoothquant_fold_shapes() {
        let mut w = test_weights(cfg(), 6);
        let scales = vec![(String::from("layer0.ln1_g"), vec![2.0f32; 16])];
        apply_smoothquant(&mut w, &scales).unwrap();
        // gains divided by 2, consuming rows multiplied by 2
        assert!((w.get("layer0.ln1_g").unwrap().get(0, 0) - 0.5).abs() < 1e-6);
    }
}
