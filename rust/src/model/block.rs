//! The shared incremental transformer core.
//!
//! Before this module, `model/forward.rs` (FP + fake-quant ActSite paths)
//! and `model/qforward.rs` (true-integer W8A8) each carried a verbatim
//! copy of `layer_norm` / `causal_attention` / `gelu` and the pre-LN block
//! loop. Both now drive the single implementation here, generic over the
//! linear operator (`Matrix` for the native model, `QuantizedLinear` for
//! the integer model), so the transformer math is defined exactly once.
//!
//! The second job of this module is *incremental* decode: [`LayerKvCache`]
//! holds one layer's K/V prefix, [`DecodeState`] holds the whole stack's,
//! and [`attention_with_prefix`] runs causal attention for new rows at
//! absolute positions `offset..offset+t` over the cached prefix plus the
//! new rows. Full-sequence prefill is the `offset == 0` special case, so
//! scoring and generation share one attention kernel — and per-token
//! decode costs O(S·d) per layer instead of the O(S²·d) a full recompute
//! pays.
//!
//! All row-level math is identical to the pre-refactor implementations
//! (same loop bodies, same fold order), which keeps the FP path bit-exact
//! — pinned by rust/tests/decode.rs.

use anyhow::Result;

use super::config::ModelConfig;
use crate::tensor::{par, Matrix};

/// Per-layer K/V prefix for incremental decode. Capacity is allocated up
/// front (`n_ctx` rows), so appends never reallocate mid-generation.
pub struct LayerKvCache {
    k: Matrix,
    v: Matrix,
    len: usize,
}

impl LayerKvCache {
    pub fn new(capacity: usize, d_model: usize) -> LayerKvCache {
        LayerKvCache {
            k: Matrix::zeros(capacity, d_model),
            v: Matrix::zeros(capacity, d_model),
            len: 0,
        }
    }

    /// Cached prefix length in tokens.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum prefix length (the model's context window).
    pub fn capacity(&self) -> usize {
        self.k.rows
    }

    /// Bytes held by this layer's cache (K + V, capacity rows — the
    /// allocation is up-front, so this is also the peak).
    pub fn memory_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Append `t` new K/V rows (one per new token).
    fn append(&mut self, k_new: &Matrix, v_new: &Matrix) {
        debug_assert_eq!(k_new.rows, v_new.rows);
        debug_assert_eq!(k_new.cols, self.k.cols);
        assert!(self.len + k_new.rows <= self.k.rows, "KV cache overflow");
        for i in 0..k_new.rows {
            self.append_row(k_new.row(i), v_new.row(i));
        }
    }

    /// Append one K/V row without materialising a 1-row [`Matrix`] — the
    /// per-sequence path of the batched decode step.
    fn append_row(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert!(self.len < self.k.rows, "KV cache overflow");
        self.k.row_mut(self.len).copy_from_slice(k_row);
        self.v.row_mut(self.len).copy_from_slice(v_row);
        self.len += 1;
    }
}

/// The whole stack's decode state: one [`LayerKvCache`] per layer plus the
/// number of tokens consumed so far. Create via
/// `NativeModel::new_decode_state` / `QuantizedModel::new_decode_state`
/// (or [`DecodeState::new`] directly), feed it through
/// `forward_incremental`, and positions advance automatically.
pub struct DecodeState {
    layers: Vec<LayerKvCache>,
    len: usize,
}

impl DecodeState {
    pub fn new(n_layers: usize, n_ctx: usize, d_model: usize) -> DecodeState {
        DecodeState {
            layers: (0..n_layers).map(|_| LayerKvCache::new(n_ctx, d_model)).collect(),
            len: 0,
        }
    }

    /// Tokens consumed so far (the next token's absolute position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Context-window capacity shared by every layer cache.
    pub fn capacity(&self) -> usize {
        self.layers.first().map_or(0, |l| l.capacity())
    }

    /// Tokens that can still be appended before the window is full.
    pub fn remaining(&self) -> usize {
        self.capacity() - self.len
    }

    /// Total KV-cache bytes across all layers
    /// (= 2 · n_layers · n_ctx · d_model · 4 bytes).
    pub fn memory_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.memory_bytes()).sum()
    }

    /// [`DecodeState::memory_bytes`] of a state with this shape, without
    /// allocating one — pool sizing arithmetic.
    pub fn memory_bytes_for(n_layers: usize, n_ctx: usize, d_model: usize) -> usize {
        2 * n_layers * n_ctx * d_model * std::mem::size_of::<f32>()
    }

    fn advance(&mut self, t: usize) {
        self.len += t;
        debug_assert!(self.layers.iter().all(|l| l.len() == self.len));
    }

    /// Clear back to an empty prefix. Capacity and allocations are
    /// retained — this is the KV-pool reuse path: a released slot is reset
    /// and leased to the next sequence without touching the allocator.
    pub fn reset(&mut self) {
        self.len = 0;
        for l in &mut self.layers {
            l.len = 0;
        }
    }
}

/// One transformer layer's parameters, generic over the linear operator
/// `L` (`Matrix` on the native path, `QuantizedLinear` on the integer
/// path).
pub struct LayerView<'a, L> {
    pub ln1_g: &'a Matrix,
    pub ln1_b: &'a Matrix,
    pub wq: &'a L,
    pub wk: &'a L,
    pub wv: &'a L,
    pub wo: &'a L,
    pub ln2_g: &'a Matrix,
    pub ln2_b: &'a Matrix,
    pub w1: &'a L,
    pub w2: &'a L,
}

/// A borrowed view of a full model, consumed by [`forward_pass`]. Building
/// one is a per-call Vec of references — cheap next to a single matmul.
pub struct ModelView<'a, L> {
    pub config: ModelConfig,
    pub tok_emb: &'a Matrix,
    pub pos_emb: &'a Matrix,
    pub layers: Vec<LayerView<'a, L>>,
    pub lnf_g: &'a Matrix,
    pub lnf_b: &'a Matrix,
    pub w_out: &'a L,
}

/// The single forward driver behind both models, both stateless scoring
/// and KV-cached decode.
///
/// * `state: None` — stateless full-sequence forward (prefill semantics,
///   nothing retained).
/// * `state: Some(s)` — incremental step: `tokens` are appended at
///   absolute positions `s.len()..`, each layer's K/V rows land in the
///   cache, and only the new rows' logits come back.
///
/// `last_logits_only` slices the final hidden state to its last row
/// before the head (greedy generation reads nothing else — the K/V rows
/// of every position are already cached by then, so per-row values are
/// unchanged and the head matmul drops from O(t·d·vocab) to
/// O(d·vocab) during prefill). Scoring passes `false`.
///
/// `matmul` applies a linear operator; `site` is the activation-site hook
/// (fake-quant transform on the native path, calibration observer or
/// identity on the integer path), called with the global site index in
/// forward order — site numbering is identical in both modes, so per-site
/// calibrated transforms work unchanged under decode.
pub fn forward_pass<L>(
    view: &ModelView<'_, L>,
    tokens: &[u32],
    mut state: Option<&mut DecodeState>,
    last_logits_only: bool,
    matmul: &mut dyn FnMut(&L, &Matrix) -> Matrix,
    site: &mut dyn FnMut(usize, Matrix) -> Matrix,
) -> Result<Matrix> {
    let cfg = view.config;
    let t = tokens.len();
    let offset = state.as_ref().map_or(0, |s| s.len());
    anyhow::ensure!(t >= 1, "forward needs at least one token");
    anyhow::ensure!(
        offset + t <= cfg.seq_len,
        "position {} exceeds model context {} (prefix {offset} + {t} new tokens)",
        offset + t,
        cfg.seq_len
    );
    anyhow::ensure!(
        tokens.iter().all(|&tok| (tok as usize) < cfg.vocab),
        "token id out of range (vocab {})",
        cfg.vocab
    );
    if let Some(s) = state.as_ref() {
        anyhow::ensure!(
            s.layers.len() == view.layers.len() && s.capacity() == cfg.seq_len,
            "decode state shape does not match the model"
        );
    }

    let d = cfg.d_model;
    let mut x = Matrix::zeros(t, d);
    for (i, &tok) in tokens.iter().enumerate() {
        for j in 0..d {
            x.set(i, j, view.tok_emb.get(tok as usize, j) + view.pos_emb.get(offset + i, j));
        }
    }

    let mut site_idx = 0usize;
    for (l, layer) in view.layers.iter().enumerate() {
        // --- attention block ---
        let h = layer_norm(&x, layer.ln1_g, layer.ln1_b);
        let hq = site(site_idx, h);
        site_idx += 1;
        let q = matmul(layer.wq, &hq);
        let k = matmul(layer.wk, &hq);
        let v = matmul(layer.wv, &hq);
        let ctx = match state.as_deref_mut() {
            Some(s) => {
                let cache = &mut s.layers[l];
                cache.append(&k, &v);
                attention_with_prefix(&q, &cache.k, &cache.v, offset, cfg.n_heads)
            }
            None => attention_with_prefix(&q, &k, &v, 0, cfg.n_heads),
        };
        let ctxq = site(site_idx, ctx);
        site_idx += 1;
        let attn_out = matmul(layer.wo, &ctxq);
        add_inplace(&mut x, &attn_out);

        // --- MLP block ---
        let h = layer_norm(&x, layer.ln2_g, layer.ln2_b);
        let hq = site(site_idx, h);
        site_idx += 1;
        let mut hh = matmul(layer.w1, &hq);
        gelu_inplace(&mut hh);
        let hhq = site(site_idx, hh);
        site_idx += 1;
        let mlp_out = matmul(layer.w2, &hhq);
        add_inplace(&mut x, &mlp_out);
    }
    if let Some(s) = state {
        s.advance(t);
    }

    let x = if last_logits_only && x.rows > 1 {
        Matrix::from_vec(1, d, x.row(t - 1).to_vec())
    } else {
        x
    };
    let h = layer_norm(&x, view.lnf_g, view.lnf_b);
    let hq = site(site_idx, h);
    Ok(matmul(view.w_out, &hq))
}

/// One continuous-batching decode step: row `i` of the batch is the next
/// token of an *independent* sequence whose KV prefix lives in
/// `states[i]`, so the linear operators run once at M=N while attention
/// runs per row over each sequence's own cache with its own prefix
/// length — the generalisation of [`attention_with_prefix`] to per-row
/// prefixes that the engine's step loop drives.
///
/// `row_site(row, site_idx, x)` is the activation-site hook applied to
/// each sequence's 1-row slice *separately*. This is deliberate: schemes
/// whose scale fields couple rows (dynamic CrossQuant's live column
/// maxima) see exactly the M=1 matrices they would see in a sequential
/// `generate_greedy`, and because every other op here is per-row
/// deterministic (LayerNorm statistics, the ascending-k matmul fold, the
/// exact i32 GEMM accumulation, element-wise GELU/residual), the batched
/// step is **bit-identical** to N independent M=1 steps — pinned by
/// rust/tests/engine.rs across every served scheme. Pass `None` when no
/// transform applies (FP, or the integer path that quantizes inside its
/// GEMMs) — the hot loop then skips the per-row split entirely.
///
/// Returns N × vocab logits (every row is that sequence's "last" row) and
/// advances each state by one position.
pub fn forward_step_batched<L>(
    view: &ModelView<'_, L>,
    tokens: &[u32],
    states: &mut [&mut DecodeState],
    matmul: &mut dyn FnMut(&L, &Matrix) -> Matrix,
    mut row_site: Option<&mut dyn FnMut(usize, usize, Matrix) -> Matrix>,
) -> Result<Matrix> {
    let cfg = view.config;
    let n = tokens.len();
    anyhow::ensure!(n >= 1, "batched step needs at least one sequence");
    anyhow::ensure!(states.len() == n, "tokens/states length mismatch ({n} vs {})", states.len());
    anyhow::ensure!(
        tokens.iter().all(|&tok| (tok as usize) < cfg.vocab),
        "token id out of range (vocab {})",
        cfg.vocab
    );
    for (i, s) in states.iter().enumerate() {
        anyhow::ensure!(
            s.layers.len() == view.layers.len() && s.capacity() == cfg.seq_len,
            "decode state {i} does not match the model"
        );
        anyhow::ensure!(
            s.len() < cfg.seq_len,
            "sequence {i}: position {} exceeds model context {}",
            s.len() + 1,
            cfg.seq_len
        );
    }

    let d = cfg.d_model;
    let mut x = Matrix::zeros(n, d);
    for (i, &tok) in tokens.iter().enumerate() {
        let pos = states[i].len();
        for j in 0..d {
            x.set(i, j, view.tok_emb.get(tok as usize, j) + view.pos_emb.get(pos, j));
        }
    }

    let mut site_idx = 0usize;
    for (l, layer) in view.layers.iter().enumerate() {
        // --- attention block ---
        let h = layer_norm(&x, layer.ln1_g, layer.ln1_b);
        let hq = apply_row_site(h, site_idx, &mut row_site);
        site_idx += 1;
        let q = matmul(layer.wq, &hq);
        let k = matmul(layer.wk, &hq);
        let v = matmul(layer.wv, &hq);
        let mut ctx = Matrix::zeros(n, d);
        for (i, state) in states.iter_mut().enumerate() {
            let offset = state.len();
            let cache = &mut state.layers[l];
            cache.append_row(k.row(i), v.row(i));
            let qi = Matrix::from_vec(1, d, q.row(i).to_vec());
            let c = attention_with_prefix(&qi, &cache.k, &cache.v, offset, cfg.n_heads);
            ctx.row_mut(i).copy_from_slice(c.row(0));
        }
        let ctxq = apply_row_site(ctx, site_idx, &mut row_site);
        site_idx += 1;
        let attn_out = matmul(layer.wo, &ctxq);
        add_inplace(&mut x, &attn_out);

        // --- MLP block ---
        let h = layer_norm(&x, layer.ln2_g, layer.ln2_b);
        let hq = apply_row_site(h, site_idx, &mut row_site);
        site_idx += 1;
        let mut hh = matmul(layer.w1, &hq);
        gelu_inplace(&mut hh);
        let hhq = apply_row_site(hh, site_idx, &mut row_site);
        site_idx += 1;
        let mlp_out = matmul(layer.w2, &hhq);
        add_inplace(&mut x, &mlp_out);
    }
    for s in states.iter_mut() {
        s.advance(1);
    }

    let h = layer_norm(&x, view.lnf_g, view.lnf_b);
    let hq = apply_row_site(h, site_idx, &mut row_site);
    Ok(matmul(view.w_out, &hq))
}

/// Apply the per-row site hook to every row of `x` independently (each
/// row belongs to a different sequence, so scale fields must never couple
/// them — see [`forward_step_batched`]). `None` is the identity: the
/// matrix passes through untouched, no per-row split or copy.
fn apply_row_site(
    x: Matrix,
    site_idx: usize,
    row_site: &mut Option<&mut dyn FnMut(usize, usize, Matrix) -> Matrix>,
) -> Matrix {
    let Some(f) = row_site else { return x };
    let (rows, cols) = (x.rows, x.cols);
    let mut out = Matrix::zeros(rows, cols);
    for i in 0..rows {
        let r = f(i, site_idx, Matrix::from_vec(1, cols, x.row(i).to_vec()));
        assert_eq!((r.rows, r.cols), (1, cols), "row site must preserve shape");
        out.row_mut(i).copy_from_slice(r.row(0));
    }
    out
}

/// The greedy autoregressive loop shared by both models (and, with a
/// timing wrapper, by `eval::generation`): validate the budget against
/// the context window, prefill the prompt, then decode one token per
/// step, argmaxing each step's last logits row. `step` runs one
/// incremental forward (its logits may be last-row-only).
pub fn generate_greedy_with(
    n_ctx: usize,
    prompt: &[u32],
    max_new_tokens: usize,
    state: &mut DecodeState,
    step: &mut dyn FnMut(&[u32], &mut DecodeState) -> Result<Matrix>,
) -> Result<Vec<u32>> {
    anyhow::ensure!(!prompt.is_empty(), "generation needs a non-empty prompt");
    anyhow::ensure!(max_new_tokens >= 1, "max_new_tokens must be >= 1");
    anyhow::ensure!(
        prompt.len() + max_new_tokens <= n_ctx,
        "prompt length {} + max_new_tokens {max_new_tokens} exceeds model context {n_ctx}",
        prompt.len(),
    );
    let logits = step(prompt, state)?;
    let mut next = argmax(logits.row(logits.rows - 1)) as u32;
    let mut out = Vec::with_capacity(max_new_tokens);
    out.push(next);
    while out.len() < max_new_tokens {
        let logits = step(&[next], state)?;
        next = argmax(logits.row(logits.rows - 1)) as u32;
        out.push(next);
    }
    Ok(out)
}

/// Row-parallel LayerNorm (eps 1e-5). Each row's statistics are
/// independent, so the per-row math — and hence the result — is identical
/// for any worker count.
pub fn layer_norm(x: &Matrix, g: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    if out.is_empty() {
        return out;
    }
    let n = x.cols as f32;
    let cols = x.cols;
    par::par_rows_mut(&mut out.data, cols, par::workers_for(x.rows, x.len()), |row0, chunk| {
        for (local, dst) in chunk.chunks_mut(cols).enumerate() {
            let row = x.row(row0 + local);
            let mu = row.iter().sum::<f32>() / n;
            let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for (j, (&v, o)) in row.iter().zip(dst.iter_mut()).enumerate() {
                *o = (v - mu) * inv * g.get(0, j) + b.get(0, j);
            }
        }
    });
    out
}

/// Causal softmax attention for `q` rows at absolute positions
/// `offset..offset+q.rows` over `keys`/`values` rows `0..offset+q.rows`
/// (the cached prefix plus the new rows; extra capacity rows beyond that
/// are ignored). `offset == 0` with `keys == k`, `values == v` is plain
/// full-sequence causal attention.
///
/// Row-parallel over query positions: output row `i` reads only q row `i`
/// and key/value rows `<= offset + i`, which every worker shares
/// immutably. Per-(row, head) math matches the serial loop exactly, for
/// any worker count.
pub fn attention_with_prefix(
    q: &Matrix,
    keys: &Matrix,
    values: &Matrix,
    offset: usize,
    n_heads: usize,
) -> Matrix {
    let t = q.rows;
    let d = q.cols;
    let total = offset + t;
    assert!(keys.rows >= total && values.rows >= total, "K/V shorter than attended prefix");
    assert_eq!(keys.cols, d, "K/V width mismatch");
    let mut out = Matrix::zeros(t, d);
    if out.is_empty() {
        return out;
    }
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    // triangular cost ~ t·total·d/2 (scores) + t·total·d/2 (weighted sum)
    let cost = t.saturating_mul(total).saturating_mul(d);
    par::par_rows_mut(&mut out.data, d, par::workers_for(t, cost), |row0, chunk| {
        let mut scores = vec![0.0f32; total];
        for (local, dst) in chunk.chunks_mut(d).enumerate() {
            let i = row0 + local;
            let pos = offset + i;
            for h in 0..n_heads {
                let off = h * hd;
                for (j, sc) in scores.iter_mut().enumerate().take(pos + 1) {
                    let mut dot = 0.0f32;
                    for a in 0..hd {
                        dot += q.get(i, off + a) * keys.get(j, off + a);
                    }
                    *sc = dot * scale;
                }
                let max = scores[..=pos].iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                let mut denom = 0.0f32;
                for sc in scores.iter_mut().take(pos + 1) {
                    *sc = (*sc - max).exp();
                    denom += *sc;
                }
                for a in 0..hd {
                    let mut acc = 0.0f32;
                    for (j, &sc) in scores.iter().enumerate().take(pos + 1) {
                        acc += sc * values.get(j, off + a);
                    }
                    dst[off + a] = acc / denom;
                }
            }
        }
    });
    out
}

/// Full-sequence causal attention — [`attention_with_prefix`] with an
/// empty prefix, kept as the named entry point the scoring paths use.
pub fn causal_attention(q: &Matrix, k: &Matrix, v: &Matrix, n_heads: usize) -> Matrix {
    attention_with_prefix(q, k, v, 0, n_heads)
}

/// jax.nn.gelu default (approximate=True): tanh approximation.
pub fn gelu_inplace(x: &mut Matrix) {
    const C: f32 = 0.7978845608; // sqrt(2/π)
    for v in x.data.iter_mut() {
        let u = *v;
        *v = 0.5 * u * (1.0 + (C * (u + 0.044715 * u * u * u)).tanh());
    }
}

/// Residual add.
pub fn add_inplace(x: &mut Matrix, y: &Matrix) {
    debug_assert_eq!(x.len(), y.len());
    for (a, b) in x.data.iter_mut().zip(&y.data) {
        *a += b;
    }
}

/// Per-position NLL against the shifted targets (len = tokens.len() − 1):
/// `logits` row `i` scores target `tokens[i + 1]`.
pub fn nll_from_logits(logits: &Matrix, tokens: &[u32]) -> Vec<f32> {
    debug_assert_eq!(logits.rows, tokens.len());
    let s = tokens.len();
    let mut nll = Vec::with_capacity(s.saturating_sub(1));
    for i in 0..s.saturating_sub(1) {
        let row = logits.row(i);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let logsum = max + row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln();
        nll.push(logsum - row[tokens[i + 1] as usize]);
    }
    nll
}

/// Log-softmax of one logits row (greedy-prediction tasks).
pub fn log_softmax(row: &[f32]) -> Vec<f32> {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let logsum = max + row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln();
    row.iter().map(|&v| v - logsum).collect()
}

/// Greedy argmax with `total_cmp` tie-breaking (last maximum wins) — the
/// one sampler both models' `generate_greedy` share, so cached and
/// full-recompute decodes can only diverge through the logits themselves.
pub fn argmax(row: &[f32]) -> usize {
    row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(i, _)| i).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    #[test]
    fn prefix_attention_matches_full_attention_rowwise() {
        let mut rng = SplitMix64::new(3);
        let s = 10;
        let d = 8;
        let q = Matrix::randn(s, d, 1.0, &mut rng);
        let k = Matrix::randn(s, d, 1.0, &mut rng);
        let v = Matrix::randn(s, d, 1.0, &mut rng);
        let full = causal_attention(&q, &k, &v, 2);
        // feed the same rows through a cache, one token at a time
        let mut cache = LayerKvCache::new(s, d);
        for i in 0..s {
            let qi = Matrix::from_vec(1, d, q.row(i).to_vec());
            let ki = Matrix::from_vec(1, d, k.row(i).to_vec());
            let vi = Matrix::from_vec(1, d, v.row(i).to_vec());
            cache.append(&ki, &vi);
            let step = attention_with_prefix(&qi, &cache.k, &cache.v, i, 2);
            assert_eq!(step.row(0), full.row(i), "row {i} must be bit-exact");
        }
        assert_eq!(cache.len(), s);
    }

    #[test]
    fn kv_cache_accounting() {
        let state = DecodeState::new(3, 16, 8);
        assert_eq!(state.capacity(), 16);
        assert_eq!(state.remaining(), 16);
        // 2 (K+V) · 3 layers · 16 ctx · 8 d_model · 4 bytes
        assert_eq!(state.memory_bytes(), 2 * 3 * 16 * 8 * 4);
        assert_eq!(DecodeState::memory_bytes_for(3, 16, 8), state.memory_bytes());
        assert!(state.is_empty());
    }

    #[test]
    #[should_panic(expected = "KV cache overflow")]
    fn kv_cache_overflow_panics() {
        let mut cache = LayerKvCache::new(2, 4);
        let rows = Matrix::zeros(3, 4);
        cache.append(&rows, &rows.clone());
    }

    #[test]
    fn reset_clears_lengths_but_keeps_capacity() {
        let mut state = DecodeState::new(2, 8, 4);
        let k = Matrix::zeros(3, 4);
        for l in &mut state.layers {
            l.append(&k, &k.clone());
        }
        state.advance(3);
        assert_eq!(state.len(), 3);
        state.reset();
        assert_eq!(state.len(), 0);
        assert_eq!(state.capacity(), 8);
        assert_eq!(state.remaining(), 8);
        assert!(state.layers.iter().all(|l| l.is_empty()));
        // memory accounting is about the arena, not the logical length
        assert_eq!(state.memory_bytes(), 2 * 2 * 8 * 4 * 4);
    }

    #[test]
    fn nll_and_log_softmax_agree() {
        let logits = Matrix::from_vec(2, 3, vec![0.1, 2.0, -1.0, 0.5, 0.5, 3.0]);
        let tokens = [0u32, 2, 1];
        let nll = nll_from_logits(&logits, &tokens);
        assert_eq!(nll.len(), 2);
        let lp0 = log_softmax(logits.row(0));
        assert!((nll[0] + lp0[2]).abs() < 1e-6);
    }

    #[test]
    fn argmax_total_order() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, -1.0]), 2); // last maximum
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
