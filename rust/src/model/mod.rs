//! The tiny-GPT model substrate on the rust side: manifest/weights loading,
//! weight-space transforms (quantization, outlier injection, smoothing),
//! and a native forward pass cross-checked against the PJRT artifacts.

pub mod config;
pub mod forward;
pub mod qforward;
pub mod quantized;
pub mod weights;

pub use config::ModelConfig;
pub use forward::{ActSite, IdentitySite, NativeModel, QuantSite, RemoveKernelSite};
pub use qforward::{QuantPath, QuantizedModel};
pub use weights::Weights;
