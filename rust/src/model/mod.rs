//! The tiny-GPT model substrate on the rust side: manifest/weights loading,
//! weight-space transforms (quantization, outlier injection, smoothing),
//! and a native forward pass cross-checked against the PJRT artifacts.
//! The transformer math itself (LN / attention / GELU / block loop, plus
//! the KV-cached incremental decode) is defined once in [`block`] and
//! shared by the FP and integer models.

pub mod block;
pub mod config;
pub mod forward;
pub mod qforward;
pub mod quantized;
pub mod weights;

pub use block::{DecodeState, LayerKvCache};
pub use config::ModelConfig;
pub use forward::{ActSite, IdentitySite, NativeModel, QuantSite, RemoveKernelSite};
pub use qforward::{QuantPath, QuantizedModel};
pub use weights::Weights;
