//! Native rust forward pass of the tiny GPT — the fast evaluation path for
//! large scheme/profile sweeps (the PJRT artifact path carries the e2e
//! examples and cross-checks this implementation to ≤1e-3 NLL; see
//! rust/tests/pjrt_integration.rs).
//!
//! Math mirrors python/compile/model.py exactly: pre-LN blocks, causal
//! softmax attention, tanh-approximated GELU (jax.nn.gelu default), LN
//! eps 1e-5, per-position NLL against the shifted targets.

use anyhow::Result;

use super::weights::Weights;
use crate::quant::{remove_kernel::RemoveKernel, ActQuantizer};
use crate::tensor::Matrix;

/// An activation-site transform (quantizer, remove-kernel, smoothing…)
/// applied at every quantization site of the forward pass. `site` is the
/// global site index (0..cfg.n_quant_sites()) so per-site calibrated
/// transforms (SmoothQuant) know where they are.
pub trait ActSite {
    fn apply(&mut self, site: usize, x: Matrix) -> Matrix;
}

/// FP forward — no transformation.
pub struct IdentitySite;

impl ActSite for IdentitySite {
    fn apply(&mut self, _site: usize, x: Matrix) -> Matrix {
        x
    }
}

/// Fake-quantize every site with one scheme; accumulates the observed
/// quantization-kernel fraction (Figure 4's measured-on-model statistic).
pub struct QuantSite<Q: ActQuantizer> {
    pub quant: Q,
    kernel_elems: f64,
    total_elems: f64,
}

impl<Q: ActQuantizer> QuantSite<Q> {
    pub fn new(quant: Q) -> Self {
        QuantSite { quant, kernel_elems: 0.0, total_elems: 0.0 }
    }

    pub fn kernel_fraction(&self) -> f32 {
        if self.total_elems == 0.0 {
            0.0
        } else {
            (self.kernel_elems / self.total_elems) as f32
        }
    }
}

impl<Q: ActQuantizer> ActSite for QuantSite<Q> {
    fn apply(&mut self, _site: usize, x: Matrix) -> Matrix {
        // Fused single pass: fake-quant output + kernel statistics in one
        // sweep (the seed walked the matrix three times here — delta
        // field twice, then the kernel scan, then the quant sweep).
        let (q, report) = crate::analysis::quantize_with_report(&x, &self.quant);
        self.kernel_elems += report.count as f64;
        self.total_elems += report.total as f64;
        q
    }
}

/// Remove-kernel ablation site; accumulates the removed fraction.
pub struct RemoveKernelSite {
    pub rk: RemoveKernel,
    removed: f64,
    total: f64,
}

impl RemoveKernelSite {
    pub fn new(rk: RemoveKernel) -> Self {
        RemoveKernelSite { rk, removed: 0.0, total: 0.0 }
    }

    pub fn removed_fraction(&self) -> f32 {
        if self.total == 0.0 { 0.0 } else { (self.removed / self.total) as f32 }
    }
}

impl ActSite for RemoveKernelSite {
    fn apply(&mut self, _site: usize, x: Matrix) -> Matrix {
        self.removed += self.rk.removed_fraction(&x) as f64 * x.len() as f64;
        self.total += x.len() as f64;
        self.rk.apply(&x)
    }
}

/// Per-site column smoothing followed by an inner quantizer — the
/// SmoothQuant evaluation path (weights must already be folded via
/// `quantized::apply_smoothquant`). Sites without scales pass through to
/// the inner quantizer unsmoothed.
pub struct SmoothedQuantSite<Q: ActQuantizer> {
    pub quant: Q,
    /// scales[site] = per-channel smoothing vector (empty = unsmoothed).
    pub scales: Vec<Vec<f32>>,
}

impl<Q: ActQuantizer> ActSite for SmoothedQuantSite<Q> {
    fn apply(&mut self, site: usize, x: Matrix) -> Matrix {
        let x = if site < self.scales.len() && !self.scales[site].is_empty() {
            let s = &self.scales[site];
            let mut out = x;
            for i in 0..out.rows {
                for (v, &sj) in out.row_mut(i).iter_mut().zip(s) {
                    *v /= sj;
                }
            }
            out
        } else {
            x
        };
        self.quant.fake_quant(&x)
    }
}

/// Capture activations at LN-fed sites (calibration / Figure-4 analysis).
pub struct CaptureSite {
    pub captured: Vec<(usize, Matrix)>,
    /// Only capture these site ids (empty = all).
    pub only: Vec<usize>,
}

impl CaptureSite {
    pub fn all() -> Self {
        CaptureSite { captured: Vec::new(), only: Vec::new() }
    }
}

impl ActSite for CaptureSite {
    fn apply(&mut self, site: usize, x: Matrix) -> Matrix {
        if self.only.is_empty() || self.only.contains(&site) {
            self.captured.push((site, x.clone()));
        }
        x
    }
}

/// Per-layer tensors, extracted once at construction.
struct LayerParams {
    ln1_g: Matrix,
    ln1_b: Matrix,
    wq: Matrix,
    wk: Matrix,
    wv: Matrix,
    wo: Matrix,
    ln2_g: Matrix,
    ln2_b: Matrix,
    w1: Matrix,
    w2: Matrix,
}

/// The native model: weight views pre-extracted for the hot loop (the
/// flat [`Weights`] is kept for the PJRT path and config access).
pub struct NativeModel {
    pub weights: Weights,
    tok_emb: Matrix,
    pos_emb: Matrix,
    layers: Vec<LayerParams>,
    lnf_g: Matrix,
    lnf_b: Matrix,
    w_out: Matrix,
}

impl NativeModel {
    pub fn new(weights: Weights) -> Self {
        let get = |n: &str| weights.get(n).expect("manifest-complete weights");
        let layers = (0..weights.config.n_layers)
            .map(|l| {
                let p = |n: &str| get(&format!("layer{l}.{n}"));
                LayerParams {
                    ln1_g: p("ln1_g"),
                    ln1_b: p("ln1_b"),
                    wq: p("wq"),
                    wk: p("wk"),
                    wv: p("wv"),
                    wo: p("wo"),
                    ln2_g: p("ln2_g"),
                    ln2_b: p("ln2_b"),
                    w1: p("w1"),
                    w2: p("w2"),
                }
            })
            .collect();
        NativeModel {
            tok_emb: get("tok_emb"),
            pos_emb: get("pos_emb"),
            layers,
            lnf_g: get("lnf_g"),
            lnf_b: get("lnf_b"),
            w_out: get("w_out"),
            weights,
        }
    }

    /// Forward one sequence, returning the log-probability distribution at
    /// the final position (greedy-prediction tasks).
    pub fn forward_last_logprobs(
        &self,
        tokens: &[u32],
        site: &mut dyn ActSite,
    ) -> Result<Vec<f32>> {
        let logits = self.forward_logits(tokens, site)?;
        let last = logits.row(logits.rows - 1);
        let max = last.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let logsum = max + last.iter().map(|&v| (v - max).exp()).sum::<f32>().ln();
        Ok(last.iter().map(|&v| v - logsum).collect())
    }

    /// Forward one sequence, returning per-position NLL (len = S−1).
    /// `site` is invoked at every quantization site in forward order.
    pub fn forward_nll(&self, tokens: &[u32], site: &mut dyn ActSite) -> Result<Vec<f32>> {
        let logits = self.forward_logits(tokens, site)?;
        let s = tokens.len();
        let mut nll = Vec::with_capacity(s - 1);
        for i in 0..s - 1 {
            let row = logits.row(i);
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let logsum = max + row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln();
            nll.push(logsum - row[tokens[i + 1] as usize]);
        }
        Ok(nll)
    }

    /// Full-logits forward (S × vocab).
    pub fn forward_logits(&self, tokens: &[u32], site: &mut dyn ActSite) -> Result<Matrix> {
        let cfg = self.weights.config;
        let s = tokens.len();
        let d = cfg.d_model;
        anyhow::ensure!(s >= 2 && s <= cfg.seq_len, "sequence length {s} out of range");

        let mut x = Matrix::zeros(s, d);
        for (i, &t) in tokens.iter().enumerate() {
            for j in 0..d {
                x.set(i, j, self.tok_emb.get(t as usize, j) + self.pos_emb.get(i, j));
            }
        }

        let mut site_idx = 0usize;
        for layer in &self.layers {
            // --- attention block ---
            let h = layer_norm(&x, &layer.ln1_g, &layer.ln1_b);
            let hq = site.apply(site_idx, h);
            site_idx += 1;
            let q = hq.matmul(&layer.wq);
            let k = hq.matmul(&layer.wk);
            let v = hq.matmul(&layer.wv);
            let ctx = causal_attention(&q, &k, &v, cfg.n_heads);
            let ctxq = site.apply(site_idx, ctx);
            site_idx += 1;
            let attn_out = ctxq.matmul(&layer.wo);
            add_inplace(&mut x, &attn_out);

            // --- MLP block ---
            let h = layer_norm(&x, &layer.ln2_g, &layer.ln2_b);
            let hq = site.apply(site_idx, h);
            site_idx += 1;
            let mut hh = hq.matmul(&layer.w1);
            gelu_inplace(&mut hh);
            let hhq = site.apply(site_idx, hh);
            site_idx += 1;
            let mlp_out = hhq.matmul(&layer.w2);
            add_inplace(&mut x, &mlp_out);
        }

        let h = layer_norm(&x, &self.lnf_g, &self.lnf_b);
        let hq = site.apply(site_idx, h);
        Ok(hq.matmul(&self.w_out))
    }
}

fn layer_norm(x: &Matrix, g: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    let n = x.cols as f32;
    for i in 0..x.rows {
        let row = x.row(i);
        let mu = row.iter().sum::<f32>() / n;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let dst = out.row_mut(i);
        for (j, (&v, o)) in row.iter().zip(dst.iter_mut()).enumerate() {
            *o = (v - mu) * inv * g.get(0, j) + b.get(0, j);
        }
    }
    out
}

fn causal_attention(q: &Matrix, k: &Matrix, v: &Matrix, n_heads: usize) -> Matrix {
    let s = q.rows;
    let d = q.cols;
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Matrix::zeros(s, d);
    let mut scores = vec![0.0f32; s];
    for h in 0..n_heads {
        let off = h * hd;
        for i in 0..s {
            // scores over keys 0..=i
            for (j, sc) in scores.iter_mut().enumerate().take(i + 1) {
                let mut dot = 0.0f32;
                for a in 0..hd {
                    dot += q.get(i, off + a) * k.get(j, off + a);
                }
                *sc = dot * scale;
            }
            let max = scores[..=i].iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut denom = 0.0f32;
            for sc in scores.iter_mut().take(i + 1) {
                *sc = (*sc - max).exp();
                denom += *sc;
            }
            for a in 0..hd {
                let mut acc = 0.0f32;
                for (j, &sc) in scores.iter().enumerate().take(i + 1) {
                    acc += sc * v.get(j, off + a);
                }
                out.set(i, off + a, acc / denom);
            }
        }
    }
    out
}

/// jax.nn.gelu default (approximate=True): tanh approximation.
fn gelu_inplace(x: &mut Matrix) {
    const C: f32 = 0.7978845608; // sqrt(2/π)
    for v in x.data.iter_mut() {
        let u = *v;
        *v = 0.5 * u * (1.0 + (C * (u + 0.044715 * u * u * u)).tanh());
    }
}

fn add_inplace(x: &mut Matrix, y: &Matrix) {
    for (a, b) in x.data.iter_mut().zip(&y.data) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::synthetic_weights as test_weights;
    use crate::quant::{crossquant::CrossQuant, Bits};

    fn tiny() -> NativeModel {
        let cfg = ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 12,
            eval_batch: 2,
        };
        NativeModel::new(test_weights(cfg, 11))
    }

    #[test]
    fn nll_shape_and_range() {
        let m = tiny();
        let toks: Vec<u32> = (0..12).map(|i| (i * 7 % 32) as u32).collect();
        let nll = m.forward_nll(&toks, &mut IdentitySite).unwrap();
        assert_eq!(nll.len(), 11);
        // random model ⇒ near-uniform ⇒ nll ≈ ln(32) ≈ 3.47
        let mean = nll.iter().sum::<f32>() / nll.len() as f32;
        assert!((mean - 32.0f32.ln()).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn causality_native() {
        let m = tiny();
        let t1: Vec<u32> = (0..12).map(|i| (i * 5 % 32) as u32).collect();
        let mut t2 = t1.clone();
        t2[11] = (t2[11] + 9) % 32;
        let n1 = m.forward_nll(&t1, &mut IdentitySite).unwrap();
        let n2 = m.forward_nll(&t2, &mut IdentitySite).unwrap();
        for i in 0..10 {
            assert!((n1[i] - n2[i]).abs() < 1e-5, "pos {i}");
        }
        assert!((n1[10] - n2[10]).abs() > 1e-7); // last target changed
    }

    #[test]
    fn quant_site_accumulates_kernel() {
        let m = tiny();
        let toks: Vec<u32> = (0..12).map(|i| (i % 32) as u32).collect();
        let mut site = QuantSite::new(CrossQuant::new(0.15, Bits::Int4));
        m.forward_nll(&toks, &mut site).unwrap();
        let f = site.kernel_fraction();
        assert!(f > 0.0 && f < 1.0, "kernel fraction {f}");
    }

    #[test]
    fn capture_site_sees_all_sites() {
        let m = tiny();
        let toks: Vec<u32> = (0..12).map(|i| (i % 32) as u32).collect();
        let mut cap = CaptureSite::all();
        m.forward_nll(&toks, &mut cap).unwrap();
        assert_eq!(cap.captured.len(), m.weights.config.n_quant_sites());
    }

    #[test]
    fn quantization_increases_nll_on_average() {
        let m = tiny();
        let mut fp_sum = 0.0f32;
        let mut q_sum = 0.0f32;
        for seed in 0..8u32 {
            let toks: Vec<u32> = (0..12).map(|i| ((i as u32 * 7 + seed * 3) % 32)).collect();
            let fp = m.forward_nll(&toks, &mut IdentitySite).unwrap();
            let mut site = QuantSite::new(CrossQuant::new(0.15, Bits::Int4));
            let q = m.forward_nll(&toks, &mut site).unwrap();
            fp_sum += fp.iter().sum::<f32>();
            q_sum += q.iter().sum::<f32>();
        }
        // INT4 on a random model: outputs differ measurably
        assert!((q_sum - fp_sum).abs() > 1e-4);
    }
}
