//! Native rust forward pass of the tiny GPT — the fast evaluation path for
//! large scheme/profile sweeps (the PJRT artifact path carries the e2e
//! examples and cross-checks this implementation to ≤1e-3 NLL; see
//! rust/tests/pjrt_integration.rs).
//!
//! Math mirrors python/compile/model.py exactly: pre-LN blocks, causal
//! softmax attention, tanh-approximated GELU (jax.nn.gelu default), LN
//! eps 1e-5, per-position NLL against the shifted targets. The transformer
//! math itself lives in [`super::block`] — one implementation shared with
//! the integer model — and this file contributes the ActSite machinery
//! plus the weight views.

use std::sync::Arc;

use anyhow::Result;

use super::block::{self, DecodeState, LayerView, ModelView};
use super::weights::Weights;
use crate::obs::{KernelTelemetry, SiteSample};
use crate::quant::{remove_kernel::RemoveKernel, ActQuantizer};
use crate::tensor::Matrix;

/// An activation-site transform (quantizer, remove-kernel, smoothing…)
/// applied at every quantization site of the forward pass. `site` is the
/// global site index (0..cfg.n_quant_sites()) so per-site calibrated
/// transforms (SmoothQuant) know where they are.
pub trait ActSite {
    fn apply(&mut self, site: usize, x: Matrix) -> Matrix;
}

/// FP forward — no transformation.
pub struct IdentitySite;

impl ActSite for IdentitySite {
    fn apply(&mut self, _site: usize, x: Matrix) -> Matrix {
        x
    }
}

/// Fake-quantize every site with one scheme; accumulates the observed
/// quantization-kernel fraction (Figure 4's measured-on-model statistic).
pub struct QuantSite<Q: ActQuantizer> {
    pub quant: Q,
    kernel_elems: f64,
    total_elems: f64,
    telemetry: Option<Arc<KernelTelemetry>>,
}

impl<Q: ActQuantizer> QuantSite<Q> {
    pub fn new(quant: Q) -> Self {
        QuantSite { quant, kernel_elems: 0.0, total_elems: 0.0, telemetry: None }
    }

    /// Wire live kernel telemetry into this site: sampled forwards feed
    /// per-site kernel-fraction and absmax gauges (`obs::KernelTelemetry`).
    pub fn with_telemetry(mut self, telemetry: Arc<KernelTelemetry>) -> Self {
        self.telemetry = Some(telemetry);
        self
    }

    pub fn kernel_fraction(&self) -> f32 {
        if self.total_elems == 0.0 {
            0.0
        } else {
            (self.kernel_elems / self.total_elems) as f32
        }
    }
}

/// Mean per-row and per-column absolute maxima of an activation tile —
/// the live counterparts of `t_i` and `c_j` in CrossQuant's eq. (5). One
/// pass; only run on telemetry-sampled calls.
fn absmax_means(x: &Matrix) -> (f32, f32) {
    if x.rows == 0 || x.cols == 0 {
        return (0.0, 0.0);
    }
    let mut col_max = vec![0.0f32; x.cols];
    let mut row_sum = 0.0f64;
    for i in 0..x.rows {
        let mut rm = 0.0f32;
        for (cm, &v) in col_max.iter_mut().zip(x.row(i)) {
            let a = v.abs();
            rm = rm.max(a);
            *cm = cm.max(a);
        }
        row_sum += rm as f64;
    }
    let col_sum: f64 = col_max.iter().map(|&v| v as f64).sum();
    ((row_sum / x.rows as f64) as f32, (col_sum / x.cols as f64) as f32)
}

impl<Q: ActQuantizer> ActSite for QuantSite<Q> {
    fn apply(&mut self, site: usize, x: Matrix) -> Matrix {
        // Fused single pass: fake-quant output + kernel statistics in one
        // sweep (the seed walked the matrix three times here — delta
        // field twice, then the kernel scan, then the quant sweep).
        let (q, report) = crate::analysis::quantize_with_report(&x, &self.quant);
        self.kernel_elems += report.count as f64;
        self.total_elems += report.total as f64;
        if let Some(t) = &self.telemetry {
            t.observe(site, || {
                let (row_absmax, col_absmax) = absmax_means(&x);
                SiteSample {
                    kernel: report.count as u64,
                    total: report.total as u64,
                    row_absmax,
                    col_absmax,
                }
            });
        }
        q
    }
}

/// Remove-kernel ablation site; accumulates the removed fraction.
pub struct RemoveKernelSite {
    pub rk: RemoveKernel,
    removed: f64,
    total: f64,
}

impl RemoveKernelSite {
    pub fn new(rk: RemoveKernel) -> Self {
        RemoveKernelSite { rk, removed: 0.0, total: 0.0 }
    }

    pub fn removed_fraction(&self) -> f32 {
        if self.total == 0.0 { 0.0 } else { (self.removed / self.total) as f32 }
    }
}

impl ActSite for RemoveKernelSite {
    fn apply(&mut self, _site: usize, x: Matrix) -> Matrix {
        self.removed += self.rk.removed_fraction(&x) as f64 * x.len() as f64;
        self.total += x.len() as f64;
        self.rk.apply(&x)
    }
}

/// Per-site column smoothing followed by an inner quantizer — the
/// SmoothQuant evaluation path (weights must already be folded via
/// `quantized::apply_smoothquant`). Sites without scales pass through to
/// the inner quantizer unsmoothed.
pub struct SmoothedQuantSite<Q: ActQuantizer> {
    pub quant: Q,
    /// scales[site] = per-channel smoothing vector (empty = unsmoothed).
    pub scales: Vec<Vec<f32>>,
}

impl<Q: ActQuantizer> ActSite for SmoothedQuantSite<Q> {
    fn apply(&mut self, site: usize, x: Matrix) -> Matrix {
        let x = if site < self.scales.len() && !self.scales[site].is_empty() {
            let s = &self.scales[site];
            let mut out = x;
            for i in 0..out.rows {
                for (v, &sj) in out.row_mut(i).iter_mut().zip(s) {
                    *v /= sj;
                }
            }
            out
        } else {
            x
        };
        self.quant.fake_quant(&x)
    }
}

/// Capture activations at LN-fed sites (calibration / Figure-4 analysis).
pub struct CaptureSite {
    pub captured: Vec<(usize, Matrix)>,
    /// Only capture these site ids (empty = all).
    pub only: Vec<usize>,
}

impl CaptureSite {
    pub fn all() -> Self {
        CaptureSite { captured: Vec::new(), only: Vec::new() }
    }
}

impl ActSite for CaptureSite {
    fn apply(&mut self, site: usize, x: Matrix) -> Matrix {
        if self.only.is_empty() || self.only.contains(&site) {
            self.captured.push((site, x.clone()));
        }
        x
    }
}

/// Per-layer tensors, extracted once at construction.
struct LayerParams {
    ln1_g: Matrix,
    ln1_b: Matrix,
    wq: Matrix,
    wk: Matrix,
    wv: Matrix,
    wo: Matrix,
    ln2_g: Matrix,
    ln2_b: Matrix,
    w1: Matrix,
    w2: Matrix,
}

/// The native model: weight views pre-extracted for the hot loop (the
/// flat [`Weights`] is kept for the PJRT path and config access).
pub struct NativeModel {
    pub weights: Weights,
    tok_emb: Matrix,
    pos_emb: Matrix,
    layers: Vec<LayerParams>,
    lnf_g: Matrix,
    lnf_b: Matrix,
    w_out: Matrix,
}

impl NativeModel {
    pub fn new(weights: Weights) -> Self {
        let get = |n: &str| weights.get(n).expect("manifest-complete weights");
        let layers = (0..weights.config.n_layers)
            .map(|l| {
                let p = |n: &str| get(&format!("layer{l}.{n}"));
                LayerParams {
                    ln1_g: p("ln1_g"),
                    ln1_b: p("ln1_b"),
                    wq: p("wq"),
                    wk: p("wk"),
                    wv: p("wv"),
                    wo: p("wo"),
                    ln2_g: p("ln2_g"),
                    ln2_b: p("ln2_b"),
                    w1: p("w1"),
                    w2: p("w2"),
                }
            })
            .collect();
        NativeModel {
            tok_emb: get("tok_emb"),
            pos_emb: get("pos_emb"),
            layers,
            lnf_g: get("lnf_g"),
            lnf_b: get("lnf_b"),
            w_out: get("w_out"),
            weights,
        }
    }

    /// The borrowed [`ModelView`] the shared block driver consumes.
    fn view(&self) -> ModelView<'_, Matrix> {
        ModelView {
            config: self.weights.config,
            tok_emb: &self.tok_emb,
            pos_emb: &self.pos_emb,
            layers: self
                .layers
                .iter()
                .map(|l| LayerView {
                    ln1_g: &l.ln1_g,
                    ln1_b: &l.ln1_b,
                    wq: &l.wq,
                    wk: &l.wk,
                    wv: &l.wv,
                    wo: &l.wo,
                    ln2_g: &l.ln2_g,
                    ln2_b: &l.ln2_b,
                    w1: &l.w1,
                    w2: &l.w2,
                })
                .collect(),
            lnf_g: &self.lnf_g,
            lnf_b: &self.lnf_b,
            w_out: &self.w_out,
        }
    }

    /// Forward one sequence, returning the log-probability distribution at
    /// the final position (greedy-prediction tasks).
    pub fn forward_last_logprobs(
        &self,
        tokens: &[u32],
        site: &mut dyn ActSite,
    ) -> Result<Vec<f32>> {
        let logits = self.forward_logits(tokens, site)?;
        Ok(block::log_softmax(logits.row(logits.rows - 1)))
    }

    /// Forward one sequence, returning per-position NLL (len = S−1).
    /// `site` is invoked at every quantization site in forward order.
    pub fn forward_nll(&self, tokens: &[u32], site: &mut dyn ActSite) -> Result<Vec<f32>> {
        let logits = self.forward_logits(tokens, site)?;
        Ok(block::nll_from_logits(&logits, tokens))
    }

    /// Full-logits forward (S × vocab), stateless.
    pub fn forward_logits(&self, tokens: &[u32], site: &mut dyn ActSite) -> Result<Matrix> {
        let s = tokens.len();
        anyhow::ensure!(
            s >= 2 && s <= self.weights.config.seq_len,
            "sequence length {s} out of range"
        );
        block::forward_pass(
            &self.view(),
            tokens,
            None,
            false,
            &mut |w, x| x.matmul(w),
            &mut |idx, x| site.apply(idx, x),
        )
    }

    /// A fresh KV-cache decode state sized for this model.
    pub fn new_decode_state(&self) -> DecodeState {
        let cfg = self.weights.config;
        DecodeState::new(cfg.n_layers, cfg.seq_len, cfg.d_model)
    }

    pub(crate) fn forward_incremental_with(
        &self,
        tokens: &[u32],
        state: &mut DecodeState,
        site: &mut dyn ActSite,
        last_logits_only: bool,
    ) -> Result<Matrix> {
        block::forward_pass(
            &self.view(),
            tokens,
            Some(state),
            last_logits_only,
            &mut |w, x| x.matmul(w),
            &mut |idx, x| site.apply(idx, x),
        )
    }

    /// Incremental forward: append `tokens` after `state`'s cached prefix
    /// and return logits for the new rows only. Prefill and per-token
    /// decode are the same call — pass the prompt first, then one token at
    /// a time.
    pub fn forward_incremental(
        &self,
        tokens: &[u32],
        state: &mut DecodeState,
        site: &mut dyn ActSite,
    ) -> Result<Matrix> {
        self.forward_incremental_with(tokens, state, site, false)
    }

    /// One continuous-batching decode step: `tokens[i]` is the next token
    /// of the independent sequence cached in `states[i]`. The linear
    /// operators run once at M=N; `row_site(row, site, x)` applies the
    /// activation transform to each sequence's 1-row slice separately, so
    /// batch-coupled scale fields (dynamic CrossQuant's live column
    /// maxima) see exactly the M=1 matrices a sequential decode would —
    /// outputs are bit-identical to per-sequence [`Self::forward_incremental`]
    /// steps. Pass `None` for the FP path (identity sites): the hot loop
    /// then skips the per-row split. Returns N × vocab logits.
    pub fn forward_step_batched(
        &self,
        tokens: &[u32],
        states: &mut [&mut DecodeState],
        row_site: Option<&mut dyn FnMut(usize, usize, Matrix) -> Matrix>,
    ) -> Result<Matrix> {
        block::forward_step_batched(
            &self.view(),
            tokens,
            states,
            &mut |w, x| x.matmul(w),
            row_site,
        )
    }

    /// Greedy autoregressive generation through the KV cache: prefill the
    /// prompt once (head applied to the last row only), then decode one
    /// token per step (M=1 matmuls). Returns the `max_new_tokens`
    /// generated ids.
    pub fn generate_greedy(
        &self,
        prompt: &[u32],
        max_new_tokens: usize,
        site: &mut dyn ActSite,
    ) -> Result<Vec<u32>> {
        let mut state = self.new_decode_state();
        block::generate_greedy_with(
            self.weights.config.seq_len,
            prompt,
            max_new_tokens,
            &mut state,
            &mut |toks, st| self.forward_incremental_with(toks, st, site, true),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::synthetic_weights as test_weights;
    use crate::quant::{crossquant::CrossQuant, Bits};

    fn tiny() -> NativeModel {
        let cfg = ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 12,
            eval_batch: 2,
        };
        NativeModel::new(test_weights(cfg, 11))
    }

    #[test]
    fn nll_shape_and_range() {
        let m = tiny();
        let toks: Vec<u32> = (0..12).map(|i| (i * 7 % 32) as u32).collect();
        let nll = m.forward_nll(&toks, &mut IdentitySite).unwrap();
        assert_eq!(nll.len(), 11);
        // random model ⇒ near-uniform ⇒ nll ≈ ln(32) ≈ 3.47
        let mean = nll.iter().sum::<f32>() / nll.len() as f32;
        assert!((mean - 32.0f32.ln()).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn causality_native() {
        let m = tiny();
        let t1: Vec<u32> = (0..12).map(|i| (i * 5 % 32) as u32).collect();
        let mut t2 = t1.clone();
        t2[11] = (t2[11] + 9) % 32;
        let n1 = m.forward_nll(&t1, &mut IdentitySite).unwrap();
        let n2 = m.forward_nll(&t2, &mut IdentitySite).unwrap();
        for i in 0..10 {
            assert!((n1[i] - n2[i]).abs() < 1e-5, "pos {i}");
        }
        assert!((n1[10] - n2[10]).abs() > 1e-7); // last target changed
    }

    #[test]
    fn quant_site_accumulates_kernel() {
        let m = tiny();
        let toks: Vec<u32> = (0..12).map(|i| (i % 32) as u32).collect();
        let mut site = QuantSite::new(CrossQuant::new(0.15, Bits::Int4));
        m.forward_nll(&toks, &mut site).unwrap();
        let f = site.kernel_fraction();
        assert!(f > 0.0 && f < 1.0, "kernel fraction {f}");
    }

    #[test]
    fn quant_site_feeds_kernel_telemetry_per_site() {
        let m = tiny();
        let toks: Vec<u32> = (0..12).map(|i| (i % 32) as u32).collect();
        let telemetry = Arc::new(KernelTelemetry::new());
        telemetry.configure(true, 0.19, 1);
        let mut site = QuantSite::new(CrossQuant::new(0.15, Bits::Int8))
            .with_telemetry(telemetry.clone());
        m.forward_nll(&toks, &mut site).unwrap();
        let j = telemetry.json();
        let sites = j.get("sites").unwrap().as_arr().unwrap();
        assert_eq!(sites.len(), m.weights.config.n_quant_sites());
        for s in sites {
            assert_eq!(s.get("samples").unwrap().as_f64(), Some(1.0));
            assert!(s.get("row_absmax_mean").unwrap().as_f64().unwrap() > 0.0);
            assert!(s.get("col_absmax_mean").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn capture_site_sees_all_sites() {
        let m = tiny();
        let toks: Vec<u32> = (0..12).map(|i| (i % 32) as u32).collect();
        let mut cap = CaptureSite::all();
        m.forward_nll(&toks, &mut cap).unwrap();
        assert_eq!(cap.captured.len(), m.weights.config.n_quant_sites());
    }

    #[test]
    fn generate_greedy_stays_in_vocab_and_context() {
        let m = tiny();
        let gen = m.generate_greedy(&[1, 2, 3], 5, &mut IdentitySite).unwrap();
        assert_eq!(gen.len(), 5);
        assert!(gen.iter().all(|&t| (t as usize) < m.weights.config.vocab));
        // deterministic
        assert_eq!(gen, m.generate_greedy(&[1, 2, 3], 5, &mut IdentitySite).unwrap());
        // context overflow and empty prompt are Errs, not panics
        assert!(m.generate_greedy(&[0; 10], 3, &mut IdentitySite).is_err());
        assert!(m.generate_greedy(&[], 3, &mut IdentitySite).is_err());
    }

    #[test]
    fn batched_step_bit_identical_to_sequential_steps() {
        // three staggered sequences, each with its own fake-quant site:
        // the M=3 batched step must reproduce the three M=1 steps exactly,
        // including under the batch-coupled CrossQuant column maxima
        // (applied per row by construction)
        let m = tiny();
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[9, 4], &[7, 7, 7, 7]];
        // sequential reference: per-sequence M=1 decode steps
        let mut ref_states: Vec<DecodeState> = Vec::new();
        let mut ref_logits: Vec<Matrix> = Vec::new();
        for p in prompts {
            let mut st = m.new_decode_state();
            let mut site = QuantSite::new(CrossQuant::new(0.15, Bits::Int8));
            m.forward_incremental_with(p, &mut st, &mut site, true).unwrap();
            let l = m
                .forward_incremental_with(&[5], &mut st, &mut site, false)
                .unwrap();
            ref_logits.push(l);
            ref_states.push(st);
        }
        // batched: prefill each alone, then one M=3 step
        let mut states: Vec<DecodeState> = Vec::new();
        let mut sites: Vec<QuantSite<CrossQuant>> = Vec::new();
        for p in prompts {
            let mut st = m.new_decode_state();
            let mut site = QuantSite::new(CrossQuant::new(0.15, Bits::Int8));
            m.forward_incremental_with(p, &mut st, &mut site, true).unwrap();
            states.push(st);
            sites.push(site);
        }
        let mut state_refs: Vec<&mut DecodeState> = states.iter_mut().collect();
        let mut hook = |row: usize, idx: usize, x: Matrix| sites[row].apply(idx, x);
        let logits =
            m.forward_step_batched(&[5, 5, 5], &mut state_refs, Some(&mut hook)).unwrap();
        assert_eq!(logits.rows, 3);
        for (i, r) in ref_logits.iter().enumerate() {
            assert_eq!(logits.row(i), r.row(0), "sequence {i} must be bit-exact");
            assert_eq!(states[i].len(), ref_states[i].len());
        }
    }

    #[test]
    fn quantization_increases_nll_on_average() {
        let m = tiny();
        let mut fp_sum = 0.0f32;
        let mut q_sum = 0.0f32;
        for seed in 0..8u32 {
            let toks: Vec<u32> = (0..12).map(|i| ((i as u32 * 7 + seed * 3) % 32)).collect();
            let fp = m.forward_nll(&toks, &mut IdentitySite).unwrap();
            let mut site = QuantSite::new(CrossQuant::new(0.15, Bits::Int4));
            let q = m.forward_nll(&toks, &mut site).unwrap();
            fp_sum += fp.iter().sum::<f32>();
            q_sum += q.iter().sum::<f32>();
        }
        // INT4 on a random model: outputs differ measurably
        assert!((q_sum - fp_sum).abs() > 1e-4);
    }
}
