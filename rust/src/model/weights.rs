//! weights.bin + manifest.json loader.
//!
//! The flat little-endian f32 weight vector and its layout table are the
//! contract between the python compile path and the rust runtime: the AOT
//! HLOs take the flat vector as a single parameter, and every rust-side
//! weight transform edits it in place through named 2-D views.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use super::config::ModelConfig;
use crate::tensor::Matrix;
use crate::util::Json;

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Clone, Debug)]
pub struct TrainInfo {
    pub final_loss: f64,
    pub final_ppl: f64,
    pub steps: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ModelConfig,
    pub params: Vec<ParamEntry>,
    pub total_params: usize,
    pub train: Option<TrainInfo>,
    /// Names of the AOT HLO artifacts recorded by aot.py.
    pub artifacts: Vec<String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let cfg = v.req("config")?;
        let usize_of = |j: &Json, k: &str| -> Result<usize> {
            j.req(k)?.as_usize().ok_or_else(|| anyhow!("'{k}' not a number"))
        };
        let config = ModelConfig {
            vocab: usize_of(cfg, "vocab")?,
            d_model: usize_of(cfg, "d_model")?,
            n_layers: usize_of(cfg, "n_layers")?,
            n_heads: usize_of(cfg, "n_heads")?,
            d_ff: usize_of(cfg, "d_ff")?,
            seq_len: usize_of(cfg, "seq_len")?,
            eval_batch: usize_of(cfg, "eval_batch")?,
        };
        let params = v
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow!("'params' not an array"))?
            .iter()
            .map(|p| -> Result<ParamEntry> {
                Ok(ParamEntry {
                    name: p.req("name")?.as_str().unwrap_or_default().to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|s| s.as_usize())
                        .collect(),
                    offset: usize_of(p, "offset")?,
                    size: usize_of(p, "size")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let train = v.get("train").map(|t| TrainInfo {
            final_loss: t.get("final_loss").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
            final_ppl: t.get("final_ppl").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
            steps: t.get("steps").and_then(|x| x.as_usize()).unwrap_or(0),
        });
        let artifacts = match v.get("artifacts") {
            Some(Json::Obj(m)) => m.keys().cloned().collect(),
            _ => Vec::new(),
        };
        Ok(Manifest { config, params, total_params: usize_of(&v, "total_params")?, train, artifacts })
    }
}

/// The loaded model: flat weights + layout.
#[derive(Clone, Debug)]
pub struct Weights {
    pub config: ModelConfig,
    pub manifest: Manifest,
    pub flat: Vec<f32>,
    index: HashMap<String, (usize, Vec<usize>)>,
}

impl Weights {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Weights> {
        let dir = artifacts_dir.as_ref();
        let manifest = Manifest::parse(
            &std::fs::read_to_string(dir.join("manifest.json"))
                .with_context(|| format!("reading {}/manifest.json", dir.display()))?,
        )?;
        let bytes = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("reading {}/weights.bin", dir.display()))?;
        ensure!(
            bytes.len() == manifest.total_params * 4,
            "weights.bin has {} bytes, manifest expects {}",
            bytes.len(),
            manifest.total_params * 4
        );
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(Self::from_parts(manifest, flat))
    }

    pub fn from_parts(manifest: Manifest, flat: Vec<f32>) -> Weights {
        let index = manifest
            .params
            .iter()
            .map(|p| (p.name.clone(), (p.offset, p.shape.clone())))
            .collect();
        Weights { config: manifest.config, manifest, flat, index }
    }

    /// Copy a named tensor out as a Matrix (1-D tensors become 1×N).
    pub fn get(&self, name: &str) -> Result<Matrix> {
        let (off, shape) = self.index.get(name).ok_or_else(|| anyhow!("no param {name}"))?;
        let (rows, cols) = match shape.len() {
            1 => (1, shape[0]),
            2 => (shape[0], shape[1]),
            n => return Err(anyhow!("param {name} has rank {n}")),
        };
        let size = rows * cols;
        Ok(Matrix::from_vec(rows, cols, self.flat[*off..off + size].to_vec()))
    }

    /// Write a matrix back into the flat vector.
    pub fn set(&mut self, name: &str, m: &Matrix) -> Result<()> {
        let (off, shape) = self.index.get(name).ok_or_else(|| anyhow!("no param {name}"))?;
        let expected: usize = shape.iter().product();
        ensure!(m.len() == expected, "shape mismatch writing {name}");
        self.flat[*off..off + expected].copy_from_slice(&m.data);
        Ok(())
    }

    /// Names of the linear-layer weight matrices (the tensors the paper
    /// quantizes; embeddings and LayerNorm affines stay FP16/FP32).
    pub fn linear_names(&self) -> Vec<String> {
        self.manifest
            .params
            .iter()
            .filter(|p| {
                p.shape.len() == 2 && !p.name.contains("emb") // wq..wo, w1, w2, w_out
            })
            .map(|p| p.name.clone())
            .collect()
    }

    pub fn param_names(&self) -> Vec<String> {
        self.manifest.params.iter().map(|p| p.name.clone()).collect()
    }

    /// Rebuild [`Weights`] from a bare (config, flat vector) pair using
    /// the canonical python parameter layout (the same layout
    /// [`synthetic_weights`] emits). The coordinator's native executor
    /// reconstructs registered weight sets this way when no PJRT runtime
    /// is linked — the flat vector is the one contract both paths share.
    pub fn from_config_flat(config: ModelConfig, flat: Vec<f32>) -> Result<Weights> {
        let (params, total) = param_layout(&config);
        ensure!(
            flat.len() == total,
            "weight vector holds {} f32s, config requires {total}",
            flat.len()
        );
        let manifest =
            Manifest { config, params, total_params: total, train: None, artifacts: Vec::new() };
        Ok(Weights::from_parts(manifest, flat))
    }
}

/// The canonical parameter layout of the python model for a config:
/// embeddings, per-layer (LN affines + attention/MLP linears), final LN,
/// output head — in flat-vector order.
fn param_layout(cfg: &ModelConfig) -> (Vec<ParamEntry>, usize) {
    let mut params = Vec::new();
    let mut offset = 0usize;
    let mut push = |name: String, shape: Vec<usize>, params: &mut Vec<ParamEntry>| {
        let size: usize = shape.iter().product();
        params.push(ParamEntry { name, shape, offset, size });
        offset += size;
    };
    push("tok_emb".into(), vec![cfg.vocab, cfg.d_model], &mut params);
    push("pos_emb".into(), vec![cfg.seq_len, cfg.d_model], &mut params);
    for l in 0..cfg.n_layers {
        for (n, shape) in [
            ("ln1_g", vec![cfg.d_model]),
            ("ln1_b", vec![cfg.d_model]),
            ("wq", vec![cfg.d_model, cfg.d_model]),
            ("wk", vec![cfg.d_model, cfg.d_model]),
            ("wv", vec![cfg.d_model, cfg.d_model]),
            ("wo", vec![cfg.d_model, cfg.d_model]),
            ("ln2_g", vec![cfg.d_model]),
            ("ln2_b", vec![cfg.d_model]),
            ("w1", vec![cfg.d_model, cfg.d_ff]),
            ("w2", vec![cfg.d_ff, cfg.d_model]),
        ] {
            push(format!("layer{l}.{n}"), shape, &mut params);
        }
    }
    push("lnf_g".into(), vec![cfg.d_model], &mut params);
    push("lnf_b".into(), vec![cfg.d_model], &mut params);
    push("w_out".into(), vec![cfg.d_model, cfg.vocab], &mut params);
    (params, offset)
}

/// Bytes of the flat FP32 checkpoint a config implies — the
/// compression-ratio denominator `repro inspect` reports for `.cqa`
/// artifacts.
pub fn fp_weight_bytes(cfg: &ModelConfig) -> usize {
    param_layout(cfg).1 * 4
}

/// Build randomly-initialised Weights with the python parameter layout —
/// the substrate for unit tests, property tests and `--synthetic` CLI runs
/// that don't have trained artifacts on disk.
pub fn synthetic_weights(cfg: ModelConfig, seed: u64) -> Weights {
    use crate::tensor::SplitMix64;
    let (params, offset) = param_layout(&cfg);
    let mut rng = SplitMix64::new(seed);
    let flat: Vec<f32> = params
        .iter()
        .flat_map(|p| {
            let std = if p.name.ends_with("_g") {
                return vec![1.0f32; p.size];
            } else if p.name.ends_with("_b") {
                return vec![0.0f32; p.size];
            } else {
                0.02f32
            };
            (0..p.size).map(|_| rng.normal() as f32 * std).collect::<Vec<_>>()
        })
        .collect();

    let manifest = Manifest {
        config: cfg,
        total_params: offset,
        params,
        train: None,
        artifacts: Vec::new(),
    };
    Weights::from_parts(manifest, flat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use synthetic_weights as test_weights;

    #[test]
    fn get_set_roundtrip() {
        let cfg = ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            seq_len: 8,
            eval_batch: 2,
        };
        let mut w = test_weights(cfg, 1);
        let mut m = w.get("layer0.wq").unwrap();
        assert_eq!((m.rows, m.cols), (16, 16));
        m.set(0, 0, 42.0);
        w.set("layer0.wq", &m).unwrap();
        assert_eq!(w.get("layer0.wq").unwrap().get(0, 0), 42.0);
    }

    #[test]
    fn linear_names_exclude_embeddings_and_norms() {
        let cfg = ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 8,
            eval_batch: 2,
        };
        let w = test_weights(cfg, 1);
        let names = w.linear_names();
        assert_eq!(names.len(), 2 * 6 + 1); // 6 linears per layer + w_out
        assert!(!names.iter().any(|n| n.contains("emb") || n.contains("ln")));
    }

    #[test]
    fn from_config_flat_matches_synthetic_layout() {
        let cfg = ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 8,
            eval_batch: 2,
        };
        let w = test_weights(cfg, 5);
        let rebuilt = Weights::from_config_flat(cfg, w.flat.clone()).unwrap();
        for name in w.param_names() {
            assert_eq!(rebuilt.get(&name).unwrap(), w.get(&name).unwrap(), "{name}");
        }
        // wrong length must be a loud error, not a misaligned model
        assert!(Weights::from_config_flat(cfg, vec![0.0; 4]).is_err());
    }

    #[test]
    fn missing_param_is_error() {
        let cfg = ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            seq_len: 8,
            eval_batch: 2,
        };
        let w = test_weights(cfg, 1);
        assert!(w.get("nope").is_err());
    }
}
