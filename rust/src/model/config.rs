//! Model configuration — mirror of python/compile/common.py::ModelConfig.
//!
//! The numbers live in artifacts/manifest.json (written at train time);
//! rust never hard-codes them, so retraining with a different size is a
//! pure `make artifacts` change.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    /// Fixed batch of the AOT-lowered eval HLOs.
    pub eval_batch: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// The default build-time config (kept in sync with common.py; the
    /// manifest is authoritative at run time).
    pub fn default_build() -> Self {
        ModelConfig {
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            seq_len: 96,
            eval_batch: 8,
        }
    }

    /// Quantization sites per forward pass (ln1, ctx, ln2, gelu per layer,
    /// plus the final lnf site) — used to size per-site transform tables.
    pub fn n_quant_sites(&self) -> usize {
        4 * self.n_layers + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dim_divides() {
        let c = ModelConfig::default_build();
        assert_eq!(c.head_dim() * c.n_heads, c.d_model);
    }

    #[test]
    fn site_count() {
        let c = ModelConfig::default_build();
        assert_eq!(c.n_quant_sites(), 17);
    }
}
