//! True-integer model forward: every linear layer runs through
//! [`QuantizedLinear`] (packed-panel int8 GEMMs — see `quant::gemm`),
//! embeddings/LayerNorms stay FP — the actual W8A8 deployment of the
//! paper, as opposed to the fake-quant evaluation protocol used by the
//! tables.
//!
//! Deployment modes ([`QuantPath`]): per-token W8A8, dynamic CrossQuant
//! (per-batch weight rescale), and calibrated static-scale CrossQuant
//! ([`QuantizedModel::calibrate_static`]) whose per-batch cost is
//! identical to per-token.
//!
//! Integration tests pin this path against the fake-quant NativeModel:
//! identical scheme ⇒ near-identical NLLs, so the fake-quant tables are
//! faithful proxies for the deployed system.
//!
//! The transformer math (LN, attention, GELU, block loop, KV-cached
//! decode) is the shared core in [`super::block`]; this file contributes
//! the quantized-linear dispatch and the calibration machinery.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::block::{self, DecodeState, LayerView, ModelView};
use super::config::ModelConfig;
use super::weights::Weights;
use crate::activations::ColStats;
use crate::quant::artifact::{Artifact, ArtifactWriter};
use crate::quant::qlinear::{QuantizedLinear, ScaleMode};
use crate::quant::Bits;
use crate::tensor::Matrix;

/// Which activation quantization runs in front of every integer GEMM.
#[derive(Clone, Copy, Debug)]
pub enum QuantPath {
    PerToken,
    /// Dynamic CrossQuant: live batch column maxima, per-batch O(I·O)
    /// weight rescale at every site.
    CrossQuant { alpha: f32 },
    /// Static CrossQuant: calibration-derived column factors folded into
    /// the weights once — requires [`QuantizedModel::calibrate_static`].
    CrossQuantStatic { alpha: f32 },
}

struct QLayer {
    ln1_g: Matrix,
    ln1_b: Matrix,
    wq: QuantizedLinear,
    wk: QuantizedLinear,
    wv: QuantizedLinear,
    wo: QuantizedLinear,
    ln2_g: Matrix,
    ln2_b: Matrix,
    w1: QuantizedLinear,
    w2: QuantizedLinear,
}

/// The integer-inference model.
pub struct QuantizedModel {
    pub config: ModelConfig,
    pub weight_bits: Bits,
    pub act_bits: Bits,
    pub path: QuantPath,
    tok_emb: Matrix,
    pos_emb: Matrix,
    layers: Vec<QLayer>,
    lnf_g: Matrix,
    lnf_b: Matrix,
    w_out: QuantizedLinear,
    /// Per-site calibration statistics retained by `calibrate_static` (or
    /// rebuilt from an artifact) so `write_artifact` can ship them.
    calib_stats: Option<Vec<ColStats>>,
    /// Registry scheme ID this model was built as (see
    /// `quant::registry::SchemeId::artifact_code`); 0 = plain
    /// crossquant-static. Stamped into the `.cqa` header on write.
    pub scheme_code: u16,
}

impl QuantizedModel {
    pub fn new(
        weights: &Weights,
        weight_bits: Bits,
        act_bits: Bits,
        path: QuantPath,
    ) -> Result<QuantizedModel> {
        // the static path needs calibration-derived folds that only
        // calibrate_static installs — constructing with it directly would
        // panic on the first forward
        anyhow::ensure!(
            !matches!(path, QuantPath::CrossQuantStatic { .. }),
            "construct with a dynamic QuantPath and call calibrate_static \
             to enable QuantPath::CrossQuantStatic"
        );
        // both grids materialise i8 codes — reject >8-bit widths here as
        // an Err instead of a panic on the first forward
        anyhow::ensure!(
            weight_bits.qmax() <= 127.0 && act_bits.qmax() <= 127.0,
            "the integer model stores i8 codes: weight/activation widths above 8 bits \
             are not representable"
        );
        let q = |name: &str| -> Result<QuantizedLinear> {
            Ok(QuantizedLinear::from_weight(&weights.get(name)?, weight_bits))
        };
        let layers = (0..weights.config.n_layers)
            .map(|l| -> Result<QLayer> {
                let p = |n: &str| weights.get(&format!("layer{l}.{n}"));
                Ok(QLayer {
                    ln1_g: p("ln1_g")?,
                    ln1_b: p("ln1_b")?,
                    wq: q(&format!("layer{l}.wq"))?,
                    wk: q(&format!("layer{l}.wk"))?,
                    wv: q(&format!("layer{l}.wv"))?,
                    wo: q(&format!("layer{l}.wo"))?,
                    ln2_g: p("ln2_g")?,
                    ln2_b: p("ln2_b")?,
                    w1: q(&format!("layer{l}.w1"))?,
                    w2: q(&format!("layer{l}.w2"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(QuantizedModel {
            config: weights.config,
            weight_bits,
            act_bits,
            path,
            tok_emb: weights.get("tok_emb")?,
            pos_emb: weights.get("pos_emb")?,
            layers,
            lnf_g: weights.get("lnf_g")?,
            lnf_b: weights.get("lnf_b")?,
            w_out: q("w_out")?,
            calib_stats: None,
            scheme_code: 0,
        })
    }

    fn qmatmul(&self, lin: &QuantizedLinear, x: &Matrix) -> Matrix {
        match self.path {
            QuantPath::PerToken => lin.forward_per_token(x, self.act_bits),
            QuantPath::CrossQuant { alpha } => lin.forward_crossquant(x, alpha, self.act_bits),
            QuantPath::CrossQuantStatic { .. } => lin.forward_crossquant_static(x, self.act_bits),
        }
    }

    /// The borrowed [`ModelView`] the shared block driver consumes.
    fn view(&self) -> ModelView<'_, QuantizedLinear> {
        ModelView {
            config: self.config,
            tok_emb: &self.tok_emb,
            pos_emb: &self.pos_emb,
            layers: self
                .layers
                .iter()
                .map(|l| LayerView {
                    ln1_g: &l.ln1_g,
                    ln1_b: &l.ln1_b,
                    wq: &l.wq,
                    wk: &l.wk,
                    wv: &l.wv,
                    wo: &l.wo,
                    ln2_g: &l.ln2_g,
                    ln2_b: &l.ln2_b,
                    w1: &l.w1,
                    w2: &l.w2,
                })
                .collect(),
            lnf_g: &self.lnf_g,
            lnf_b: &self.lnf_b,
            w_out: &self.w_out,
        }
    }

    /// Run the linear stack to logits, calling `observe(site, input)` with
    /// every quantization-site input before its integer matmuls (4 sites
    /// per layer — attn-in, attn-out, mlp-in, mlp-mid — plus the head
    /// site). The calibration capture hook; forwards pass a no-op.
    fn forward_logits_observed(
        &self,
        tokens: &[u32],
        observe: &mut dyn FnMut(usize, &Matrix),
    ) -> Result<Matrix> {
        let s = tokens.len();
        anyhow::ensure!(s >= 2 && s <= self.config.seq_len, "sequence length {s} out of range");
        block::forward_pass(
            &self.view(),
            tokens,
            None,
            false,
            &mut |lin, x| self.qmatmul(lin, x),
            &mut |site, x| {
                observe(site, &x);
                x
            },
        )
    }

    /// Full-logits forward (S × vocab) through the integer linear stack.
    pub fn forward_logits(&self, tokens: &[u32]) -> Result<Matrix> {
        self.forward_logits_observed(tokens, &mut |_, _| {})
    }

    /// Per-position NLL through the all-integer linear stack.
    pub fn forward_nll(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        let logits = self.forward_logits(tokens)?;
        Ok(block::nll_from_logits(&logits, tokens))
    }

    /// A fresh KV-cache decode state sized for this model.
    pub fn new_decode_state(&self) -> DecodeState {
        DecodeState::new(self.config.n_layers, self.config.seq_len, self.config.d_model)
    }

    pub(crate) fn forward_incremental_with(
        &self,
        tokens: &[u32],
        state: &mut DecodeState,
        last_logits_only: bool,
    ) -> Result<Matrix> {
        block::forward_pass(
            &self.view(),
            tokens,
            Some(state),
            last_logits_only,
            &mut |lin, x| self.qmatmul(lin, x),
            &mut |_, x| x,
        )
    }

    /// Incremental forward: append `tokens` after `state`'s cached prefix
    /// and return logits for the new rows only. Per-token decode drives
    /// the packed `quant::gemm` microkernel with M=1.
    pub fn forward_incremental(&self, tokens: &[u32], state: &mut DecodeState) -> Result<Matrix> {
        self.forward_incremental_with(tokens, state, false)
    }

    /// One continuous-batching decode step on the true-integer path:
    /// `tokens[i]` is the next token of the independent sequence cached in
    /// `states[i]`, and every linear site runs one packed int8 GEMM at
    /// M=N. Per-token and static-CrossQuant activation scales are per-row
    /// (row abs-maxima and calibration constants) and the i32 accumulation
    /// is exact, so the batched step is bit-identical to per-sequence M=1
    /// steps. The *dynamic* CrossQuant path is rejected: its live column
    /// maxima would couple the stacked sequences (serve dynamic CrossQuant
    /// through the native path, or calibrate static scales).
    pub fn forward_step_batched(
        &self,
        tokens: &[u32],
        states: &mut [&mut DecodeState],
    ) -> Result<Matrix> {
        anyhow::ensure!(
            !matches!(self.path, QuantPath::CrossQuant { .. }),
            "batched decode on the dynamic-CrossQuant integer path would couple sequences \
             through the live column maxima"
        );
        block::forward_step_batched(
            &self.view(),
            tokens,
            states,
            &mut |lin, x| self.qmatmul(lin, x),
            None,
        )
    }

    /// Greedy autoregressive generation on the true-integer path: prefill
    /// once (head applied to the last row only), then one-token decode
    /// steps through the packed int8 GEMM. Works for every [`QuantPath`],
    /// including `CrossQuantStatic` after
    /// [`QuantizedModel::calibrate_static`]. Returns the generated ids.
    pub fn generate_greedy(&self, prompt: &[u32], max_new_tokens: usize) -> Result<Vec<u32>> {
        let mut state = self.new_decode_state();
        block::generate_greedy_with(
            self.config.seq_len,
            prompt,
            max_new_tokens,
            &mut state,
            &mut |toks, st| self.forward_incremental_with(toks, st, true),
        )
    }

    /// Calibrate static CrossQuant scales: run the calibration sequences
    /// through the *dynamic* path, accumulate per-site column maxima
    /// ([`ColStats`]), fold ĉ^(1−α) into every linear **once**, and switch
    /// the model to [`QuantPath::CrossQuantStatic`]. Deployed forwards
    /// then pay zero per-batch weight rescale — per-token W8A8 cost plus
    /// one multiply per activation element.
    pub fn calibrate_static(&mut self, alpha: f32, calib: &[Vec<u32>]) -> Result<()> {
        anyhow::ensure!(!calib.is_empty(), "calibration needs at least one sequence");
        anyhow::ensure!(
            alpha.is_finite() && (0.0..=1.0).contains(&alpha),
            "calibration alpha must be in [0,1], got {alpha}"
        );
        let n_sites = 4 * self.layers.len() + 1;
        let mut stats: Vec<ColStats> = (0..n_sites).map(|_| ColStats::new()).collect();
        let saved = self.path;
        self.path = QuantPath::CrossQuant { alpha };
        let mut run = Ok(());
        for tokens in calib {
            let r = self.forward_logits_observed(tokens, &mut |site, x| stats[site].observe(x));
            if let Err(e) = r {
                run = Err(e);
                break;
            }
        }
        self.path = saved;
        run?;
        // ColStats propagates NaN by design; surface a corrupt
        // calibration run as an Err before any weights are folded
        for (site, s) in stats.iter().enumerate() {
            anyhow::ensure!(
                s.col_max().iter().all(|v| v.is_finite()),
                "calibration produced non-finite statistics at site {site}"
            );
        }
        let st = |cp: Vec<f32>| ScaleMode::Static { alpha, col_pow: cp };
        for (l, layer) in self.layers.iter_mut().enumerate() {
            let base = 4 * l;
            let cp = stats[base].col_pow(alpha);
            layer.wq.set_scale_mode(st(cp.clone()));
            layer.wk.set_scale_mode(st(cp.clone()));
            layer.wv.set_scale_mode(st(cp));
            layer.wo.set_scale_mode(st(stats[base + 1].col_pow(alpha)));
            layer.w1.set_scale_mode(st(stats[base + 2].col_pow(alpha)));
            layer.w2.set_scale_mode(st(stats[base + 3].col_pow(alpha)));
        }
        self.w_out.set_scale_mode(st(stats[n_sites - 1].col_pow(alpha)));
        self.calib_stats = Some(stats);
        self.path = QuantPath::CrossQuantStatic { alpha };
        Ok(())
    }

    /// Mutable access to every quantized linear together with its
    /// activation-site index (wq/wk/wv share 4l, wo 4l+1, w1 4l+2,
    /// w2 4l+3, the head 4L) — the hook the registry's GPTQ and LoRC
    /// build passes iterate.
    pub(crate) fn linear_slots_mut(&mut self) -> Vec<(String, usize, &mut QuantizedLinear)> {
        let mut slots = Vec::with_capacity(6 * self.layers.len() + 1);
        let n_layers = self.layers.len();
        for (l, layer) in self.layers.iter_mut().enumerate() {
            let base = 4 * l;
            slots.push((format!("layer{l}.wq"), base, &mut layer.wq));
            slots.push((format!("layer{l}.wk"), base, &mut layer.wk));
            slots.push((format!("layer{l}.wv"), base, &mut layer.wv));
            slots.push((format!("layer{l}.wo"), base + 1, &mut layer.wo));
            slots.push((format!("layer{l}.w1"), base + 2, &mut layer.w1));
            slots.push((format!("layer{l}.w2"), base + 3, &mut layer.w2));
        }
        slots.push(("w_out".to_string(), 4 * n_layers, &mut self.w_out));
        slots
    }

    /// The (name, layer) pairs of every quantized linear, in artifact
    /// section order — one definition, so the writer can never drift
    /// from the layer structure.
    fn linear_slots(&self) -> Vec<(String, &QuantizedLinear)> {
        let mut slots = Vec::with_capacity(6 * self.layers.len() + 1);
        for (l, layer) in self.layers.iter().enumerate() {
            for (slot, lin) in [
                ("wq", &layer.wq),
                ("wk", &layer.wk),
                ("wv", &layer.wv),
                ("wo", &layer.wo),
                ("w1", &layer.w1),
                ("w2", &layer.w2),
            ] {
                slots.push((format!("layer{l}.{slot}"), lin));
            }
        }
        slots.push(("w_out".to_string(), &self.w_out));
        slots
    }

    /// Persist the calibrated model as a `.cqa` deployment artifact (see
    /// `quant::artifact` for the byte layout): folded int8/int4 panels,
    /// folded scales, activation-side column factors, FP embeddings + LN
    /// affines, and the raw calibration column maxima. Requires
    /// [`QuantizedModel::calibrate_static`] (or an artifact load) first.
    /// Returns the number of sections written.
    pub fn write_artifact(&self, path: &Path) -> Result<usize> {
        let alpha = match self.path {
            QuantPath::CrossQuantStatic { alpha } => alpha,
            _ => anyhow::bail!(
                "write_artifact requires a calibrated static model \
                 (run calibrate_static first)"
            ),
        };
        let stats = self
            .calib_stats
            .as_ref()
            .ok_or_else(|| anyhow!("no calibration statistics retained"))?;
        let mut w = ArtifactWriter::new(self.config, alpha, self.weight_bits, self.act_bits);
        w.set_scheme(self.scheme_code);
        w.add_matrix("tok_emb", &self.tok_emb)?;
        w.add_matrix("pos_emb", &self.pos_emb)?;
        for (l, layer) in self.layers.iter().enumerate() {
            w.add_matrix(&format!("layer{l}.ln1_g"), &layer.ln1_g)?;
            w.add_matrix(&format!("layer{l}.ln1_b"), &layer.ln1_b)?;
            w.add_matrix(&format!("layer{l}.ln2_g"), &layer.ln2_g)?;
            w.add_matrix(&format!("layer{l}.ln2_b"), &layer.ln2_b)?;
        }
        w.add_matrix("lnf_g", &self.lnf_g)?;
        w.add_matrix("lnf_b", &self.lnf_b)?;
        for (name, lin) in self.linear_slots() {
            let (_, col_pow, panels, scale) = lin
                .static_parts()
                .ok_or_else(|| anyhow!("linear '{name}' has no static fold"))?;
            w.add_panels(&format!("{name}.panels"), panels)?;
            w.add_f32(&format!("{name}.scale"), 1, scale.len(), scale)?;
            w.add_f32(&format!("{name}.colpow"), 1, col_pow.len(), col_pow)?;
            // LoRC correction pair rides along in fixed position so a
            // load → save round-trip reproduces the bytes exactly
            if let Some((u, v)) = lin.lorc() {
                w.add_matrix(&format!("{name}.lorc_u"), u)?;
                w.add_matrix(&format!("{name}.lorc_v"), v)?;
            }
        }
        for (i, s) in stats.iter().enumerate() {
            w.add_f32(&format!("site{i}.colmax"), 1, s.col_max().len(), s.col_max())?;
        }
        let sections = w.section_count();
        w.write(path)?;
        Ok(sections)
    }

    /// Rebuild a serving model from an opened `.cqa` artifact — **no FP
    /// weights, no calibration**: the folded int8 panels are borrowed
    /// straight from the file mapping (zero copy; INT4 nibbles decode to
    /// owned buffers), and the model comes up already on
    /// [`QuantPath::CrossQuantStatic`]. Bit-identical to the in-memory
    /// `calibrate_static` model it was written from (pinned by
    /// rust/tests/artifact.rs).
    pub fn from_artifact(art: &Artifact) -> Result<QuantizedModel> {
        let cfg = art.config;
        let alpha = art.alpha;
        anyhow::ensure!(
            alpha.is_finite() && (0.0..=1.0).contains(&alpha),
            "artifact alpha {alpha} out of range"
        );
        anyhow::ensure!(
            cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0,
            "artifact config: d_model {} is not divisible by n_heads {}",
            cfg.d_model,
            cfg.n_heads
        );
        let mat = |name: &str, rows: usize, cols: usize| -> Result<Matrix> {
            let m = art.matrix(name)?;
            anyhow::ensure!(
                (m.rows, m.cols) == (rows, cols),
                "section '{name}': shape {}x{} does not match the config's {rows}x{cols}",
                m.rows,
                m.cols
            );
            Ok(m)
        };
        let lin = |name: &str, in_dim: usize, out_dim: usize| -> Result<QuantizedLinear> {
            let panels = art.panels(&format!("{name}.panels"))?;
            anyhow::ensure!(
                (panels.k, panels.n) == (in_dim, out_dim),
                "section '{name}.panels': shape {}x{} does not match the config's \
                 {in_dim}x{out_dim}",
                panels.k,
                panels.n
            );
            let mut q = QuantizedLinear::from_static_parts(
                art.weight_bits,
                alpha,
                art.f32_vec(&format!("{name}.colpow"))?,
                panels,
                art.f32_vec(&format!("{name}.scale"))?,
            )
            .with_context(|| format!("rebuilding linear '{name}'"))?;
            if art.section(&format!("{name}.lorc_u")).is_ok() {
                let u = art.matrix(&format!("{name}.lorc_u"))?;
                let v = art.matrix(&format!("{name}.lorc_v"))?;
                anyhow::ensure!(
                    u.rows == in_dim && v.cols == out_dim && u.cols == v.rows,
                    "section '{name}.lorc_u/v': rank-r pair {}x{} · {}x{} does not \
                     correct a {in_dim}x{out_dim} linear",
                    u.rows,
                    u.cols,
                    v.rows,
                    v.cols
                );
                q.set_lorc(u, v);
            }
            Ok(q)
        };
        let d = cfg.d_model;
        let layers = (0..cfg.n_layers)
            .map(|l| -> Result<QLayer> {
                Ok(QLayer {
                    ln1_g: mat(&format!("layer{l}.ln1_g"), 1, d)?,
                    ln1_b: mat(&format!("layer{l}.ln1_b"), 1, d)?,
                    wq: lin(&format!("layer{l}.wq"), d, d)?,
                    wk: lin(&format!("layer{l}.wk"), d, d)?,
                    wv: lin(&format!("layer{l}.wv"), d, d)?,
                    wo: lin(&format!("layer{l}.wo"), d, d)?,
                    ln2_g: mat(&format!("layer{l}.ln2_g"), 1, d)?,
                    ln2_b: mat(&format!("layer{l}.ln2_b"), 1, d)?,
                    w1: lin(&format!("layer{l}.w1"), d, cfg.d_ff)?,
                    w2: lin(&format!("layer{l}.w2"), cfg.d_ff, d)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let n_sites = cfg.n_quant_sites();
        let calib_stats = (0..n_sites)
            .map(|i| Ok(ColStats::from_col_max(art.f32_vec(&format!("site{i}.colmax"))?)))
            .collect::<Result<Vec<_>>>()?;
        Ok(QuantizedModel {
            config: cfg,
            weight_bits: art.weight_bits,
            act_bits: art.act_bits,
            path: QuantPath::CrossQuantStatic { alpha },
            tok_emb: mat("tok_emb", cfg.vocab, d)?,
            pos_emb: mat("pos_emb", cfg.seq_len, d)?,
            layers,
            lnf_g: mat("lnf_g", 1, d)?,
            lnf_b: mat("lnf_b", 1, d)?,
            w_out: lin("w_out", d, cfg.vocab)?,
            calib_stats: Some(calib_stats),
            scheme_code: art.scheme,
        })
    }

    /// [`Artifact::open`] + [`QuantizedModel::from_artifact`] in one step
    /// — the serving cold-start path benchmarked in
    /// benches/artifact_load.rs.
    pub fn load_artifact(path: &Path) -> Result<QuantizedModel> {
        let art = Artifact::open(path)?;
        Self::from_artifact(&art)
    }

    /// Total integer-weight payload bytes across the model.
    pub fn weight_payload_bytes(&self) -> usize {
        let mut total = self.w_out.payload_bytes();
        for l in &self.layers {
            total += l.wq.payload_bytes()
                + l.wk.payload_bytes()
                + l.wv.payload_bytes()
                + l.wo.payload_bytes()
                + l.w1.payload_bytes()
                + l.w2.payload_bytes();
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::synthetic_weights;
    use crate::model::{IdentitySite, NativeModel};

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 20,
            eval_batch: 2,
        }
    }

    fn toks() -> Vec<u32> {
        (0..20).map(|i| (i * 7 % 64) as u32).collect()
    }

    #[test]
    fn integer_w8a8_close_to_fp() {
        let w = synthetic_weights(cfg(), 21);
        let fp = NativeModel::new(w.clone());
        let qm = QuantizedModel::new(
            &w,
            Bits::Int8,
            Bits::Int8,
            QuantPath::CrossQuant { alpha: 0.15 },
        )
        .unwrap();
        let nll_fp = fp.forward_nll(&toks(), &mut IdentitySite).unwrap();
        let nll_q = qm.forward_nll(&toks()).unwrap();
        let mean_fp: f32 = nll_fp.iter().sum::<f32>() / nll_fp.len() as f32;
        let mean_q: f32 = nll_q.iter().sum::<f32>() / nll_q.len() as f32;
        assert!((mean_fp - mean_q).abs() < 0.1, "fp {mean_fp} int {mean_q}");
    }

    #[test]
    fn integer_path_matches_fake_quant_eval() {
        use crate::model::quantized::{quantize_weights, WeightScheme};
        use crate::model::QuantSite;
        use crate::quant::per_token::PerToken;
        let base = synthetic_weights(cfg(), 22);
        // fake-quant protocol
        let mut wq = base.clone();
        quantize_weights(&mut wq, WeightScheme::PerChannel(Bits::Int8)).unwrap();
        let fake = NativeModel::new(wq);
        let mut site = QuantSite::new(PerToken::new(Bits::Int8));
        let nll_fake = fake.forward_nll(&toks(), &mut site).unwrap();
        // integer protocol (quantization sites coincide: every linear input)
        let qm = QuantizedModel::new(&base, Bits::Int8, Bits::Int8, QuantPath::PerToken).unwrap();
        let nll_int = qm.forward_nll(&toks()).unwrap();
        for (a, b) in nll_fake.iter().zip(&nll_int) {
            assert!((a - b).abs() < 0.05, "fake {a} int {b}");
        }
    }

    #[test]
    fn static_scales_track_dynamic_nll() {
        let w = synthetic_weights(cfg(), 23);
        let mut qm = QuantizedModel::new(
            &w,
            Bits::Int8,
            Bits::Int8,
            QuantPath::CrossQuant { alpha: 0.15 },
        )
        .unwrap();
        let nll_dyn = qm.forward_nll(&toks()).unwrap();
        // calibration stream drawn from the same token process as eval
        let calib: Vec<Vec<u32>> = (0..8)
            .map(|s| (0..20).map(|i| ((i * 7 + s * 11) % 64) as u32).collect())
            .collect();
        qm.calibrate_static(0.15, &calib).unwrap();
        assert!(matches!(qm.path, QuantPath::CrossQuantStatic { .. }));
        let nll_st = qm.forward_nll(&toks()).unwrap();
        let mean_dyn: f32 = nll_dyn.iter().sum::<f32>() / nll_dyn.len() as f32;
        let mean_st: f32 = nll_st.iter().sum::<f32>() / nll_st.len() as f32;
        let rel = (mean_dyn - mean_st).abs() / mean_dyn.max(1e-6);
        assert!(rel < 0.02, "static NLL {mean_st} vs dynamic {mean_dyn} (rel {rel})");
    }

    #[test]
    fn batched_integer_step_bit_identical_to_sequential() {
        let w = synthetic_weights(cfg(), 27);
        let mut qm = QuantizedModel::new(
            &w,
            Bits::Int8,
            Bits::Int8,
            QuantPath::CrossQuant { alpha: 0.15 },
        )
        .unwrap();
        let calib: Vec<Vec<u32>> = (0..8)
            .map(|s| (0..20).map(|i| ((i * 5 + s * 13) % 64) as u32).collect())
            .collect();
        qm.calibrate_static(0.15, &calib).unwrap();
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[60, 61], &[4; 5]];
        let mut ref_logits = Vec::new();
        for p in prompts {
            let mut st = qm.new_decode_state();
            qm.forward_incremental_with(p, &mut st, true).unwrap();
            ref_logits.push(qm.forward_incremental_with(&[8], &mut st, false).unwrap());
        }
        let mut states: Vec<DecodeState> = prompts
            .iter()
            .map(|p| {
                let mut st = qm.new_decode_state();
                qm.forward_incremental_with(p, &mut st, true).unwrap();
                st
            })
            .collect();
        let mut refs: Vec<&mut DecodeState> = states.iter_mut().collect();
        let logits = qm.forward_step_batched(&[8, 8, 8], &mut refs).unwrap();
        for (i, r) in ref_logits.iter().enumerate() {
            assert_eq!(logits.row(i), r.row(0), "sequence {i} must be bit-exact");
        }
        // the dynamic path is rejected, not silently batch-coupled
        let qdyn = QuantizedModel::new(
            &w,
            Bits::Int8,
            Bits::Int8,
            QuantPath::CrossQuant { alpha: 0.15 },
        )
        .unwrap();
        let mut st = qdyn.new_decode_state();
        qdyn.forward_incremental_with(&[1, 2], &mut st, true).unwrap();
        let mut refs: Vec<&mut DecodeState> = vec![&mut st];
        assert!(qdyn.forward_step_batched(&[3], &mut refs).is_err());
    }

    #[test]
    fn wide_grids_are_rejected_at_construction() {
        // Bits::Other(12+) is fake-quant-legal but not i8-representable:
        // must be an Err here, not a panic on the first forward
        let w = synthetic_weights(cfg(), 26);
        let bad_act = QuantizedModel::new(&w, Bits::Int8, Bits::Other(12), QuantPath::PerToken);
        assert!(bad_act.is_err());
        let bad_w = QuantizedModel::new(&w, Bits::Other(16), Bits::Int8, QuantPath::PerToken);
        assert!(bad_w.is_err());
    }

    #[test]
    fn uncalibrated_static_path_is_rejected_at_construction() {
        let w = synthetic_weights(cfg(), 25);
        let r = QuantizedModel::new(
            &w,
            Bits::Int8,
            Bits::Int8,
            QuantPath::CrossQuantStatic { alpha: 0.15 },
        );
        assert!(r.is_err(), "static path without calibration must not construct");
    }

    #[test]
    fn calibration_restores_path_on_error() {
        let w = synthetic_weights(cfg(), 24);
        let mut qm =
            QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::PerToken).unwrap();
        // sequence longer than seq_len ⇒ calibration must fail cleanly
        let bad = vec![(0..64).map(|i| (i % 64) as u32).collect::<Vec<u32>>()];
        assert!(qm.calibrate_static(0.15, &bad).is_err());
        assert!(matches!(qm.path, QuantPath::PerToken));
    }

    #[test]
    fn payload_accounting() {
        let w = synthetic_weights(cfg(), 23);
        let q8 = QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::PerToken).unwrap();
        let q4 = QuantizedModel::new(&w, Bits::Int4, Bits::Int8, QuantPath::PerToken).unwrap();
        assert_eq!(q4.weight_payload_bytes() * 2, q8.weight_payload_bytes());
    }
}
