//! Quantization-kernel analysis engine — the paper's diagnostic lens (§4).

pub mod kernel;
pub mod stats;
pub mod threshold;

pub use kernel::{
    kernel_fraction, kernel_fraction_threads, kernel_mask, quantize_with_report,
    quantize_with_report_threads, KernelReport,
};
pub use stats::CrossStats;
