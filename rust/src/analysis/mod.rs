//! Quantization-kernel analysis engine — the paper's diagnostic lens (§4).

pub mod kernel;
pub mod stats;
pub mod threshold;

pub use kernel::{kernel_fraction, kernel_mask, KernelReport};
pub use stats::CrossStats;
