//! Threshold machinery for §4.3 (Figures 6/7): sweep the removed-kernel
//! fraction, record perplexity, and locate the largest kernel proportion
//! whose degradation stays within a tolerance of the FP baseline.

/// One point on a Figure-6/7 curve.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub kernel_fraction: f32,
    pub perplexity: f64,
}

/// Result of a threshold sweep.
#[derive(Clone, Debug)]
pub struct ThresholdCurve {
    pub points: Vec<SweepPoint>,
    pub fp_perplexity: f64,
}

impl ThresholdCurve {
    /// Run `eval(fraction) -> ppl` over a fraction grid.
    pub fn sweep(fractions: &[f32], fp_perplexity: f64, mut eval: impl FnMut(f32) -> f64) -> Self {
        let points = fractions
            .iter()
            .map(|&f| SweepPoint { kernel_fraction: f, perplexity: eval(f) })
            .collect();
        ThresholdCurve { points, fp_perplexity }
    }

    /// The paper's threshold: the largest kernel fraction whose perplexity
    /// stays within `rel_tol` (e.g. 0.05 = 5 %) of the FP baseline. Returns
    /// None if even the smallest sweep point exceeds the tolerance.
    pub fn threshold(&self, rel_tol: f64) -> Option<f32> {
        let limit = self.fp_perplexity * (1.0 + rel_tol);
        let mut best: Option<f32> = None;
        for p in &self.points {
            if p.perplexity <= limit {
                best = Some(best.map_or(p.kernel_fraction, |b: f32| b.max(p.kernel_fraction)));
            }
        }
        best
    }

    /// Is perplexity (weakly) increasing in kernel fraction? (The paper's
    /// "positive correlation" observation — checked with a small slack to
    /// absorb eval noise.)
    pub fn is_monotone(&self, slack: f64) -> bool {
        let mut sorted = self.points.clone();
        sorted.sort_by(|a, b| a.kernel_fraction.total_cmp(&b.kernel_fraction));
        sorted.windows(2).all(|w| w[1].perplexity >= w[0].perplexity * (1.0 - slack))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_curve() -> ThresholdCurve {
        // ppl flat until 0.2, then exploding — an OPT-like knee
        ThresholdCurve::sweep(&[0.0, 0.05, 0.1, 0.2, 0.3, 0.4], 10.0, |f| {
            if f <= 0.2 {
                10.0 + f as f64
            } else {
                10.0 + ((f as f64 - 0.2) * 100.0).exp()
            }
        })
    }

    #[test]
    fn finds_knee() {
        let c = synthetic_curve();
        let th = c.threshold(0.05).unwrap();
        assert!((th - 0.2).abs() < 1e-6, "{th}");
    }

    #[test]
    fn monotone_detection() {
        let c = synthetic_curve();
        assert!(c.is_monotone(0.01));
        let bad = ThresholdCurve::sweep(&[0.0, 0.1, 0.2], 10.0, |f| {
            if f > 0.05 { 5.0 } else { 20.0 }
        });
        assert!(!bad.is_monotone(0.01));
    }

    #[test]
    fn none_when_all_points_exceed() {
        let c = ThresholdCurve::sweep(&[0.1, 0.2], 10.0, |_| 100.0);
        assert_eq!(c.threshold(0.05), None);
    }
}
