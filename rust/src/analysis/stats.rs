//! Cross-scale statistics — the quantities of Table 1 and §4.2's proof.
//!
//! For a matrix X and exponent α, Table 1 reports:
//!   * the fraction of (i,j) with c_j ≥ t_i          (Case II, B̃ can grow)
//!   * the fraction of (i,j) with B̃_ij < B_ij       (Case I, kernel shrinks)
//!   * the resulting CrossQuant kernel proportion.

use crate::quant::{crossquant::CrossQuant, per_token::PerToken, ActQuantizer, Bits, EPS};
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct CrossStats {
    pub alpha: f32,
    /// P[c_j ≥ t_i] over all elements.
    pub frac_col_ge_row: f32,
    /// P[B̃_ij < B_ij] over all elements (undefined at α=1; reported as 0).
    pub frac_bound_smaller: f32,
    /// CrossQuant kernel proportion at this α.
    pub kernel_fraction: f32,
    /// Per-token kernel proportion (α-independent, for reference).
    pub per_token_kernel_fraction: f32,
}

impl CrossStats {
    pub fn compute(x: &Matrix, alpha: f32, bits: Bits) -> CrossStats {
        let t = x.row_abs_max();
        let c = x.col_abs_max();

        let mut n_col_ge_row = 0usize;
        let mut n_bound_smaller = 0usize;
        for &ti in &t {
            for &cj in &c {
                if cj >= ti {
                    n_col_ge_row += 1;
                }
                // B̃ < B ⇔ t^α c^(1−α) < t ⇔ c < t (for α<1)
                let ti_e = ti.max(EPS);
                let cj_e = cj.max(EPS);
                if alpha < 1.0 && ti_e.powf(alpha) * cj_e.powf(1.0 - alpha) < ti_e {
                    n_bound_smaller += 1;
                }
            }
        }
        let total = (t.len() * c.len()).max(1);

        let cq = CrossQuant::new(alpha, bits);
        let pt = PerToken::new(bits);
        CrossStats {
            alpha,
            frac_col_ge_row: n_col_ge_row as f32 / total as f32,
            frac_bound_smaller: if alpha < 1.0 {
                n_bound_smaller as f32 / total as f32
            } else {
                0.0
            },
            kernel_fraction: super::kernel_fraction(x, &cq.delta_field(x)),
            per_token_kernel_fraction: super::kernel_fraction(x, &pt.delta_field(x)),
        }
    }
}

/// Outlier statistics of an activation matrix (Appendix A's premise).
#[derive(Clone, Debug)]
pub struct OutlierStats {
    /// Fraction of elements with |x| > 20 × mean|x| (Dettmers' criterion).
    pub outlier_fraction: f32,
    /// max|x| / median of column absmaxes — the "how rogue" ratio.
    pub max_over_median_col: f32,
}

impl OutlierStats {
    pub fn compute(x: &Matrix) -> OutlierStats {
        let mean_abs =
            (x.data.iter().map(|v| v.abs() as f64).sum::<f64>() / x.len().max(1) as f64) as f32;
        let outliers = x.data.iter().filter(|v| v.abs() > 20.0 * mean_abs).count() as f32
            / x.len().max(1) as f32;
        let mut c = x.col_abs_max();
        c.sort_by(f32::total_cmp);
        let med = if c.is_empty() { 0.0 } else { c[c.len() / 2] };
        let max = c.last().copied().unwrap_or(0.0);
        OutlierStats {
            outlier_fraction: outliers,
            max_over_median_col: if med > 0.0 { max / med } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn outlier_matrix() -> Matrix {
        let mut rng = SplitMix64::new(41);
        let mut x = Matrix::randn(96, 96, 1.0, &mut rng);
        for i in 0..x.rows {
            let v = x.get(i, 0) * 50.0;
            x.set(i, 0, v);
        }
        x
    }

    #[test]
    fn case_two_is_rare_with_outlier_columns() {
        // When every row contains the outlier column, t_i is large, so few
        // columns satisfy c_j ≥ t_i — the paper's ~3% claim regime.
        let x = outlier_matrix();
        let s = CrossStats::compute(&x, 0.15, Bits::Int8);
        assert!(s.frac_col_ge_row < 0.1, "{}", s.frac_col_ge_row);
        assert!(s.frac_bound_smaller > 0.9, "{}", s.frac_bound_smaller);
    }

    #[test]
    fn kernel_shrinks_vs_per_token() {
        let x = outlier_matrix();
        let s = CrossStats::compute(&x, 0.15, Bits::Int8);
        assert!(s.kernel_fraction < s.per_token_kernel_fraction);
    }

    #[test]
    fn alpha_one_matches_per_token_kernel() {
        let x = outlier_matrix();
        let s = CrossStats::compute(&x, 1.0, Bits::Int8);
        assert!((s.kernel_fraction - s.per_token_kernel_fraction).abs() < 5e-3);
        assert_eq!(s.frac_bound_smaller, 0.0);
    }

    #[test]
    fn outlier_stats_detects_injection() {
        let x = outlier_matrix();
        let o = OutlierStats::compute(&x);
        assert!(o.max_over_median_col > 10.0);
        let mut rng = SplitMix64::new(5);
        let clean = Matrix::randn(96, 96, 1.0, &mut rng);
        let oc = OutlierStats::compute(&clean);
        assert!(oc.max_over_median_col < 3.0);
    }
}
