//! Quantization kernel K(Q) — Definition 1 of the paper.
//!
//! K(Q) = { X_ij | Q(X_ij) = 0 } ⇔ |X_ij| < B_ij = 0.5·Δ_ij  (eq. 4),
//! restricted to non-zero elements (a structural zero loses nothing).

use crate::quant::{ActQuantizer, DeltaField};
use crate::tensor::Matrix;

/// Boolean membership mask of the quantization kernel.
pub fn kernel_mask(x: &Matrix, field: &DeltaField) -> Vec<bool> {
    let mut mask = Vec::with_capacity(x.len());
    for i in 0..x.rows {
        for (j, &v) in x.row(i).iter().enumerate() {
            mask.push(v != 0.0 && v.abs() < field.zero_bound(i, j));
        }
    }
    mask
}

/// |K(Q)| / |X| — the paper's headline statistic (Figure 4 y-axis).
///
/// Specialised per scale-field variant (hoisting the per-row factor and
/// keeping the inner loop branchless) — this scan runs over every
/// activation of every eval batch in the analysis figures, so it is a §Perf
/// hot path.
pub fn kernel_fraction(x: &Matrix, field: &DeltaField) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    let mut count = 0usize;
    match field {
        DeltaField::PerRow(rows) => {
            for i in 0..x.rows {
                let bound = 0.5 * rows[i];
                count += x
                    .row(i)
                    .iter()
                    .map(|&v| (v != 0.0 && v.abs() < bound) as usize)
                    .sum::<usize>();
            }
        }
        DeltaField::PerCol(cols) => {
            for i in 0..x.rows {
                count += x
                    .row(i)
                    .iter()
                    .zip(cols)
                    .map(|(&v, &d)| (v != 0.0 && v.abs() < 0.5 * d) as usize)
                    .sum::<usize>();
            }
        }
        DeltaField::Cross { row_pow, col_pow } => {
            for i in 0..x.rows {
                let half_rp = 0.5 * row_pow[i];
                count += x
                    .row(i)
                    .iter()
                    .zip(col_pow)
                    .map(|(&v, &cp)| (v != 0.0 && v.abs() < half_rp * cp) as usize)
                    .sum::<usize>();
            }
        }
    }
    count as f32 / x.len() as f32
}

/// Full per-matrix kernel diagnostics for one quantization scheme.
#[derive(Clone, Debug)]
pub struct KernelReport {
    pub scheme: String,
    pub fraction: f32,
    pub count: usize,
    pub total: usize,
    /// Mean |x| of kernel members (how much magnitude is being destroyed).
    pub mean_abs_kernel: f32,
    /// Mean |x| of survivors.
    pub mean_abs_rest: f32,
}

impl KernelReport {
    pub fn compute(x: &Matrix, quant: &dyn ActQuantizer) -> KernelReport {
        let field = quant.delta_field(x);
        let mut count = 0usize;
        let (mut sum_k, mut sum_r) = (0.0f64, 0.0f64);
        let mut n_r = 0usize;
        for i in 0..x.rows {
            for (j, &v) in x.row(i).iter().enumerate() {
                if v != 0.0 && v.abs() < field.zero_bound(i, j) {
                    count += 1;
                    sum_k += v.abs() as f64;
                } else {
                    n_r += 1;
                    sum_r += v.abs() as f64;
                }
            }
        }
        KernelReport {
            scheme: quant.name(),
            fraction: count as f32 / x.len().max(1) as f32,
            count,
            total: x.len(),
            mean_abs_kernel: if count > 0 { (sum_k / count as f64) as f32 } else { 0.0 },
            mean_abs_rest: if n_r > 0 { (sum_r / n_r as f64) as f32 } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{crossquant::CrossQuant, per_token::PerToken, Bits};
    use crate::tensor::{Matrix, SplitMix64};

    /// Definition-1 equivalence: the mask predicted from the zero bound
    /// must exactly match the set of elements the quantizer maps to zero.
    #[test]
    fn mask_equals_actual_zeros() {
        let mut rng = SplitMix64::new(31);
        let x = Matrix::randn(64, 48, 1.0, &mut rng);
        for quant in [CrossQuant::new(0.15, Bits::Int8), CrossQuant::new(0.6, Bits::Int4)] {
            let field = quant.delta_field(&x);
            let mask = kernel_mask(&x, &field);
            let q = quant.fake_quant(&x);
            for (idx, &m) in mask.iter().enumerate() {
                let zeroed = q.data[idx] == 0.0 && x.data[idx] != 0.0;
                assert_eq!(m, zeroed, "idx {idx} x={}", x.data[idx]);
            }
        }
    }

    #[test]
    fn fraction_counts_match_mask() {
        let mut rng = SplitMix64::new(32);
        let x = Matrix::randn(40, 40, 1.0, &mut rng);
        let q = PerToken::new(Bits::Int8);
        let field = q.delta_field(&x);
        let frac = kernel_fraction(&x, &field);
        let mask_count = kernel_mask(&x, &field).iter().filter(|&&b| b).count();
        assert!((frac - mask_count as f32 / x.len() as f32).abs() < 1e-7);
    }

    #[test]
    fn report_partitions_elements() {
        let mut rng = SplitMix64::new(33);
        let x = Matrix::randn(32, 32, 1.0, &mut rng);
        let r = KernelReport::compute(&x, &PerToken::new(Bits::Int4));
        assert_eq!(r.total, 1024);
        assert!(r.fraction >= 0.0 && r.fraction <= 1.0);
        // kernel members are by construction smaller on average
        if r.count > 0 {
            assert!(r.mean_abs_kernel < r.mean_abs_rest);
        }
    }
}
