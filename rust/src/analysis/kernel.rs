//! Quantization kernel K(Q) — Definition 1 of the paper.
//!
//! K(Q) = { X_ij | Q(X_ij) = 0 } ⇔ |X_ij| < B_ij = 0.5·Δ_ij  (eq. 4),
//! restricted to non-zero elements (a structural zero loses nothing).
//!
//! The scans here run over every activation of every eval batch, so they
//! are §Perf hot paths: all of them are row-parallel (see
//! [`crate::tensor::par`]), and [`quantize_with_report`] fuses the
//! fake-quant sweep with the kernel statistics so the eval harness pays
//! one pass over the matrix instead of three.

use crate::quant::{fake_quant_row, ActQuantizer, DeltaField};
use crate::tensor::{par, Matrix};

/// Boolean membership mask of the quantization kernel.
pub fn kernel_mask(x: &Matrix, field: &DeltaField) -> Vec<bool> {
    let mut mask = Vec::with_capacity(x.len());
    for i in 0..x.rows {
        for (j, &v) in x.row(i).iter().enumerate() {
            mask.push(v != 0.0 && v.abs() < field.zero_bound(i, j));
        }
    }
    mask
}

/// |K(Q)| / |X| — the paper's headline statistic (Figure 4 y-axis).
///
/// Row-parallel; counts are integers, so any worker count produces the
/// identical result ([`kernel_fraction_threads`]`(x, field, 1)` is the
/// serial reference).
pub fn kernel_fraction(x: &Matrix, field: &DeltaField) -> f32 {
    kernel_fraction_threads(x, field, par::workers_for(x.rows, x.len()))
}

/// [`kernel_fraction`] with an explicit worker count.
pub fn kernel_fraction_threads(x: &Matrix, field: &DeltaField, workers: usize) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    let counts = par::par_map_rows(x.rows, workers, |range| {
        let mut count = 0usize;
        for i in range {
            count += kernel_count_row(x.row(i), field, i);
        }
        count
    });
    counts.into_iter().sum::<usize>() as f32 / x.len() as f32
}

/// Per-row kernel count — the same classification expression as the
/// fused/report paths ([`for_each_delta`] walking Δ, the eq.-4 bound
/// 0.5·Δ), so `kernel_fraction` and `KernelReport::count` can never
/// disagree. The delta walker is specialised per field variant, so the
/// per-row factor still hoists and the loop stays branchless.
#[inline]
fn kernel_count_row(row: &[f32], field: &DeltaField, i: usize) -> usize {
    let mut count = 0usize;
    for_each_delta(field, i, row.len(), |j, d| {
        let v = row[j];
        count += (v != 0.0 && v.abs() < 0.5 * d) as usize;
    });
    count
}

/// Running kernel statistics of one worker's row block.
#[derive(Clone, Copy, Default)]
struct KernelPartial {
    count: usize,
    n_rest: usize,
    sum_kernel: f64,
    sum_rest: f64,
}

impl KernelPartial {
    /// Classify one element against its zero bound 0.5·Δ (eq. 4).
    #[inline(always)]
    fn add(&mut self, v: f32, d: f32) {
        let a = v.abs();
        if v != 0.0 && a < 0.5 * d {
            self.count += 1;
            self.sum_kernel += a as f64;
        } else {
            self.n_rest += 1;
            self.sum_rest += a as f64;
        }
    }

    fn merge(mut self, o: KernelPartial) -> KernelPartial {
        self.count += o.count;
        self.n_rest += o.n_rest;
        self.sum_kernel += o.sum_kernel;
        self.sum_rest += o.sum_rest;
        self
    }
}

/// Walk one row's per-element deltas Δ_ij, specialised per field variant.
#[inline(always)]
fn for_each_delta(field: &DeltaField, i: usize, cols: usize, mut f: impl FnMut(usize, f32)) {
    match field {
        DeltaField::PerRow(rows) => {
            let d = rows[i];
            for j in 0..cols {
                f(j, d);
            }
        }
        DeltaField::PerCol(col_d) => {
            for (j, &d) in col_d.iter().enumerate().take(cols) {
                f(j, d);
            }
        }
        DeltaField::Cross { row_pow, col_pow } => {
            let rp = row_pow[i];
            for (j, &cp) in col_pow.iter().enumerate().take(cols) {
                f(j, rp * cp);
            }
        }
    }
}

/// Fused single-pass quantize + kernel analysis: computes the delta field
/// once, then produces the fake-quant output *and* the full
/// [`KernelReport`] in one sweep over the matrix — where the separate
/// path (`delta_field` + `fake_quant` + `KernelReport::compute`) walks it
/// three times and derives the scale field twice. This is the hot call of
/// the eval harness ([`crate::model::QuantSite`] runs it at every
/// activation site), the experiment drivers, and the coordinator's native
/// executor.
///
/// The fake-quant half routes through the same per-row kernel as
/// [`crate::quant::fake_quant_with`], so the output matrix is bit-exact
/// with the separate path; counts are exact integers, and the two mean
/// statistics differ from the serial order only by f64 summation
/// regrouping (pinned to ≤1e-6 relative in rust/tests/parallel.rs).
pub fn quantize_with_report(x: &Matrix, quant: &dyn ActQuantizer) -> (Matrix, KernelReport) {
    quantize_with_report_threads(x, quant, par::workers_for(x.rows, x.len()))
}

/// [`quantize_with_report`] with an explicit worker count.
pub fn quantize_with_report_threads(
    x: &Matrix,
    quant: &dyn ActQuantizer,
    workers: usize,
) -> (Matrix, KernelReport) {
    let field = quant.delta_field(x);
    let qmax = quant.qmax();
    let cols = x.cols;
    let mut out = Matrix::zeros(x.rows, x.cols);
    let partials = par::par_rows_map_mut(&mut out.data, cols.max(1), workers, |row0, chunk| {
        let mut p = KernelPartial::default();
        for (local_i, dst) in chunk.chunks_mut(cols.max(1)).enumerate() {
            let i = row0 + local_i;
            let src = x.row(i);
            fake_quant_row(src, dst, &field, i, qmax);
            for_each_delta(&field, i, cols, |j, d| p.add(src[j], d));
        }
        p
    });
    let total = partials.into_iter().fold(KernelPartial::default(), KernelPartial::merge);
    (out, KernelReport::from_partial(quant.name(), x.len(), total))
}

/// Full per-matrix kernel diagnostics for one quantization scheme.
#[derive(Clone, Debug)]
pub struct KernelReport {
    pub scheme: String,
    pub fraction: f32,
    pub count: usize,
    pub total: usize,
    /// Mean |x| of kernel members (how much magnitude is being destroyed).
    pub mean_abs_kernel: f32,
    /// Mean |x| of survivors.
    pub mean_abs_rest: f32,
}

impl KernelReport {
    /// Statistics-only scan (row-parallel, no output matrix). Use
    /// [`quantize_with_report`] when the fake-quant output is needed too.
    pub fn compute(x: &Matrix, quant: &dyn ActQuantizer) -> KernelReport {
        let field = quant.delta_field(x);
        let partials = par::par_map_rows(x.rows, par::workers_for(x.rows, x.len()), |range| {
            let mut p = KernelPartial::default();
            for i in range {
                let row = x.row(i);
                for_each_delta(&field, i, row.len(), |j, d| p.add(row[j], d));
            }
            p
        });
        let total = partials.into_iter().fold(KernelPartial::default(), KernelPartial::merge);
        KernelReport::from_partial(quant.name(), x.len(), total)
    }

    fn from_partial(scheme: String, total: usize, p: KernelPartial) -> KernelReport {
        KernelReport {
            scheme,
            fraction: p.count as f32 / total.max(1) as f32,
            count: p.count,
            total,
            mean_abs_kernel: if p.count > 0 {
                (p.sum_kernel / p.count as f64) as f32
            } else {
                0.0
            },
            mean_abs_rest: if p.n_rest > 0 { (p.sum_rest / p.n_rest as f64) as f32 } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{crossquant::CrossQuant, per_token::PerToken, Bits};
    use crate::tensor::{Matrix, SplitMix64};

    /// Definition-1 equivalence: the mask predicted from the zero bound
    /// must exactly match the set of elements the quantizer maps to zero.
    #[test]
    fn mask_equals_actual_zeros() {
        let mut rng = SplitMix64::new(31);
        let x = Matrix::randn(64, 48, 1.0, &mut rng);
        for quant in [CrossQuant::new(0.15, Bits::Int8), CrossQuant::new(0.6, Bits::Int4)] {
            let field = quant.delta_field(&x);
            let mask = kernel_mask(&x, &field);
            let q = quant.fake_quant(&x);
            for (idx, &m) in mask.iter().enumerate() {
                let zeroed = q.data[idx] == 0.0 && x.data[idx] != 0.0;
                assert_eq!(m, zeroed, "idx {idx} x={}", x.data[idx]);
            }
        }
    }

    #[test]
    fn fraction_counts_match_mask() {
        let mut rng = SplitMix64::new(32);
        let x = Matrix::randn(40, 40, 1.0, &mut rng);
        let q = PerToken::new(Bits::Int8);
        let field = q.delta_field(&x);
        let frac = kernel_fraction(&x, &field);
        let mask_count = kernel_mask(&x, &field).iter().filter(|&&b| b).count();
        assert!((frac - mask_count as f32 / x.len() as f32).abs() < 1e-7);
    }

    #[test]
    fn report_partitions_elements() {
        let mut rng = SplitMix64::new(33);
        let x = Matrix::randn(32, 32, 1.0, &mut rng);
        let r = KernelReport::compute(&x, &PerToken::new(Bits::Int4));
        assert_eq!(r.total, 1024);
        assert!(r.fraction >= 0.0 && r.fraction <= 1.0);
        // kernel members are by construction smaller on average
        if r.count > 0 {
            assert!(r.mean_abs_kernel < r.mean_abs_rest);
        }
    }

    #[test]
    fn fused_output_matches_fake_quant_and_report() {
        let mut rng = SplitMix64::new(34);
        let x = Matrix::randn(57, 43, 1.0, &mut rng);
        for quant in [CrossQuant::new(0.15, Bits::Int8), CrossQuant::new(1.0, Bits::Int4)] {
            let (q_fused, report) = quantize_with_report(&x, &quant);
            assert_eq!(q_fused.data, quant.fake_quant(&x).data, "fused output must be bit-exact");
            let separate = KernelReport::compute(&x, &quant);
            assert_eq!(report.count, separate.count);
            assert_eq!(report.total, separate.total);
            assert!((report.mean_abs_kernel - separate.mean_abs_kernel).abs() < 1e-6);
            assert!((report.mean_abs_rest - separate.mean_abs_rest).abs() < 1e-6);
        }
    }

    #[test]
    fn fused_report_handles_empty_matrix() {
        let x = Matrix::zeros(0, 16);
        let (q, r) = quantize_with_report(&x, &PerToken::new(Bits::Int8));
        assert!(q.is_empty());
        assert_eq!((r.count, r.total), (0, 0));
        assert_eq!(r.fraction, 0.0);
    }
}
