//! Offline stand-in for the `xla` (xla_extension) bindings — the sole
//! external dependency of this crate is `anyhow` (see Cargo.toml), so the
//! PJRT surface the runtime layer codes against is provided here instead
//! of by a native library.
//!
//! [`Literal`] is a real implementation — host-side typed buffers with
//! shape metadata — so the marshalling layer in `runtime::literal` (and
//! its tests) works unchanged. The PJRT pieces ([`PjRtClient`] onward)
//! are honest stubs: constructing the client reports that no XLA runtime
//! is linked, and callers degrade exactly as they would with a missing
//! plugin — the integration tests skip, and the coordinator falls back to
//! its native executor (see `coordinator::scheduler`).

use std::fmt;

/// Error type mirroring the real bindings' error far enough for
/// `?`-conversion into `anyhow::Error`.
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} failed: the xla_extension runtime is not linked into this offline build"
    ))
}

/// Element types a [`Literal`] can carry.
pub trait ArrayElement: Copy {
    #[doc(hidden)]
    fn into_payload(v: Vec<Self>) -> Payload;
    #[doc(hidden)]
    fn from_payload(p: &Payload) -> Option<Vec<Self>>;
    #[doc(hidden)]
    const TYPE_NAME: &'static str;
}

/// Typed storage of a literal (crate-internal; reachable only through the
/// [`ArrayElement`] machinery).
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl ArrayElement for f32 {
    fn into_payload(v: Vec<f32>) -> Payload {
        Payload::F32(v)
    }
    fn from_payload(p: &Payload) -> Option<Vec<f32>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const TYPE_NAME: &'static str = "f32";
}

impl ArrayElement for i32 {
    fn into_payload(v: Vec<i32>) -> Payload {
        Payload::I32(v)
    }
    fn from_payload(p: &Payload) -> Option<Vec<i32>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const TYPE_NAME: &'static str = "i32";
}

/// Host-side typed buffer + shape — the subset of `xla::Literal` this
/// crate marshals through.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: ArrayElement>(v: &[T]) -> Literal {
        Literal { payload: T::into_payload(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { payload: Payload::F32(vec![v]), dims: Vec::new() }
    }

    /// Tuple literal (what executions with `return_tuple=True` produce).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![elems.len() as i64], payload: Payload::Tuple(elems) }
    }

    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(v) => v.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret the buffer under new dimensions (element count must
    /// match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.element_count() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Copy the buffer out as a host vector of `T`.
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        T::from_payload(&self.payload)
            .ok_or_else(|| Error(format!("literal does not hold {} elements", T::TYPE_NAME)))
    }

    pub fn get_first_element<T: ArrayElement>(&self) -> Result<T> {
        self.to_vec::<T>()?.first().copied().ok_or_else(|| Error("empty literal".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// PJRT client stub: construction always reports unavailability so every
/// caller takes its no-PJRT path (skip / fallback), never a partial one.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT CPU client initialization"))
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }
}

/// Parsed HLO module stub.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<std::path::Path>) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {}", path.as_ref().display())))
    }
}

/// Computation handle stub.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled-executable stub.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

/// Device-buffer stub.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_typed_readback() {
        let lit = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]).reshape(&[2, 3]).unwrap();
        assert_eq!(lit.dims(), &[2, 3]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(lit.to_vec::<f32>().is_err(), "type-mismatched readback must fail");
        assert!(lit.reshape(&[4, 2]).is_err(), "element-count mismatch must fail");
    }

    #[test]
    fn scalar_and_tuple_literals() {
        assert_eq!(Literal::scalar(0.5).get_first_element::<f32>().unwrap(), 0.5);
        let t = Literal::tuple(vec![Literal::scalar(1.0), Literal::scalar(2.0)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(1.0).to_tuple().is_err());
    }

    #[test]
    fn pjrt_client_reports_unavailable() {
        let err = PjRtClient::cpu().expect_err("offline build has no PJRT");
        assert!(format!("{err}").contains("failed"), "{err}");
    }
}
