//! Log-bucketed (HDR-style) latency histograms with rolling windows.
//!
//! The seed coordinator carried a single fixed 10-bucket histogram whose
//! quantiles were only as honest as the hand-picked bucket edges (and
//! whose overflow sentinel was `u64::MAX` — 1.8e19 µs once serialized).
//! This histogram is logarithmic with [`SUB`] sub-buckets per octave, so
//! every recorded value lands in a bucket whose upper bound is within
//! ~6% of the value, across the whole range from 1 µs to [`max_trackable_us`]
//! (~200 days) — no tuning per metric, honest p50/p95/p99/p999 for
//! time-to-first-token and inter-token latency alike.
//!
//! All counters are relaxed atomics: recording is lock-free and merge is
//! exact (merge of shards ≡ histogram of the union — property-tested in
//! rust/tests/obs.rs). Values past the last finite bucket go to an
//! explicit overflow counter and quantiles clamp to the last finite
//! bound — never a sentinel.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::Json;

/// log2(sub-buckets per octave): 16 sub-buckets ⇒ ≤ 1/16 relative error.
const SUB_BITS: u32 = 4;
/// Sub-buckets per octave.
const SUB: usize = 1 << SUB_BITS;
/// Octave groups past the exact range — bounds the bucket array.
const GROUPS: usize = 40;
/// Total finite buckets.
const NUM_BUCKETS: usize = (GROUPS + 1) * SUB;

/// Largest value (µs) the finite buckets can hold; beyond it observations
/// land in the overflow counter.
pub fn max_trackable_us() -> u64 {
    bucket_bound(NUM_BUCKETS - 1)
}

/// Bucket index for a value: values below [`SUB`] are exact; above, the
/// top [`SUB_BITS`]+1 bits of the value select (octave, sub-bucket).
fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // >= SUB_BITS
    let group = msb - SUB_BITS as usize + 1;
    let mantissa = (v >> (msb - SUB_BITS as usize)) as usize; // in [SUB, 2*SUB)
    group * SUB + (mantissa - SUB)
}

/// Inclusive upper bound of bucket `i` — what quantiles report.
fn bucket_bound(i: usize) -> u64 {
    if i < SUB {
        return i as u64;
    }
    let group = i / SUB;
    let rem = (i % SUB) as u64;
    ((SUB as u64 + rem + 1) << (group - 1)) - 1
}

/// One lock-free log-bucketed histogram. Shared by reference between the
/// recording threads and the metrics reader; every operation is a relaxed
/// atomic, so a snapshot taken mid-record can be off by the in-flight
/// observation — fine for telemetry, and the merge/quantile algebra is
/// exact over whatever counts are visible.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    overflow: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            overflow: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        let idx = bucket_index(v);
        if idx < NUM_BUCKETS {
            self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        } else {
            self.overflow.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(v, Ordering::Relaxed);
        self.max_us.fetch_max(v, Ordering::Relaxed);
    }

    /// Observations recorded (overflowed values included).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Observations past the last finite bucket — the explicit signal the
    /// old `u64::MAX` quantile sentinel stood in for.
    pub fn overflow_count(&self) -> u64 {
        self.overflow.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Mean over the histogram's **own** observation count — never some
    /// adjacent counter's (the seed divided by `completed`, skewing the
    /// mean whenever latency was recorded on another path).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us() as f64 / n as f64
    }

    /// Approximate quantile (upper bucket bound, tightened to the observed
    /// max). Monotone in `q`. A rank landing in the overflow region clamps
    /// to the last finite bucket bound — check [`Self::overflow_count`]
    /// to see whether that happened.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((total as f64 * q).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= rank {
                return bucket_bound(i).min(self.max_us());
            }
        }
        max_trackable_us()
    }

    /// Add another histogram's counts into this one — exact: merging
    /// per-shard histograms is indistinguishable from having recorded the
    /// union into one.
    pub fn merge_from(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(&other.buckets) {
            let v = src.load(Ordering::Relaxed);
            if v != 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum_us.fetch_add(other.sum_us(), Ordering::Relaxed);
        self.overflow.fetch_add(other.overflow_count(), Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us(), Ordering::Relaxed);
    }

    /// Zero every counter (rolling-window slot recycling).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
        self.overflow.store(0, Ordering::Relaxed);
        self.max_us.store(0, Ordering::Relaxed);
    }

    /// Raw bucket counts — the merge property tests compare these.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Observations above `threshold_us`, at bucket granularity: only
    /// buckets entirely past the threshold count, so observations sharing
    /// the threshold's own bucket are not counted — a conservative
    /// under-count of at most one bucket's worth (≤ ~6% of the
    /// threshold), the same error bound as the quantiles. Overflowed
    /// observations always count as above.
    pub fn count_above(&self, threshold_us: u64) -> u64 {
        let mut above = self.overflow.load(Ordering::Relaxed);
        let first = bucket_index(threshold_us) + 1;
        for b in self.buckets.iter().skip(first) {
            above += b.load(Ordering::Relaxed);
        }
        above
    }

    /// Fraction of observations above `threshold_us` (0 when empty) —
    /// the violation fraction the SLO burn-rate math consumes.
    pub fn fraction_above(&self, threshold_us: u64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.count_above(threshold_us) as f64 / n as f64
    }

    /// Full summary object: count, mean, the standard quantile ladder,
    /// max, and the explicit overflow count.
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("mean_us", Json::num(self.mean_us())),
            ("p50_us", Json::num(self.quantile_us(0.5) as f64)),
            ("p95_us", Json::num(self.quantile_us(0.95) as f64)),
            ("p99_us", Json::num(self.quantile_us(0.99) as f64)),
            ("p999_us", Json::num(self.quantile_us(0.999) as f64)),
            ("max_us", Json::num(self.max_us() as f64)),
            ("overflow", Json::num(self.overflow_count() as f64)),
        ])
    }

    /// Compact window summary (rolling gauges).
    fn brief_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("p50_us", Json::num(self.quantile_us(0.5) as f64)),
            ("p95_us", Json::num(self.quantile_us(0.95) as f64)),
            ("p99_us", Json::num(self.quantile_us(0.99) as f64)),
        ])
    }
}

/// Rolling-window slots: one histogram per second over the last
/// [`SLOTS`] seconds, recycled in place. Windows up to 60 s merge the
/// live slots, so windowed quantiles reflect *now*, not process lifetime.
const SLOTS: usize = 64;

pub struct Rolling {
    slots: Vec<RollSlot>,
}

struct RollSlot {
    epoch: AtomicU64,
    hist: Histogram,
}

impl Default for Rolling {
    fn default() -> Self {
        Rolling::new()
    }
}

impl Rolling {
    pub fn new() -> Rolling {
        Rolling {
            slots: (0..SLOTS)
                .map(|_| RollSlot { epoch: AtomicU64::new(u64::MAX), hist: Histogram::new() })
                .collect(),
        }
    }

    pub fn record(&self, v: u64) {
        self.record_at(super::now_secs(), v);
    }

    /// Record at an explicit epoch second (deterministic in tests). Slot
    /// recycling is racy by design: two threads recycling the same stale
    /// slot can drop a few in-flight observations from the window — an
    /// accepted telemetry-grade tradeoff that keeps recording lock-free.
    pub fn record_at(&self, epoch_s: u64, v: u64) {
        let slot = &self.slots[(epoch_s % SLOTS as u64) as usize];
        if slot.epoch.load(Ordering::Acquire) != epoch_s {
            slot.hist.reset();
            slot.epoch.store(epoch_s, Ordering::Release);
        }
        slot.hist.record(v);
    }

    /// Merge the slots covering the last `window_s` seconds (now
    /// inclusive) into a fresh histogram. `window_s` must be < [`SLOTS`].
    pub fn window(&self, window_s: u64) -> Histogram {
        self.window_at(super::now_secs(), window_s)
    }

    pub fn window_at(&self, now_s: u64, window_s: u64) -> Histogram {
        debug_assert!((window_s as usize) < SLOTS);
        let out = Histogram::new();
        for slot in &self.slots {
            let e = slot.epoch.load(Ordering::Acquire);
            if e <= now_s && now_s - e < window_s {
                out.merge_from(&slot.hist);
            }
        }
        out
    }

    /// Forget every slot (`{"cmd":"metrics_reset"}`): windows computed
    /// afterwards see only observations recorded after the reset.
    pub fn reset(&self) {
        for slot in &self.slots {
            slot.epoch.store(u64::MAX, Ordering::Release);
            slot.hist.reset();
        }
    }
}

/// Rolling per-second *event* counter — the histogram-free sibling of
/// [`Rolling`] for signals where only the windowed count matters
/// (succeeded/failed request streams feeding the SLO error-rate burn).
/// Same slot-recycling discipline, same `_at` injected-clock test hooks.
pub struct RollingCount {
    slots: Vec<CountSlot>,
}

struct CountSlot {
    epoch: AtomicU64,
    count: AtomicU64,
}

impl Default for RollingCount {
    fn default() -> Self {
        RollingCount::new()
    }
}

impl RollingCount {
    pub fn new() -> RollingCount {
        RollingCount {
            slots: (0..SLOTS)
                .map(|_| CountSlot { epoch: AtomicU64::new(u64::MAX), count: AtomicU64::new(0) })
                .collect(),
        }
    }

    pub fn record(&self) {
        self.record_at(super::now_secs());
    }

    /// Count one event at an explicit epoch second. Recycling a stale
    /// slot is racy the same way [`Rolling::record_at`] is — a few
    /// in-flight events can vanish from the window, never double-count.
    pub fn record_at(&self, epoch_s: u64) {
        let slot = &self.slots[(epoch_s % SLOTS as u64) as usize];
        if slot.epoch.load(Ordering::Acquire) != epoch_s {
            slot.count.store(0, Ordering::Relaxed);
            slot.epoch.store(epoch_s, Ordering::Release);
        }
        slot.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Events in the last `window_s` seconds (now inclusive).
    /// `window_s` must be < [`SLOTS`].
    pub fn window(&self, window_s: u64) -> u64 {
        self.window_at(super::now_secs(), window_s)
    }

    pub fn window_at(&self, now_s: u64, window_s: u64) -> u64 {
        debug_assert!((window_s as usize) < SLOTS);
        let mut total = 0u64;
        for slot in &self.slots {
            let e = slot.epoch.load(Ordering::Acquire);
            if e <= now_s && now_s - e < window_s {
                total += slot.count.load(Ordering::Relaxed);
            }
        }
        total
    }

    pub fn reset(&self) {
        for slot in &self.slots {
            slot.epoch.store(u64::MAX, Ordering::Release);
            slot.count.store(0, Ordering::Relaxed);
        }
    }
}

/// A lifetime histogram plus its rolling windows — one per tracked
/// latency signal (request latency, TTFT, inter-token, queue wait,
/// batch-forward time).
#[derive(Default)]
pub struct LatencyTrack {
    pub total: Histogram,
    pub rolling: Rolling,
}

impl LatencyTrack {
    pub fn record_us(&self, v: u64) {
        self.total.record(v);
        self.rolling.record(v);
    }

    /// Zero the lifetime histogram and forget the rolling slots
    /// (`{"cmd":"metrics_reset"}`).
    pub fn reset(&self) {
        self.total.reset();
        self.rolling.reset();
    }

    /// Lifetime summary plus `w1s`/`w10s`/`w60s` windowed quantiles.
    pub fn json(&self) -> Json {
        let mut fields = match self.total.json() {
            Json::Obj(m) => m,
            _ => unreachable!("histogram json is an object"),
        };
        let now = super::now_secs();
        for (name, secs) in [("w1s", 1u64), ("w10s", 10), ("w60s", 60)] {
            fields.insert(name.to_string(), self.rolling.window_at(now, secs).brief_json());
        }
        Json::Obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_tile_the_range() {
        // every bucket's bound maps back into that bucket, bounds are
        // strictly increasing, and consecutive values never skip a bucket
        for i in 0..NUM_BUCKETS {
            let b = bucket_bound(i);
            assert_eq!(bucket_index(b), i, "bound of bucket {i}");
            if i > 0 {
                assert!(bucket_bound(i - 1) < b);
                assert_eq!(bucket_index(bucket_bound(i - 1) + 1), i);
            }
        }
    }

    #[test]
    fn relative_error_is_bounded() {
        let h = Histogram::new();
        for v in [1u64, 7, 100, 12_345, 1_000_000, 123_456_789] {
            h.reset();
            h.record(v);
            let q = h.quantile_us(0.5);
            assert!(q >= v, "quantile {q} below recorded {v}");
            assert!((q - v) as f64 <= v as f64 / 16.0 + 1.0, "{q} too far above {v}");
        }
    }

    #[test]
    fn overflow_clamps_instead_of_sentineling() {
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX / 2);
        assert_eq!(h.overflow_count(), 2);
        assert_eq!(h.count(), 2);
        let p99 = h.quantile_us(0.99);
        assert!(p99 <= max_trackable_us(), "quantile must clamp, got {p99}");
        // the clamped value still serializes as a sane finite number
        let j = h.json();
        assert_eq!(j.get("overflow").and_then(|v| v.as_f64()), Some(2.0));
        assert!(j.get("p99_us").and_then(|v| v.as_f64()).unwrap() <= max_trackable_us() as f64);
    }

    #[test]
    fn quantile_tightens_to_observed_max() {
        let h = Histogram::new();
        h.record(1_000_000); // bucket bound ≈ 1.04 ms
        assert_eq!(h.quantile_us(0.99), 1_000_000);
    }

    #[test]
    fn rolling_window_evicts_old_seconds() {
        let r = Rolling::new();
        r.record_at(100, 5_000);
        r.record_at(105, 9_000);
        r.record_at(110, 1_000);
        // at t=110: 1 s window sees only the newest value
        assert_eq!(r.window_at(110, 1).count(), 1);
        assert_eq!(r.window_at(110, 1).quantile_us(0.5), 1_000);
        // 10 s window sees t=105 and t=110, not t=100
        let w10 = r.window_at(110, 10);
        assert_eq!(w10.count(), 2);
        assert!(w10.quantile_us(0.99) >= 9_000);
        // 60 s window sees everything
        assert_eq!(r.window_at(110, 60).count(), 3);
        // much later, every old second has aged out of the window
        r.record_at(300, 7);
        assert_eq!(r.window_at(300, 60).count(), 1);
    }

    #[test]
    fn count_above_matches_bucket_semantics() {
        let h = Histogram::new();
        for v in [100u64, 1_000, 10_000, 100_000] {
            h.record(v);
        }
        h.record(u64::MAX); // overflow is always "above"
        assert_eq!(h.count_above(0), 5);
        assert_eq!(h.count_above(5_000), 3);
        assert_eq!(h.count_above(u64::MAX / 2), 1);
        assert!((h.fraction_above(5_000) - 3.0 / 5.0).abs() < 1e-12);
        // threshold inside a value's own bucket under-counts, never over
        assert!(h.count_above(99_000) <= 2);
    }

    #[test]
    fn rolling_count_windows_and_resets() {
        let c = RollingCount::new();
        for epoch in 100..160 {
            c.record_at(epoch);
            c.record_at(epoch);
        }
        assert_eq!(c.window_at(159, 1), 2);
        assert_eq!(c.window_at(159, 10), 20);
        assert_eq!(c.window_at(159, 60), 120);
        // far in the future every slot has aged out
        assert_eq!(c.window_at(400, 60), 0);
        c.record_at(400);
        assert_eq!(c.window_at(400, 60), 1);
        c.reset();
        assert_eq!(c.window_at(400, 60), 0);
    }

    #[test]
    fn rolling_and_track_reset_clear_windows() {
        let t = LatencyTrack::default();
        t.record_us(5_000);
        assert_eq!(t.total.count(), 1);
        assert_eq!(t.rolling.window(60).count(), 1);
        t.reset();
        assert_eq!(t.total.count(), 0);
        assert_eq!(t.rolling.window(60).count(), 0);
    }

    #[test]
    fn latency_track_reports_windows() {
        let t = LatencyTrack::default();
        t.record_us(1_500);
        let j = t.json();
        assert_eq!(j.get("count").and_then(|v| v.as_f64()), Some(1.0));
        assert!(j.get("w60s").is_some());
        assert!(j.get("w1s").unwrap().get("p99_us").is_some());
    }
}
