//! Live quantization-kernel telemetry — the paper's metric, on a fleet.
//!
//! CrossQuant's accuracy argument is that the *quantization kernel* (the
//! set of nonzero activations quantized to zero) stays small: below ~19%
//! for OPT and around 1% for LLaMA. Offline analysis
//! (`analysis::quantize_with_report`) measures this on calibration data;
//! this module samples it on *live* dynamic-scheme forwards, per
//! activation site, so a drifting input distribution that inflates the
//! kernel shows up in `{"cmd":"metrics"}` — and as a structured warning —
//! before it shows up as quality loss.
//!
//! Sampling is cheap by construction: off by default
//! (`--kernel-telemetry`), stride-sampled (every Nth call per site), and
//! summarized with algorithm-R reservoirs so memory is constant.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::tensor::SplitMix64;
use crate::util::Json;

/// The paper's OPT bound: kernel fractions above 19% correlate with
/// measurable quantization loss (LLaMA-family models sit near 1%).
pub const DEFAULT_KERNEL_THRESHOLD: f32 = 0.19;

const RESERVOIR_CAP: usize = 64;
const DEFAULT_STRIDE: u64 = 8;

/// One measured forward at one activation site.
#[derive(Clone, Copy, Debug, Default)]
pub struct SiteSample {
    /// Elements in the quantization kernel (nonzero quantized to zero).
    pub kernel: u64,
    /// Total elements in the activation tile.
    pub total: u64,
    /// Mean over rows of each row's absolute max (`t_i` in eq. (5)).
    pub row_absmax: f32,
    /// Mean over columns of each column's absolute max (`c_j`).
    pub col_absmax: f32,
}

#[derive(Default)]
struct SiteStat {
    calls: u64,
    samples: u64,
    kernel_elems: u64,
    total_elems: u64,
    row_absmax_sum: f64,
    col_absmax_sum: f64,
    /// Algorithm-R reservoir of per-call kernel fractions — keeps a
    /// uniform sample of the whole history in constant memory so the
    /// gauge can report a max that isn't dominated by one ancient spike.
    reservoir: Vec<f32>,
    rng: Option<SplitMix64>,
    /// Latched once a warning fires; resets when the running fraction
    /// falls below half the threshold (simple hysteresis — no log storm
    /// while a site hovers at the bound).
    over_threshold: bool,
}

/// Shared, process-wide kernel telemetry. Cloned (via `Arc`) into each
/// dynamic-scheme activation site; `observe` is a no-op unless enabled.
pub struct KernelTelemetry {
    enabled: AtomicBool,
    /// Threshold stored in micro-units so it fits an atomic.
    threshold_micro: AtomicU64,
    stride: AtomicU64,
    sites: Mutex<Vec<SiteStat>>,
}

impl Default for KernelTelemetry {
    fn default() -> Self {
        KernelTelemetry::new()
    }
}

impl KernelTelemetry {
    pub fn new() -> KernelTelemetry {
        KernelTelemetry {
            enabled: AtomicBool::new(false),
            threshold_micro: AtomicU64::new((DEFAULT_KERNEL_THRESHOLD as f64 * 1e6) as u64),
            stride: AtomicU64::new(DEFAULT_STRIDE),
            sites: Mutex::new(Vec::new()),
        }
    }

    pub fn configure(&self, enabled: bool, threshold: f32, stride: u64) {
        self.enabled.store(enabled, Ordering::Relaxed);
        self.threshold_micro
            .store((threshold.clamp(0.0, 1.0) as f64 * 1e6) as u64, Ordering::Relaxed);
        self.stride.store(stride.max(1), Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn threshold(&self) -> f32 {
        self.threshold_micro.load(Ordering::Relaxed) as f32 / 1e6
    }

    /// Record one forward at `site`. `stats` is only invoked on sampled
    /// calls (every `stride`-th per site), so the closure can afford a
    /// pass over the activation tile.
    pub fn observe(&self, site: usize, stats: impl FnOnce() -> SiteSample) {
        if !self.enabled() {
            return;
        }
        let stride = self.stride.load(Ordering::Relaxed);
        let threshold = self.threshold();
        let mut sites = self.sites.lock().unwrap_or_else(|e| e.into_inner());
        if sites.len() <= site {
            sites.resize_with(site + 1, SiteStat::default);
        }
        let st = &mut sites[site];
        st.calls += 1;
        if st.calls % stride != 1 && stride > 1 {
            return;
        }
        let s = stats();
        if s.total == 0 {
            return;
        }
        st.samples += 1;
        st.kernel_elems += s.kernel;
        st.total_elems += s.total;
        st.row_absmax_sum += s.row_absmax as f64;
        st.col_absmax_sum += s.col_absmax as f64;
        let frac = s.kernel as f32 / s.total as f32;
        let rng = st.rng.get_or_insert_with(|| SplitMix64::new(0xC0FF_EE00 ^ site as u64));
        if st.reservoir.len() < RESERVOIR_CAP {
            st.reservoir.push(frac);
        } else {
            let j = rng.below(st.samples as usize);
            if j < RESERVOIR_CAP {
                st.reservoir[j] = frac;
            }
        }
        let running = st.kernel_elems as f32 / st.total_elems.max(1) as f32;
        if running > threshold && !st.over_threshold {
            st.over_threshold = true;
            super::log::warn(
                "kernel",
                "quantization-kernel fraction over threshold",
                &[
                    ("site", site.to_string()),
                    ("fraction", format!("{running:.4}")),
                    ("threshold", format!("{threshold:.4}")),
                ],
            );
        } else if st.over_threshold && running < threshold / 2.0 {
            st.over_threshold = false;
        }
    }

    /// Per-site gauges for `{"cmd":"metrics"}`.
    pub fn json(&self) -> Json {
        let sites = self.sites.lock().unwrap_or_else(|e| e.into_inner());
        let rows = sites
            .iter()
            .enumerate()
            .filter(|(_, st)| st.samples > 0)
            .map(|(i, st)| {
                let frac = st.kernel_elems as f64 / st.total_elems.max(1) as f64;
                let res_max =
                    st.reservoir.iter().copied().fold(0.0f32, f32::max) as f64;
                Json::obj(vec![
                    ("site", Json::num(i as f64)),
                    ("calls", Json::num(st.calls as f64)),
                    ("samples", Json::num(st.samples as f64)),
                    ("kernel_fraction", Json::num(frac)),
                    ("kernel_fraction_sampled_max", Json::num(res_max)),
                    ("row_absmax_mean", Json::num(st.row_absmax_sum / st.samples as f64)),
                    ("col_absmax_mean", Json::num(st.col_absmax_sum / st.samples as f64)),
                    ("over_threshold", Json::Bool(st.over_threshold)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled())),
            ("threshold", Json::num(self.threshold() as f64)),
            ("sites", Json::Arr(rows)),
        ])
    }

    /// Prometheus gauges, one sample per site per metric.
    pub fn prom(&self, w: &mut super::prom::PromWriter) {
        let sites = self.sites.lock().unwrap_or_else(|e| e.into_inner());
        for (i, st) in sites.iter().enumerate() {
            if st.samples == 0 {
                continue;
            }
            let site = i.to_string();
            let labels: &[(&str, &str)] = &[("site", site.as_str())];
            w.write(
                "cq_kernel_fraction",
                "gauge",
                "Quantization-kernel fraction per activation site (paper bound: 0.19 OPT / 0.01 LLaMA).",
                labels,
                st.kernel_elems as f64 / st.total_elems.max(1) as f64,
            );
            w.write(
                "cq_kernel_row_absmax_mean",
                "gauge",
                "Mean per-row activation absmax (t_i) at this site.",
                labels,
                st.row_absmax_sum / st.samples as f64,
            );
            w.write(
                "cq_kernel_col_absmax_mean",
                "gauge",
                "Mean per-column activation absmax (c_j) at this site.",
                labels,
                st.col_absmax_sum / st.samples as f64,
            );
            w.write(
                "cq_kernel_samples_total",
                "counter",
                "Sampled forwards at this site.",
                labels,
                st.samples as f64,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(kernel: u64, total: u64) -> SiteSample {
        SiteSample { kernel, total, row_absmax: 1.5, col_absmax: 2.5 }
    }

    #[test]
    fn disabled_telemetry_never_calls_stats() {
        let t = KernelTelemetry::new();
        t.observe(0, || panic!("stats must not run while disabled"));
        assert_eq!(t.json().get("sites").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn stride_sampling_and_accumulation() {
        let t = KernelTelemetry::new();
        t.configure(true, 0.19, 4);
        for _ in 0..16 {
            t.observe(2, || sample(10, 100));
        }
        let j = t.json();
        let sites = j.get("sites").unwrap().as_arr().unwrap();
        assert_eq!(sites.len(), 1);
        let s = &sites[0];
        assert_eq!(s.get("site").unwrap().as_f64(), Some(2.0));
        assert_eq!(s.get("calls").unwrap().as_f64(), Some(16.0));
        assert_eq!(s.get("samples").unwrap().as_f64(), Some(4.0));
        assert!((s.get("kernel_fraction").unwrap().as_f64().unwrap() - 0.1).abs() < 1e-9);
        assert!((s.get("row_absmax_mean").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-6);
    }

    #[test]
    fn threshold_latch_has_hysteresis() {
        let t = KernelTelemetry::new();
        t.configure(true, 0.19, 1);
        t.observe(0, || sample(30, 100)); // 30% > 19% → latches
        let over = |t: &KernelTelemetry| {
            t.json().get("sites").unwrap().as_arr().unwrap()[0]
                .get("over_threshold")
                .unwrap()
                .clone()
        };
        assert_eq!(over(&t), Json::Bool(true));
        // running fraction drops but stays above threshold/2 → still latched
        t.observe(0, || sample(0, 100));
        assert_eq!(over(&t), Json::Bool(true));
        // drive the running fraction below half the threshold → unlatch
        for _ in 0..10 {
            t.observe(0, || sample(0, 100));
        }
        assert_eq!(over(&t), Json::Bool(false));
    }

    #[test]
    fn prometheus_rendering_includes_site_label() {
        let t = KernelTelemetry::new();
        t.configure(true, 0.19, 1);
        t.observe(1, || sample(5, 100));
        let mut w = crate::obs::prom::PromWriter::new();
        t.prom(&mut w);
        let body = w.finish();
        assert!(body.contains("cq_kernel_fraction{site=\"1\"} 0.05\n"));
        assert!(body.contains("# TYPE cq_kernel_fraction gauge"));
    }
}
