//! Request tracing: per-stage spans in a lock-free fixed-capacity ring.
//!
//! A trace id is assigned at the router (or supplied by the client via
//! the `"trace"` wire field), propagated over the line protocol to the
//! worker, and carried through scheduler → engine → model forward. Each
//! stage records a [`Span`] into the process's [`SpanRing`]; the ring is
//! queryable over the wire (`{"cmd":"trace","id":...}`) and dumpable as
//! Chrome `trace_event` JSON for `chrome://tracing`.
//!
//! The ring is a seqlock-per-slot design: writers claim a slot with one
//! `fetch_add`, publish with two release stores around the field writes;
//! readers detect torn slots by re-checking the commit word. No locks,
//! no allocation, fixed memory — safe to leave enabled in production.
//! Overwrite is the eviction policy: the ring keeps the most recent
//! `capacity` spans.

use std::sync::atomic::{fence, AtomicU64, Ordering};

use crate::util::Json;

/// The span taxonomy — one variant per serving stage. Durations tile a
/// traced generate request end-to-end: queue wait → admission wait →
/// prefill → one decode span per token (each measured from the previous
/// token, so the sum is the full residence time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Router: client frame accepted → final frame relayed (aux = worker
    /// index that served the attempt).
    Dispatch,
    /// Worker: request submitted → picked up by the executor/engine.
    QueueWait,
    /// Engine: entered the admission queue → KV slot leased.
    AdmissionWait,
    /// Engine: prompt prefill through the first emitted token.
    Prefill,
    /// Engine: previous token emitted → this token emitted (aux = token
    /// index); equals the inter-token latency for that position.
    DecodeToken,
    /// Executor: one batched forward (aux = batch rows).
    BatchForward,
    /// Int8 GEMM time inside the enclosing forward (aux = GEMM calls).
    Gemm,
    /// `.cqa` artifact load on the serving path.
    ArtifactLoad,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Dispatch => "dispatch",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::AdmissionWait => "admission_wait",
            SpanKind::Prefill => "prefill",
            SpanKind::DecodeToken => "decode_token",
            SpanKind::BatchForward => "batch_forward",
            SpanKind::Gemm => "gemm",
            SpanKind::ArtifactLoad => "artifact_load",
        }
    }

    fn code(self) -> u64 {
        self as u64
    }

    fn from_code(c: u64) -> Option<SpanKind> {
        Some(match c {
            0 => SpanKind::Dispatch,
            1 => SpanKind::QueueWait,
            2 => SpanKind::AdmissionWait,
            3 => SpanKind::Prefill,
            4 => SpanKind::DecodeToken,
            5 => SpanKind::BatchForward,
            6 => SpanKind::Gemm,
            7 => SpanKind::ArtifactLoad,
            _ => return None,
        })
    }
}

/// One recorded stage of one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    /// Owning trace id (0 = untraced background work, e.g. a cold
    /// artifact load not attributable to one request).
    pub trace: u64,
    pub kind: SpanKind,
    /// Microseconds since process start ([`super::now_us`]).
    pub start_us: u64,
    pub dur_us: u64,
    /// Kind-specific annotation (token index, worker index, GEMM calls…).
    pub aux: u64,
}

impl Span {
    /// Wire shape for the `{"cmd":"trace"}` response.
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("trace", Json::str(super::trace_id_string(self.trace))),
            ("kind", Json::str(self.kind.name())),
            ("start_us", Json::num(self.start_us as f64)),
            ("dur_us", Json::num(self.dur_us as f64)),
            ("aux", Json::num(self.aux as f64)),
        ])
    }
}

/// Default ring capacity: 8192 spans ≈ a few hundred traced generate
/// requests, ~400 KiB resident.
pub const DEFAULT_RING_CAPACITY: usize = 8192;

struct RingSlot {
    /// Seqlock word: 0 = never written, odd = write in progress,
    /// `2·seq + 2` = slot holds the record claimed at sequence `seq`.
    commit: AtomicU64,
    trace: AtomicU64,
    kind: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
    aux: AtomicU64,
}

/// Lock-free fixed-capacity span ring. Any thread may record; any thread
/// may snapshot concurrently — torn slots (a writer mid-publish, or a
/// lapped writer) are detected via the commit word and skipped.
pub struct SpanRing {
    slots: Vec<RingSlot>,
    head: AtomicU64,
    mask: u64,
}

impl Default for SpanRing {
    fn default() -> Self {
        SpanRing::new(DEFAULT_RING_CAPACITY)
    }
}

impl SpanRing {
    /// `capacity` is rounded up to a power of two (masking beats modulo
    /// on the record path).
    pub fn new(capacity: usize) -> SpanRing {
        let cap = capacity.max(2).next_power_of_two();
        SpanRing {
            slots: (0..cap)
                .map(|_| RingSlot {
                    commit: AtomicU64::new(0),
                    trace: AtomicU64::new(0),
                    kind: AtomicU64::new(0),
                    start_us: AtomicU64::new(0),
                    dur_us: AtomicU64::new(0),
                    aux: AtomicU64::new(0),
                })
                .collect(),
            head: AtomicU64::new(0),
            mask: cap as u64 - 1,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total spans ever recorded (recent `capacity` are retained).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    pub fn record(&self, s: Span) {
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(seq & self.mask) as usize];
        slot.commit.store(2 * seq + 1, Ordering::Release);
        slot.trace.store(s.trace, Ordering::Relaxed);
        slot.kind.store(s.kind.code(), Ordering::Relaxed);
        slot.start_us.store(s.start_us, Ordering::Relaxed);
        slot.dur_us.store(s.dur_us, Ordering::Relaxed);
        slot.aux.store(s.aux, Ordering::Relaxed);
        slot.commit.store(2 * seq + 2, Ordering::Release);
    }

    /// Consistent copies of every stable slot (torn slots skipped).
    pub fn snapshot(&self) -> Vec<Span> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let c1 = slot.commit.load(Ordering::Acquire);
            if c1 == 0 || c1 % 2 == 1 {
                continue; // never written, or a writer is mid-publish
            }
            let s = Span {
                trace: slot.trace.load(Ordering::Relaxed),
                kind: match SpanKind::from_code(slot.kind.load(Ordering::Relaxed)) {
                    Some(k) => k,
                    None => continue, // torn beyond recognition
                },
                start_us: slot.start_us.load(Ordering::Relaxed),
                dur_us: slot.dur_us.load(Ordering::Relaxed),
                aux: slot.aux.load(Ordering::Relaxed),
            };
            fence(Ordering::Acquire);
            if slot.commit.load(Ordering::Relaxed) == c1 {
                out.push(s);
            }
        }
        out
    }

    /// Spans of one trace, ordered by start time (`trace == 0` returns
    /// the whole ring — the "dump everything" query).
    pub fn for_trace(&self, trace: u64) -> Vec<Span> {
        let mut spans: Vec<Span> =
            self.snapshot().into_iter().filter(|s| trace == 0 || s.trace == trace).collect();
        spans.sort_by_key(|s| (s.start_us, s.dur_us, s.aux));
        spans
    }
}

/// Render spans as a Chrome `trace_event` document (the JSON Object
/// Format): load the rendered object directly in `chrome://tracing` or
/// Perfetto. Complete events (`ph: "X"`), `ts`/`dur` in microseconds.
pub fn chrome_trace_json(spans: &[Span]) -> Json {
    let events = spans
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::str(s.kind.name())),
                ("cat", Json::str("crossquant")),
                ("ph", Json::str("X")),
                ("ts", Json::num(s.start_us as f64)),
                ("dur", Json::num(s.dur_us as f64)),
                ("pid", Json::num(1.0)),
                // one lane per trace so concurrent requests stack visually
                ("tid", Json::num((s.trace % 0x7fff) as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("trace", Json::str(super::trace_id_string(s.trace))),
                        ("aux", Json::num(s.aux as f64)),
                    ]),
                ),
            ])
        })
        .collect();
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64, kind: SpanKind, start: u64) -> Span {
        Span { trace, kind, start_us: start, dur_us: 10, aux: 0 }
    }

    #[test]
    fn record_and_query_by_trace() {
        let ring = SpanRing::new(16);
        ring.record(span(7, SpanKind::QueueWait, 100));
        ring.record(span(9, SpanKind::Prefill, 150));
        ring.record(span(7, SpanKind::Prefill, 200));
        let t7 = ring.for_trace(7);
        assert_eq!(t7.len(), 2);
        assert_eq!(t7[0].kind, SpanKind::QueueWait);
        assert_eq!(t7[1].kind, SpanKind::Prefill);
        assert_eq!(ring.for_trace(0).len(), 3, "trace 0 dumps everything");
    }

    #[test]
    fn ring_overwrites_oldest() {
        let ring = SpanRing::new(4);
        for i in 0..10u64 {
            ring.record(span(1, SpanKind::DecodeToken, i));
        }
        let spans = ring.for_trace(1);
        assert_eq!(spans.len(), 4);
        assert_eq!(spans[0].start_us, 6, "oldest retained span");
        assert_eq!(ring.recorded(), 10);
    }

    #[test]
    fn chrome_dump_is_wellformed() {
        let ring = SpanRing::new(8);
        ring.record(span(3, SpanKind::Dispatch, 5));
        let doc = chrome_trace_json(&ring.for_trace(3));
        let parsed = crate::util::Json::parse(&doc.render()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(events[0].get("name").unwrap().as_str(), Some("dispatch"));
        assert_eq!(events[0].get("ts").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn kind_codes_roundtrip() {
        for k in [
            SpanKind::Dispatch,
            SpanKind::QueueWait,
            SpanKind::AdmissionWait,
            SpanKind::Prefill,
            SpanKind::DecodeToken,
            SpanKind::BatchForward,
            SpanKind::Gemm,
            SpanKind::ArtifactLoad,
        ] {
            assert_eq!(SpanKind::from_code(k.code()), Some(k));
        }
        assert_eq!(SpanKind::from_code(99), None);
    }
}
