//! Leveled structured logging: one line per event, `key=value` fields,
//! level gated by the `CROSSQUANT_LOG` environment variable
//! (`error|warn|info|debug`, default `info`).
//!
//! This replaces the scattered `eprintln!` diagnostics in the fleet
//! supervisor, router, and executor. Lines look like:
//!
//! ```text
//! ts=12.041 level=warn target=fleet msg="worker exited" worker=1 code=9
//! ```
//!
//! Fields with spaces/quotes are quoted; a trace id is included as
//! `trace=<hex>` by callers when one is in scope.

use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    pub fn label(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" | "trace" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// Maximum level that gets emitted, read once from `CROSSQUANT_LOG`.
fn max_level() -> Level {
    static MAX: OnceLock<Level> = OnceLock::new();
    *MAX.get_or_init(|| {
        std::env::var("CROSSQUANT_LOG").ok().and_then(|v| Level::parse(&v)).unwrap_or(Level::Info)
    })
}

pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Quote a field value only when it needs it (spaces, quotes, `=`).
fn quote(v: &str) -> String {
    if v.is_empty() || v.contains(|c: char| c.is_whitespace() || c == '"' || c == '=') {
        format!("{v:?}")
    } else {
        v.to_string()
    }
}

/// Emit one structured line to stderr if `level` is enabled.
pub fn log(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    let mut line = format!(
        "ts={:.3} level={} target={} msg={}",
        super::now_us() as f64 / 1e6,
        level.label(),
        target,
        quote(msg)
    );
    for (k, v) in fields {
        line.push(' ');
        line.push_str(k);
        line.push('=');
        line.push_str(&quote(v));
    }
    eprintln!("{line}");
}

pub fn error(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Error, target, msg, fields);
}

pub fn warn(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Warn, target, msg, fields);
}

pub fn info(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Info, target, msg, fields);
}

pub fn debug(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Debug, target, msg, fields);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_ordering() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse(" debug "), Some(Level::Debug));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn values_are_quoted_only_when_needed() {
        assert_eq!(quote("plain"), "plain");
        assert_eq!(quote("has space"), "\"has space\"");
        assert_eq!(quote("k=v"), "\"k=v\"");
        assert_eq!(quote(""), "\"\"");
    }
}
