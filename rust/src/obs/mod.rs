//! Observability: spans, histograms, rolling windows, an event ring, and
//! exposition — the layer every serving component reports through.
//!
//! The paper's central claim is operational ("keep the quantization-kernel
//! proportion below ~19% and INT8 activation quantization is
//! precision-free"), so the serving stack has to be able to *watch* that
//! proportion — and its own latency — on a live fleet, not just in offline
//! analysis runs. This module provides the shared building blocks:
//!
//! * [`hist`] — a log-bucketed (HDR-style) histogram with honest
//!   p50/p95/p99/p999, exact merge, an explicit overflow count, and
//!   1s/10s/60s rolling windows so gauges reflect *now*.
//! * [`trace`] — per-request trace ids, per-stage [`trace::Span`]s
//!   (dispatch, queue wait, admission wait, prefill, per-token decode,
//!   int8 GEMM, artifact load), a lock-free fixed-capacity
//!   [`trace::SpanRing`], and a Chrome `trace_event` dump for
//!   `chrome://tracing`.
//! * [`log`] — a leveled structured logger (`CROSSQUANT_LOG`, one-line
//!   key=value format) replacing the scattered `eprintln!` diagnostics.
//! * [`prom`] — Prometheus text exposition for
//!   `{"cmd":"metrics","format":"prometheus"}`.
//! * [`kernel`] — live sampling of the paper's quantization-kernel
//!   fraction and row/column absmax per activation site, with a
//!   structured warning when a site crosses the configured bound.
//! * [`slo`] — declarative SLO specs (TTFT p99, inter-token p99, error
//!   rate) and multi-window error-budget burn rates (fast 1 s/10 s +
//!   slow 60 s) over the rolling histograms — the signal
//!   `{"cmd":"slo"}`, the Prometheus exposition, and the engine's
//!   priority shedding all consume.
//!
//! Everything is hand-rolled on std (Cargo.toml: anyhow is the sole
//! external dependency) and lock-free on the hot paths: recording a span
//! or a latency observation is a handful of relaxed atomic ops.

pub mod hist;
pub mod kernel;
pub mod log;
pub mod prom;
pub mod slo;
pub mod trace;

pub use hist::{Histogram, LatencyTrack, Rolling, RollingCount};
pub use kernel::{KernelTelemetry, SiteSample, DEFAULT_KERNEL_THRESHOLD};
pub use slo::{SloPolicy, SloReport, SloSpec, WindowBurn};
pub use trace::{Span, SpanKind, SpanRing};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Process-start anchor for span timestamps: all span `start_us` values
/// are microseconds since the first call into the clock, monotone within
/// a process (Chrome's `ts` field wants exactly this shape).
fn start() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Microseconds since process start (monotonic).
pub fn now_us() -> u64 {
    start().elapsed().as_micros() as u64
}

/// Whole seconds since process start — the rolling-window epoch.
pub fn now_secs() -> u64 {
    now_us() / 1_000_000
}

/// Allocate a fresh nonzero trace id: a SplitMix64-style mix of a
/// per-process seed (wall clock ⊕ pid, so two routers started in the same
/// second still diverge) and a monotone counter. `| 1` keeps 0 reserved
/// as "untraced".
pub fn next_trace_id() -> u64 {
    static SEED: OnceLock<u64> = OnceLock::new();
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let seed = *SEED.get_or_init(|| {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED);
        nanos ^ ((std::process::id() as u64) << 32)
    });
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    seed.wrapping_add(n.wrapping_mul(0x9E37_79B9_7F4A_7C15)).wrapping_mul(0xBF58_476D_1CE4_E5B9) | 1
}

/// Render a trace id for the wire. Ids are full-range u64s, and JSON
/// numbers are f64 (precision loss above 2^53), so ids always cross the
/// wire as hex strings.
pub fn trace_id_string(id: u64) -> String {
    format!("{id:016x}")
}

/// Parse a client-supplied `"trace"` wire field. Accepts the canonical
/// hex string, a decimal string, a plain JSON number, or — for "let me
/// name my request" ergonomics — any other string, hashed (FNV-1a) to a
/// stable nonzero id.
pub fn parse_trace_field(v: &crate::util::Json) -> Option<u64> {
    use crate::util::Json;
    match v {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some((*n as u64) | 1),
        Json::Str(s) => {
            if let Ok(id) = u64::from_str_radix(s, 16) {
                return Some(id | 1);
            }
            if let Ok(id) = s.parse::<u64>() {
                return Some(id | 1);
            }
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            for b in s.bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
            Some(h | 1)
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    #[test]
    fn clock_is_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }

    #[test]
    fn trace_ids_are_nonzero_and_distinct() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn trace_field_parses_all_wire_shapes() {
        let id = next_trace_id();
        let hex = trace_id_string(id);
        assert_eq!(parse_trace_field(&Json::str(hex)), Some(id));
        assert_eq!(parse_trace_field(&Json::num(42.0)), Some(43)); // | 1
        assert_eq!(parse_trace_field(&Json::str("17")), Some(23)); // hex first
        // arbitrary names hash stably and never to zero
        let named = parse_trace_field(&Json::str("my-request")).unwrap();
        assert_ne!(named, 0);
        assert_eq!(parse_trace_field(&Json::str("my-request")), Some(named));
        assert!(parse_trace_field(&Json::Null).is_none());
        assert!(parse_trace_field(&Json::num(-1.0)).is_none());
    }
}
