//! Prometheus text exposition (format version 0.0.4), hand-rolled.
//!
//! Workers answer `{"cmd":"metrics","format":"prometheus"}` with a body
//! built through [`PromWriter`]; the router aggregates the fleet by
//! re-labeling each worker's body with a `worker="<i>"` label via
//! [`relabel`] and concatenating.

use std::collections::BTreeSet;

/// Incremental builder for a Prometheus text body. `# HELP`/`# TYPE`
/// headers are emitted once per metric name, on first write.
#[derive(Default)]
pub struct PromWriter {
    out: String,
    seen: BTreeSet<String>,
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter::default()
    }

    /// Append one sample. `kind` is `"counter"` or `"gauge"`; `labels`
    /// render as `{k="v",...}`. Non-finite values render as `NaN`, which
    /// Prometheus accepts.
    pub fn write(
        &mut self,
        name: &str,
        kind: &str,
        help: &str,
        labels: &[(&str, &str)],
        value: f64,
    ) {
        if self.seen.insert(name.to_string()) {
            self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        }
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}={:?}", v));
            }
            self.out.push('}');
        }
        if value.is_finite() {
            // integers print without a fractional part, like the rest of
            // the wire format
            if value.fract() == 0.0 && value.abs() < 1e15 {
                self.out.push_str(&format!(" {}\n", value as i64));
            } else {
                self.out.push_str(&format!(" {value}\n"));
            }
        } else {
            self.out.push_str(" NaN\n");
        }
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Insert `key="value"` into every sample line of an existing exposition
/// body (comment lines pass through). Used by the router to tag each
/// worker's metrics before concatenating the fleet view.
pub fn relabel(body: &str, key: &str, value: &str) -> String {
    let mut out = String::with_capacity(body.len() + 64);
    for line in body.lines() {
        if line.is_empty() || line.starts_with('#') {
            out.push_str(line);
            out.push('\n');
            continue;
        }
        if let Some(brace) = line.find('{') {
            out.push_str(&line[..=brace]);
            out.push_str(&format!("{key}={value:?},"));
            out.push_str(&line[brace + 1..]);
        } else if let Some(space) = line.find(' ') {
            out.push_str(&line[..space]);
            out.push_str(&format!("{{{key}={value:?}}}"));
            out.push_str(&line[space..]);
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headers_emitted_once_per_name() {
        let mut w = PromWriter::new();
        w.write("cq_requests_total", "counter", "Requests seen.", &[], 3.0);
        w.write("cq_requests_total", "counter", "Requests seen.", &[("kind", "gen")], 1.0);
        let body = w.finish();
        assert_eq!(body.matches("# HELP cq_requests_total").count(), 1);
        assert_eq!(body.matches("# TYPE cq_requests_total counter").count(), 1);
        assert!(body.contains("cq_requests_total 3\n"));
        assert!(body.contains("cq_requests_total{kind=\"gen\"} 1\n"));
    }

    #[test]
    fn relabel_handles_both_line_shapes() {
        let body = "# HELP m h\n# TYPE m gauge\nm 1\nm{a=\"b\"} 2\n";
        let tagged = relabel(body, "worker", "0");
        assert!(tagged.contains("m{worker=\"0\"} 1\n"));
        assert!(tagged.contains("m{worker=\"0\",a=\"b\"} 2\n"));
        assert!(tagged.contains("# HELP m h\n"));
    }

    #[test]
    fn nonfinite_values_render_as_nan() {
        let mut w = PromWriter::new();
        w.write("m", "gauge", "h", &[], f64::NAN);
        assert!(w.finish().contains("m NaN\n"));
    }
}
