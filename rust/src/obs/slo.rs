//! Declarative SLOs and multi-window error-budget burn rates.
//!
//! PR 9 gave the serving tier rolling latency histograms; this module is
//! the layer that turns them into a decision signal. An [`SloSpec`]
//! states three objectives — TTFT p99, inter-token p99, and error rate —
//! and [`SloSpec::evaluate_at`] computes, per window, how fast each
//! objective is burning its error budget:
//!
//! * A latency objective "p99 ≤ X" implicitly budgets 1% of requests to
//!   exceed X. Its burn rate over a window is
//!   `fraction_above(X) / 0.01` — burn 1.0 consumes the budget exactly
//!   at the sustainable rate, burn 100 means *every* request violates.
//! * The error-rate objective budgets `error_rate` of requests to fail;
//!   burn is `observed_error_fraction / error_rate`.
//!
//! Burn is computed over three windows — fast 1 s and 10 s, slow 60 s,
//! all under the [`Rolling`] ring's 64-slot capacity — and alerting
//! follows the classic multi-window rule: a window alerts when any
//! objective's burn reaches `burn_threshold`, and the tier *sheds* only
//! when both a fast window and the slow window alert. The fast window
//! confirms the overload is happening right now (so shedding stops
//! quickly on recovery); the slow window confirms it is sustained (so a
//! one-second blip never sheds). Every `*_at` entry point takes an
//! explicit epoch second, mirroring [`Rolling::window_at`], so the burn
//! math is property-testable under an injected clock.

use std::sync::atomic::{AtomicU64, Ordering};

use super::hist::{Histogram, Rolling, RollingCount};
use crate::util::Json;

/// The three burn windows, seconds. The first `FAST_WINDOWS` are "fast";
/// the rest are "slow". All must stay below the rolling ring's 64 slots.
pub const WINDOWS_S: [u64; 3] = [1, 10, 60];
const FAST_WINDOWS: usize = 2;

/// Budget fraction a p99 objective implies: 1% of requests may exceed
/// the target.
const P99_BUDGET: f64 = 0.01;

/// One service-level objective set. Latency targets are upper bounds on
/// the p99; `error_rate` is the budgeted failure fraction;
/// `burn_threshold` is the burn rate at which a window starts alerting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloSpec {
    pub ttft_p99_us: u64,
    pub inter_token_p99_us: u64,
    pub error_rate: f64,
    pub burn_threshold: f64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec {
            ttft_p99_us: 500_000,
            inter_token_p99_us: 200_000,
            error_rate: 0.01,
            burn_threshold: 10.0,
        }
    }
}

/// Burn rate of a "p99 ≤ threshold" latency objective over one window
/// histogram: violation fraction over the implied 1% budget.
pub fn latency_burn(window: &Histogram, threshold_us: u64) -> f64 {
    window.fraction_above(threshold_us) / P99_BUDGET
}

/// Burn rate of the error-rate objective over windowed ok/err counts.
pub fn error_burn(ok: u64, err: u64, target_rate: f64) -> f64 {
    let total = ok + err;
    if total == 0 || target_rate <= 0.0 {
        return 0.0;
    }
    (err as f64 / total as f64) / target_rate
}

/// Per-window burn rates for every objective, plus the sample counts the
/// rates were computed over (a burn over zero samples is 0, and the
/// counts let readers see that).
#[derive(Clone, Copy, Debug)]
pub struct WindowBurn {
    pub window_s: u64,
    pub ttft_burn: f64,
    pub inter_token_burn: f64,
    pub error_burn: f64,
    pub ttft_samples: u64,
    pub requests: u64,
    pub alerting: bool,
}

impl WindowBurn {
    /// The worst objective's burn — what alerting keys on.
    pub fn max_burn(&self) -> f64 {
        self.ttft_burn.max(self.inter_token_burn).max(self.error_burn)
    }

    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("window_s", Json::num(self.window_s as f64)),
            ("ttft_burn", Json::num(self.ttft_burn)),
            ("inter_token_burn", Json::num(self.inter_token_burn)),
            ("error_burn", Json::num(self.error_burn)),
            ("max_burn", Json::num(self.max_burn())),
            ("ttft_samples", Json::num(self.ttft_samples as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("alerting", Json::Bool(self.alerting)),
        ])
    }
}

/// The rolling signals an evaluation reads — borrowed from `Metrics`, or
/// built standalone in tests.
pub struct SloInputs<'a> {
    pub ttft: &'a Rolling,
    pub inter_token: &'a Rolling,
    pub ok: &'a RollingCount,
    pub err: &'a RollingCount,
}

/// A full multi-window evaluation: per-window burns plus the combined
/// alert booleans. `shedding` is the bit admission control consumes.
#[derive(Clone, Debug)]
pub struct SloReport {
    pub spec: SloSpec,
    pub windows: Vec<WindowBurn>,
    pub fast_alert: bool,
    pub slow_alert: bool,
    pub shedding: bool,
}

impl SloReport {
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("spec", self.spec.json()),
            ("windows", Json::arr(self.windows.iter().map(|w| w.json()).collect())),
            ("fast_alert", Json::Bool(self.fast_alert)),
            ("slow_alert", Json::Bool(self.slow_alert)),
            ("shedding", Json::Bool(self.shedding)),
        ])
    }
}

impl SloSpec {
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("ttft_p99_us", Json::num(self.ttft_p99_us as f64)),
            ("inter_token_p99_us", Json::num(self.inter_token_p99_us as f64)),
            ("error_rate", Json::num(self.error_rate)),
            ("burn_threshold", Json::num(self.burn_threshold)),
        ])
    }

    pub fn evaluate(&self, inputs: &SloInputs) -> SloReport {
        self.evaluate_at(inputs, super::now_secs())
    }

    /// Evaluate every objective over every window at an explicit epoch
    /// second — deterministic under an injected clock.
    pub fn evaluate_at(&self, inputs: &SloInputs, now_s: u64) -> SloReport {
        let windows: Vec<WindowBurn> = WINDOWS_S
            .iter()
            .map(|&w| {
                let ttft = inputs.ttft.window_at(now_s, w);
                let inter = inputs.inter_token.window_at(now_s, w);
                let ok = inputs.ok.window_at(now_s, w);
                let err = inputs.err.window_at(now_s, w);
                let mut burn = WindowBurn {
                    window_s: w,
                    ttft_burn: latency_burn(&ttft, self.ttft_p99_us),
                    inter_token_burn: latency_burn(&inter, self.inter_token_p99_us),
                    error_burn: error_burn(ok, err, self.error_rate),
                    ttft_samples: ttft.count(),
                    requests: ok + err,
                    alerting: false,
                };
                burn.alerting = burn.max_burn() >= self.burn_threshold;
                burn
            })
            .collect();
        let fast_alert = windows[..FAST_WINDOWS].iter().any(|w| w.alerting);
        let slow_alert = windows[FAST_WINDOWS..].iter().any(|w| w.alerting);
        SloReport { spec: *self, windows, fast_alert, slow_alert, shedding: fast_alert && slow_alert }
    }
}

/// The live, shareable policy cell: an [`SloSpec`] behind relaxed
/// atomics, configured once at startup from the `--slo-*` flags and read
/// on every evaluation — the same configure-once pattern as
/// [`super::KernelTelemetry`].
pub struct SloPolicy {
    ttft_p99_us: AtomicU64,
    inter_token_p99_us: AtomicU64,
    error_rate_bits: AtomicU64,
    burn_threshold_bits: AtomicU64,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy::new(SloSpec::default())
    }
}

impl SloPolicy {
    pub fn new(spec: SloSpec) -> SloPolicy {
        let p = SloPolicy {
            ttft_p99_us: AtomicU64::new(0),
            inter_token_p99_us: AtomicU64::new(0),
            error_rate_bits: AtomicU64::new(0),
            burn_threshold_bits: AtomicU64::new(0),
        };
        p.configure(spec);
        p
    }

    pub fn configure(&self, spec: SloSpec) {
        self.ttft_p99_us.store(spec.ttft_p99_us, Ordering::Relaxed);
        self.inter_token_p99_us.store(spec.inter_token_p99_us, Ordering::Relaxed);
        self.error_rate_bits.store(spec.error_rate.to_bits(), Ordering::Relaxed);
        self.burn_threshold_bits.store(spec.burn_threshold.to_bits(), Ordering::Relaxed);
    }

    pub fn spec(&self) -> SloSpec {
        SloSpec {
            ttft_p99_us: self.ttft_p99_us.load(Ordering::Relaxed),
            inter_token_p99_us: self.inter_token_p99_us.load(Ordering::Relaxed),
            error_rate: f64::from_bits(self.error_rate_bits.load(Ordering::Relaxed)),
            burn_threshold: f64::from_bits(self.burn_threshold_bits.load(Ordering::Relaxed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs() -> (Rolling, Rolling, RollingCount, RollingCount) {
        (Rolling::new(), Rolling::new(), RollingCount::new(), RollingCount::new())
    }

    #[test]
    fn empty_windows_burn_nothing() {
        let (ttft, inter, ok, err) = inputs();
        let report = SloSpec::default()
            .evaluate_at(&SloInputs { ttft: &ttft, inter_token: &inter, ok: &ok, err: &err }, 100);
        assert_eq!(report.windows.len(), WINDOWS_S.len());
        for w in &report.windows {
            assert_eq!(w.max_burn(), 0.0);
            assert!(!w.alerting);
        }
        assert!(!report.shedding);
    }

    #[test]
    fn all_violations_burn_at_one_over_budget() {
        let (ttft, inter, ok, err) = inputs();
        let spec = SloSpec { ttft_p99_us: 1_000, ..SloSpec::default() };
        for _ in 0..50 {
            ttft.record_at(100, 50_000); // every TTFT violates
            ok.record_at(100);
        }
        let report =
            spec.evaluate_at(&SloInputs { ttft: &ttft, inter_token: &inter, ok: &ok, err: &err }, 100);
        // 100% violation over a 1% budget: burn 100 on every window
        for w in &report.windows {
            assert!((w.ttft_burn - 100.0).abs() < 1e-9, "burn {}", w.ttft_burn);
            assert!(w.alerting);
        }
        assert!(report.fast_alert && report.slow_alert && report.shedding);
    }

    #[test]
    fn error_burn_is_observed_rate_over_budget() {
        assert_eq!(error_burn(0, 0, 0.01), 0.0);
        assert_eq!(error_burn(99, 1, 0.01), 1.0); // exactly on budget
        assert_eq!(error_burn(0, 10, 0.01), 100.0);
        assert_eq!(error_burn(10, 0, 0.0), 0.0); // zero budget never divides
    }

    #[test]
    fn policy_round_trips_spec() {
        let spec = SloSpec {
            ttft_p99_us: 123,
            inter_token_p99_us: 456,
            error_rate: 0.05,
            burn_threshold: 2.5,
        };
        let policy = SloPolicy::new(spec);
        assert_eq!(policy.spec(), spec);
        policy.configure(SloSpec::default());
        assert_eq!(policy.spec(), SloSpec::default());
    }
}
