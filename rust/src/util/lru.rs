//! A small bounded least-recently-used cache.
//!
//! Built for caches of a handful of heavyweight entries (the coordinator's
//! calibrated static models: capacity 8, each entry a full integer model),
//! where the previous `HashMap` + `keys().next()` eviction dropped an
//! *arbitrary* entry — under an α sweep that could evict the hottest model
//! every time. Recency updates are O(capacity) Vec scans, which at these
//! sizes is cheaper than any linked structure.

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;

/// Bounded map with least-recently-used eviction. Both `get` and `insert`
/// count as a use.
pub struct LruCache<K: Eq + Hash + Clone, V> {
    capacity: usize,
    map: HashMap<K, V>,
    /// Recency order: front = least recently used, back = most.
    order: VecDeque<K>,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    pub fn new(capacity: usize) -> LruCache<K, V> {
        assert!(capacity > 0, "LruCache capacity must be > 0");
        LruCache { capacity, map: HashMap::new(), order: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Look up `key`, marking it most recently used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        if self.map.contains_key(key) {
            self.touch(key);
        }
        self.map.get(key)
    }

    /// Insert (or replace) `key`, marking it most recently used. If this
    /// pushes the cache past capacity, the least-recently-used entry is
    /// evicted and returned.
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        let replaced = self.map.insert(key.clone(), value).is_some();
        if replaced {
            self.touch(&key);
        } else {
            self.order.push_back(key);
        }
        if self.map.len() > self.capacity {
            let lru = self.order.pop_front().expect("order tracks map");
            let v = self.map.remove(&lru).expect("order keys live in map");
            return Some((lru, v));
        }
        None
    }

    /// Keys from least to most recently used (test/debug surface).
    pub fn keys_lru_order(&self) -> impl Iterator<Item = &K> {
        self.order.iter()
    }

    fn touch(&mut self, key: &K) {
        if let Some(pos) = self.order.iter().position(|k| k == key) {
            let k = self.order.remove(pos).expect("position is in range");
            self.order.push_back(k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(c: &LruCache<i32, i32>) -> Vec<i32> {
        c.keys_lru_order().copied().collect()
    }

    #[test]
    fn evicts_least_recently_used_not_arbitrary() {
        let mut c = LruCache::new(3);
        for k in 1..=3 {
            assert!(c.insert(k, k * 10).is_none());
        }
        // touch 1 — it becomes MRU, so 2 is now the eviction candidate
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(keys(&c), vec![2, 3, 1]);
        let evicted = c.insert(4, 40);
        assert_eq!(evicted, Some((2, 20)));
        assert_eq!(c.len(), 3);
        assert!(!c.contains(&2));
        // full eviction order from here: 3, then 1, then 4
        assert_eq!(c.insert(5, 50), Some((3, 30)));
        assert_eq!(c.insert(6, 60), Some((1, 10)));
        assert_eq!(keys(&c), vec![4, 5, 6]);
    }

    #[test]
    fn reinsert_refreshes_recency_and_replaces_value() {
        let mut c = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11); // replace: no eviction, 1 becomes MRU
        assert_eq!(c.len(), 2);
        assert_eq!(keys(&c), vec![2, 1]);
        assert_eq!(c.insert(3, 30), Some((2, 20)));
        assert_eq!(c.get(&1), Some(&11));
    }

    #[test]
    fn miss_does_not_disturb_order() {
        let mut c = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.get(&9), None);
        assert_eq!(keys(&c), vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "capacity must be > 0")]
    fn zero_capacity_rejected() {
        let _ = LruCache::<i32, i32>::new(0);
    }
}
