//! Deterministic fault injection for the serving tier.
//!
//! A worker process parses the `CROSSQUANT_FAULT` environment variable once at
//! startup into a [`FaultInjector`]. Request-handling code consults the
//! injector on every *data* request (score/generate — never `cmd` control
//! frames, so heartbeats and metrics cannot perturb the schedule) and applies
//! whichever action the plan selects. Because the plan keys off a per-process
//! request counter, fault scenarios are bit-for-bit reproducible: the Nth data
//! request always hits the same fault regardless of thread interleaving.
//!
//! Plan grammar (`;`-separated rules, first matching rule wins):
//!
//! ```text
//! CROSSQUANT_FAULT="panic:nth=5"              # abort the process on request 5
//! CROSSQUANT_FAULT="latency:ms=250,every=2"   # sleep 250ms on every 2nd request
//! CROSSQUANT_FAULT="drop:nth=3"               # drop the connection, no response
//! CROSSQUANT_FAULT="truncate:nth=2"           # write half a response, no newline
//! CROSSQUANT_FAULT="latency:ms=50,after=10"   # sleep on every request past 10
//! ```
//!
//! Selectors: `nth=K` fires exactly on the Kth data request (1-based),
//! `every=K` fires on every Kth request, `after=K` fires on every request
//! strictly after the Kth. A rule with no selector fires on every request.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// What the request handler should do to the current request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// No fault scheduled for this request.
    None,
    /// Abort the whole process (simulates a worker crash mid-request).
    Panic,
    /// Sleep for the given duration before responding.
    Latency(Duration),
    /// Close the connection without writing a response line.
    DropConnection,
    /// Write a truncated response (partial line, no terminating newline),
    /// then close the connection.
    TruncateResponse,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Selector {
    /// Fire exactly on the Kth request (1-based).
    Nth(u64),
    /// Fire on every Kth request.
    Every(u64),
    /// Fire on every request strictly after the Kth.
    After(u64),
    /// Fire on every request.
    Always,
}

impl Selector {
    fn matches(self, n: u64) -> bool {
        match self {
            Selector::Nth(k) => n == k,
            Selector::Every(k) => k > 0 && n % k == 0,
            Selector::After(k) => n > k,
            Selector::Always => true,
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Rule {
    selector: Selector,
    action: FaultAction,
}

/// Parsed fault plan plus the shared data-request counter.
#[derive(Debug)]
pub struct FaultInjector {
    rules: Vec<Rule>,
    counter: AtomicU64,
}

impl FaultInjector {
    /// Parse the `CROSSQUANT_FAULT` environment variable. Absent or empty
    /// means no faults; a malformed plan is a hard error so a typo in a test
    /// harness can never silently disable the scenario it meant to set up.
    pub fn from_env() -> anyhow::Result<Self> {
        match std::env::var("CROSSQUANT_FAULT") {
            Ok(plan) => Self::parse(&plan),
            Err(_) => Ok(Self::none()),
        }
    }

    /// An injector that never fires.
    pub fn none() -> Self {
        FaultInjector {
            rules: Vec::new(),
            counter: AtomicU64::new(0),
        }
    }

    /// True when the plan contains at least one rule.
    pub fn is_active(&self) -> bool {
        !self.rules.is_empty()
    }

    /// Parse a plan string (see module docs for the grammar).
    pub fn parse(plan: &str) -> anyhow::Result<Self> {
        let mut rules = Vec::new();
        for part in plan.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            rules.push(Self::parse_rule(part)?);
        }
        Ok(FaultInjector {
            rules,
            counter: AtomicU64::new(0),
        })
    }

    fn parse_rule(rule: &str) -> anyhow::Result<Rule> {
        let (kind, args) = match rule.split_once(':') {
            Some((k, a)) => (k.trim(), a.trim()),
            None => (rule, ""),
        };
        let mut selector = Selector::Always;
        let mut latency_ms: Option<u64> = None;
        for kv in args.split(',') {
            let kv = kv.trim();
            if kv.is_empty() {
                continue;
            }
            let (key, value) = kv.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("fault rule `{rule}`: expected key=value, got `{kv}`")
            })?;
            let value: u64 = value
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault rule `{rule}`: `{kv}` is not an integer"))?;
            match key.trim() {
                "nth" => selector = Selector::Nth(value),
                "every" => {
                    if value == 0 {
                        anyhow::bail!("fault rule `{rule}`: every=0 is meaningless");
                    }
                    selector = Selector::Every(value);
                }
                "after" => selector = Selector::After(value),
                "ms" => latency_ms = Some(value),
                other => anyhow::bail!("fault rule `{rule}`: unknown key `{other}`"),
            }
        }
        let action = match kind {
            "panic" => FaultAction::Panic,
            "latency" => {
                let ms = latency_ms.ok_or_else(|| {
                    anyhow::anyhow!("fault rule `{rule}`: latency requires ms=<int>")
                })?;
                FaultAction::Latency(Duration::from_millis(ms))
            }
            "drop" => FaultAction::DropConnection,
            "truncate" => FaultAction::TruncateResponse,
            other => anyhow::bail!("unknown fault kind `{other}` in rule `{rule}`"),
        };
        if kind != "latency" && latency_ms.is_some() {
            anyhow::bail!("fault rule `{rule}`: ms= only applies to latency");
        }
        Ok(Rule { selector, action })
    }

    /// Advance the data-request counter and return the action scheduled for
    /// this request, if any. First matching rule wins.
    pub fn on_data_request(&self) -> FaultAction {
        if self.rules.is_empty() {
            return FaultAction::None;
        }
        let n = self.counter.fetch_add(1, Ordering::SeqCst) + 1;
        for rule in &self.rules {
            if rule.selector.matches(n) {
                return rule.action;
            }
        }
        FaultAction::None
    }

    /// Apply the process-local side of an action: sleeping for latency faults
    /// and aborting for panic faults. Connection-level actions (drop,
    /// truncate) are returned to the caller, which owns the socket.
    pub fn apply_local(&self, action: FaultAction) -> FaultAction {
        match action {
            FaultAction::Latency(d) => {
                std::thread::sleep(d);
                FaultAction::None
            }
            FaultAction::Panic => {
                eprintln!("CROSSQUANT_FAULT: injected panic, aborting worker");
                std::process::abort();
            }
            other => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_fires() {
        let inj = FaultInjector::parse("").unwrap();
        assert!(!inj.is_active());
        for _ in 0..16 {
            assert_eq!(inj.on_data_request(), FaultAction::None);
        }
    }

    #[test]
    fn nth_fires_exactly_once() {
        let inj = FaultInjector::parse("panic:nth=3").unwrap();
        assert_eq!(inj.on_data_request(), FaultAction::None);
        assert_eq!(inj.on_data_request(), FaultAction::None);
        assert_eq!(inj.on_data_request(), FaultAction::Panic);
        assert_eq!(inj.on_data_request(), FaultAction::None);
    }

    #[test]
    fn every_fires_periodically() {
        let inj = FaultInjector::parse("latency:ms=5,every=2").unwrap();
        let expect = FaultAction::Latency(Duration::from_millis(5));
        assert_eq!(inj.on_data_request(), FaultAction::None);
        assert_eq!(inj.on_data_request(), expect);
        assert_eq!(inj.on_data_request(), FaultAction::None);
        assert_eq!(inj.on_data_request(), expect);
    }

    #[test]
    fn after_fires_past_threshold() {
        let inj = FaultInjector::parse("drop:after=2").unwrap();
        assert_eq!(inj.on_data_request(), FaultAction::None);
        assert_eq!(inj.on_data_request(), FaultAction::None);
        assert_eq!(inj.on_data_request(), FaultAction::DropConnection);
        assert_eq!(inj.on_data_request(), FaultAction::DropConnection);
    }

    #[test]
    fn multiple_rules_first_match_wins() {
        let inj = FaultInjector::parse("truncate:nth=2; drop:every=3").unwrap();
        assert_eq!(inj.on_data_request(), FaultAction::None);
        assert_eq!(inj.on_data_request(), FaultAction::TruncateResponse);
        assert_eq!(inj.on_data_request(), FaultAction::DropConnection);
        assert_eq!(inj.on_data_request(), FaultAction::None);
    }

    #[test]
    fn malformed_plans_are_rejected() {
        for bad in [
            "explode:nth=1",
            "panic:nth",
            "panic:nth=x",
            "latency:every=2",
            "latency:ms=1,bogus=2",
            "drop:ms=5",
            "latency:ms=1,every=0",
        ] {
            assert!(FaultInjector::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn bare_kind_fires_always() {
        let inj = FaultInjector::parse("drop").unwrap();
        assert_eq!(inj.on_data_request(), FaultAction::DropConnection);
        assert_eq!(inj.on_data_request(), FaultAction::DropConnection);
    }
}
