//! Minimal read-only file memory-mapping (the offline build has no
//! memmap2 — see Cargo.toml). On 64-bit unix the mapping goes through
//! the raw `mmap`/`munmap` symbols libc already links into every binary;
//! elsewhere — 32-bit targets (where `off_t`'s width is configuration-
//! dependent and a mismatched extern signature would be UB, not a clean
//! error), non-unix platforms, empty files, and any syscall failure —
//! the bytes are read into an owned buffer behind the same API, so
//! callers never branch on platform.
//!
//! The map is `PROT_READ`/`MAP_PRIVATE`: the bytes live in the page
//! cache, are shared between processes mapping the same file, and are
//! paged in on first touch — the zero-copy substrate under
//! [`crate::quant::artifact`]'s panel sections.

use std::fs::File;
use std::path::Path;

use anyhow::{Context, Result};

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::c_void;
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
}

enum Backing {
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped { ptr: *const u8, len: usize },
    Owned(Vec<u8>),
}

/// A read-only byte view of a file: memory-mapped where possible, an
/// owned buffer otherwise.
pub struct Mmap {
    backing: Backing,
}

// The mapping is read-only for its whole lifetime and unmapped exactly
// once in Drop, so sharing the view across threads is safe.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only. Falls back to reading the file into memory
    /// when mapping is unavailable (non-unix or 32-bit target, empty
    /// file, syscall error).
    pub fn map(path: &Path) -> Result<Mmap> {
        let file =
            File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len() as usize;
        #[cfg(all(unix, target_pointer_width = "64"))]
        if len > 0 {
            use std::os::unix::io::AsRawFd;
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize != -1 {
                return Ok(Mmap { backing: Backing::Mapped { ptr: ptr as *const u8, len } });
            }
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        let _ = len;
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Ok(Mmap { backing: Backing::Owned(bytes) })
    }

    /// Wrap an in-memory buffer behind the same API (tests, writers that
    /// validate before hitting disk).
    pub fn from_vec(bytes: Vec<u8>) -> Mmap {
        Mmap { backing: Backing::Owned(bytes) }
    }

    /// The mapped (or owned) bytes.
    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Backing::Owned(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.bytes().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the bytes are served by a real file mapping rather than
    /// an owned buffer — the zero-copy invariant artifact tests pin.
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mapped { .. } => true,
            Backing::Owned(_) => false,
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let Backing::Mapped { ptr, len } = &self.backing {
            unsafe {
                sys::munmap(*ptr as *mut std::ffi::c_void, *len);
            }
        }
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cq-mmap-{}-{name}", std::process::id()))
    }

    #[test]
    fn maps_file_contents() {
        let path = tmp("basic");
        std::fs::write(&path, b"panel bytes in place").unwrap();
        let m = Mmap::map(&path).unwrap();
        assert_eq!(m.bytes(), b"panel bytes in place");
        assert_eq!(m.len(), 20);
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(m.is_mapped(), "64-bit unix must serve a real mapping");
        drop(m); // munmap must not fault
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_file_is_owned_and_safe() {
        let path = tmp("empty");
        std::fs::write(&path, b"").unwrap();
        let m = Mmap::map(&path).unwrap();
        assert!(m.is_empty());
        assert!(!m.is_mapped());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_error() {
        assert!(Mmap::map(Path::new("/nonexistent/nowhere.cqa")).is_err());
    }

    #[test]
    fn from_vec_roundtrip() {
        let m = Mmap::from_vec(vec![1, 2, 3]);
        assert_eq!(m.bytes(), &[1, 2, 3]);
        assert!(!m.is_mapped());
    }
}
