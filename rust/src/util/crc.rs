//! CRC-32 (IEEE 802.3, the zlib/PNG polynomial) — the integrity checksum
//! of the `.cqa` deployment artifact format (`quant::artifact`). Table-
//! driven, built at compile time; no external crate (offline dependency
//! policy, see Cargo.toml).

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (init 0xFFFFFFFF, reflected, final xor — the
/// standard checksum `cksum`/zlib users expect; `crc32(b"123456789")`
/// is `0xCBF43926`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_value() {
        // the canonical CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_and_sensitivity() {
        assert_eq!(crc32(b""), 0);
        let a = crc32(b"deployable artifact");
        let b = crc32(b"deployable artifacu");
        assert_ne!(a, b);
        // a single flipped bit anywhere changes the sum
        let mut buf = vec![0xA5u8; 1024];
        let clean = crc32(&buf);
        buf[517] ^= 0x10;
        assert_ne!(crc32(&buf), clean);
    }
}
