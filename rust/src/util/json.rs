//! Minimal JSON parser + writer (the build environment has no serde_json;
//! see Cargo.toml). Covers the full JSON grammar this project produces and
//! consumes: artifacts/manifest.json and the table dumps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- accessors ----------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    // ---------- construction ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---------- parsing ----------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---------- writing ----------

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_nan() || n.is_infinite() {
                    // JSON has no NaN/Inf; null is the conventional encoding
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if !pretty {
                            out.push(' ');
                        }
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if !pretty {
                            out.push(' ');
                        }
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes.get(self.pos).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek()? == b {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}, found '{}'", b as char, self.pos, self.peek()? as char)
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                // multi-byte UTF-8: copy raw bytes until a char boundary
                b if b >= 0x80 => {
                    let start = self.pos - 1;
                    while self.pos < self.bytes.len() && self.bytes[self.pos] & 0xC0 == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
                b => s.push(b as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"config": {"vocab": 512, "d_model": 128}, "params": [{"name": "tok_emb", "shape": [512, 128], "offset": 0, "size": 65536}], "total_params": 932096, "train": {"final_loss": 2.31, "final_ppl": 10.07, "steps": 400}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("config").unwrap().get("vocab").unwrap().as_usize(), Some(512));
        assert_eq!(
            v.get("params").unwrap().idx(0).unwrap().get("name").unwrap().as_str(),
            Some("tok_emb")
        );
        // render → parse → equal
        let re = Json::parse(&v.render()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_nested_and_escapes() {
        let v = Json::parse(r#"{"a": [1, 2.5, -3e2, true, false, null, "x\nyA"]}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].as_f64(), Some(-300.0));
        assert_eq!(arr[6].as_str(), Some("x\nyA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("{\"k\": \"α β̃ ≥\"}").unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("α β̃ ≥"));
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn pretty_render_parses_back() {
        let v = Json::obj(vec![
            ("rows", Json::arr(vec![Json::num(1.0), Json::num(2.25)])),
            ("title", Json::str("Table 2")),
        ]);
        let pretty = v.render_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }
}
