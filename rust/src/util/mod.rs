//! Small self-contained utilities (the offline build has no serde/clap —
//! see Cargo.toml).

pub mod json;
pub mod lru;

pub use json::Json;
pub use lru::LruCache;
