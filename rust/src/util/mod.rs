//! Small self-contained utilities (the offline build has no serde/clap —
//! see Cargo.toml).

pub mod crc;
pub mod fault;
pub mod json;
pub mod lru;
pub mod mmap;

pub use crc::crc32;
pub use fault::{FaultAction, FaultInjector};
pub use json::Json;
pub use lru::LruCache;
pub use mmap::Mmap;
