//! Language-modeling perplexity — the paper's Table 2 / Figures 5–7 metric.
//!
//! ppl = exp( mean per-position NLL ) over a fixed evaluation stream drawn
//! from one of the synthetic corpora. Two paths produce the NLLs:
//! the native rust forward (fast; large sweeps) and the PJRT artifacts
//! (the production three-layer path) — integration tests pin them to agree.

use anyhow::Result;

use crate::corpus::{CorpusGen, CorpusKind};
use crate::model::{ActSite, NativeModel};

#[derive(Clone, Copy, Debug)]
pub struct PerplexityResult {
    pub perplexity: f64,
    pub mean_nll: f64,
    pub tokens: usize,
}

impl PerplexityResult {
    pub fn from_nlls(nlls: &[f32]) -> PerplexityResult {
        let n = nlls.len().max(1);
        let mean = nlls.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        PerplexityResult { perplexity: mean.exp(), mean_nll: mean, tokens: nlls.len() }
    }
}

/// Evaluate perplexity with the native forward pass.
///
/// `sequences` eval sequences of the model's full context length are drawn
/// from `kind` with a fixed seed (disjoint from the training seed), so
/// every scheme sees the identical stream.
pub fn perplexity_native(
    model: &NativeModel,
    site: &mut dyn ActSite,
    kind: CorpusKind,
    sequences: usize,
    seed: u64,
) -> Result<PerplexityResult> {
    let cfg = model.weights.config;
    let mut gen = CorpusGen::with_kind(cfg.vocab, seed, kind);
    let mut nlls = Vec::with_capacity(sequences * (cfg.seq_len - 1));
    for _ in 0..sequences {
        let toks = gen.sequence(cfg.seq_len);
        nlls.extend(model.forward_nll(&toks, site)?);
    }
    Ok(PerplexityResult::from_nlls(&nlls))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{
        config::ModelConfig, weights::synthetic_weights as test_weights, IdentitySite,
    };

    #[test]
    fn from_nlls_math() {
        let r = PerplexityResult::from_nlls(&[1.0, 1.0, 1.0]);
        assert!((r.perplexity - std::f64::consts::E).abs() < 1e-9);
        assert_eq!(r.tokens, 3);
    }

    #[test]
    fn random_model_near_uniform_ppl() {
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            eval_batch: 2,
        };
        let m = NativeModel::new(test_weights(cfg, 2));
        let r = perplexity_native(&m, &mut IdentitySite, CorpusKind::Wiki2, 4, 99).unwrap();
        assert!(r.perplexity > 32.0 && r.perplexity < 128.0, "{}", r.perplexity);
    }

    #[test]
    fn deterministic_across_calls() {
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            eval_batch: 2,
        };
        let m = NativeModel::new(test_weights(cfg, 2));
        let a = perplexity_native(&m, &mut IdentitySite, CorpusKind::Wiki2, 3, 7).unwrap();
        let b = perplexity_native(&m, &mut IdentitySite, CorpusKind::Wiki2, 3, 7).unwrap();
        assert_eq!(a.perplexity, b.perplexity);
    }
}
