//! Evaluation suite: perplexity over the synthetic corpora plus the
//! synthetic zero-/few-shot tasks mirroring the paper's lm-eval setup.

pub mod harness;
pub mod perplexity;
pub mod tasks;

pub use perplexity::{perplexity_native, PerplexityResult};
pub use tasks::{Task, TaskResult, TaskSuite};
