//! Evaluation suite: perplexity over the synthetic corpora, the synthetic
//! zero-/few-shot tasks mirroring the paper's lm-eval setup, and the
//! generation-workload instrumentation (prefill/decode timing).

pub mod generation;
pub mod harness;
pub mod perplexity;
pub mod tasks;

pub use generation::{generate_serial, generate_timed, DecodeTiming, IncrementalDecoder};
pub use perplexity::{perplexity_native, PerplexityResult};
pub use tasks::{Task, TaskResult, TaskSuite};
