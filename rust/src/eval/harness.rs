//! Table assembly + printing in the paper's row format, shared by every
//! `exp::*` reproduction module and the CLI.

/// One printed row: method label, W/A setting, then named numeric cells.
#[derive(Clone, Debug)]
pub struct Row {
    pub method: String,
    pub setting: String,
    pub cells: Vec<f64>,
}

impl Row {
    pub fn new(method: impl Into<String>, setting: impl Into<String>, cells: Vec<f64>) -> Row {
        Row { method: method.into(), setting: setting.into(), cells }
    }
}

/// A paper table/figure reproduction, ready to print or serialize.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Row>,
    /// Formatting hint: how many decimals per cell.
    pub decimals: usize,
    /// Render cells as percentages.
    pub percent: bool,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: Vec<&str>) -> Table {
        Table {
            title: title.into(),
            columns: columns.into_iter().map(String::from).collect(),
            rows: Vec::new(),
            decimals: 2,
            percent: false,
        }
    }

    pub fn percent(mut self) -> Table {
        self.percent = true;
        self
    }

    pub fn decimals(mut self, d: usize) -> Table {
        self.decimals = d;
        self
    }

    pub fn push(&mut self, row: Row) {
        assert_eq!(row.cells.len(), self.columns.len(), "row width mismatch");
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let method_w = self
            .rows
            .iter()
            .map(|r| r.method.len())
            .chain(std::iter::once("Method".len()))
            .max()
            .unwrap_or(8)
            + 2;
        let set_w = self
            .rows
            .iter()
            .map(|r| r.setting.len())
            .chain(std::iter::once("W/A".len()))
            .max()
            .unwrap_or(6)
            + 2;
        let col_ws: Vec<usize> =
            self.columns.iter().map(|c| (c.chars().count() + 2).max(12)).collect();
        out.push_str(&format!("{:method_w$}{:set_w$}", "Method", "W/A"));
        for (c, w) in self.columns.iter().zip(&col_ws) {
            out.push_str(&format!("{c:>w$}", w = *w));
        }
        out.push('\n');
        out.push_str(&"-".repeat(method_w + set_w + col_ws.iter().sum::<usize>()));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&format!("{:method_w$}{:set_w$}", r.method, r.setting));
            for (&v, w) in r.cells.iter().zip(&col_ws) {
                let cell = if v.is_nan() {
                    "-".to_string()
                } else if self.percent {
                    format!("{:.1$}%", v * 100.0, self.decimals)
                } else if v >= 1e4 {
                    format!("{:.0e}", v)
                } else {
                    format!("{:.1$}", v, self.decimals)
                };
                out.push_str(&format!("{cell:>w$}", w = *w));
            }
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Machine-readable dump (one JSON object per row) for EXPERIMENTS.md
    /// tooling and tests.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("columns", Json::arr(self.columns.iter().map(|c| Json::str(c.clone())).collect())),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("method", Json::str(r.method.clone())),
                                ("setting", Json::str(r.setting.clone())),
                                (
                                    "cells",
                                    Json::arr(r.cells.iter().map(|&v| Json::num(v)).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_all_cells() {
        let mut t = Table::new("Demo", vec!["Wiki2", "C4"]);
        t.push(Row::new("FP16", "W16A16", vec![5.47, 7.52]));
        t.push(Row::new("CrossQuant", "W8A8", vec![5.48, 7.53]));
        let s = t.render();
        assert!(s.contains("5.47") && s.contains("7.53") && s.contains("CrossQuant"));
    }

    #[test]
    fn percent_formatting() {
        let mut t = Table::new("Acc", vec!["Avg."]).percent().decimals(2);
        t.push(Row::new("FP16", "W16A16", vec![0.6827]));
        assert!(t.render().contains("68.27%"));
    }

    #[test]
    fn huge_values_scientific() {
        let mut t = Table::new("P", vec!["Wiki2"]);
        t.push(Row::new("Per-token", "W4A4", vec![2e4]));
        assert!(t.render().contains("2e4"));
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = Table::new("X", vec!["a", "b"]);
        t.push(Row::new("m", "s", vec![1.0]));
    }
}
