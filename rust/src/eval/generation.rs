//! Generation-workload instrumentation: drive a model's KV-cached greedy
//! decode and split the cost into prefill vs per-token decode — the
//! numbers `benches/decode.rs` ships as `BENCH_decode.json`.
//!
//! Both model flavours plug in through [`IncrementalDecoder`], so the
//! timed loop (and therefore the accounting) is identical for the FP
//! fake-quant path and the true-integer paths.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::block::{self, DecodeState};
use crate::model::{ActSite, NativeModel, QuantizedModel};
use crate::tensor::Matrix;

/// Anything that can run an incremental (KV-cached) forward step.
pub trait IncrementalDecoder {
    /// Model context window (prompt + generated tokens must fit).
    fn n_ctx(&self) -> usize;
    /// A fresh, empty decode state sized for the model.
    fn new_state(&self) -> DecodeState;
    /// Append `tokens` after the cached prefix; logits for the new rows.
    /// With `last_only`, implementations may return just the final row —
    /// all the greedy loop ever reads.
    fn step(&mut self, tokens: &[u32], state: &mut DecodeState, last_only: bool)
        -> Result<Matrix>;
}

/// The native (FP / fake-quant) model plus its activation-site transform.
pub struct NativeDecoder<'a> {
    pub model: &'a NativeModel,
    pub site: &'a mut dyn ActSite,
}

impl IncrementalDecoder for NativeDecoder<'_> {
    fn n_ctx(&self) -> usize {
        self.model.weights.config.seq_len
    }

    fn new_state(&self) -> DecodeState {
        self.model.new_decode_state()
    }

    fn step(
        &mut self,
        tokens: &[u32],
        state: &mut DecodeState,
        last_only: bool,
    ) -> Result<Matrix> {
        self.model.forward_incremental_with(tokens, state, self.site, last_only)
    }
}

/// The true-integer model (any [`crate::model::QuantPath`]).
pub struct QuantizedDecoder<'a>(pub &'a QuantizedModel);

impl IncrementalDecoder for QuantizedDecoder<'_> {
    fn n_ctx(&self) -> usize {
        self.0.config.seq_len
    }

    fn new_state(&self) -> DecodeState {
        self.0.new_decode_state()
    }

    fn step(
        &mut self,
        tokens: &[u32],
        state: &mut DecodeState,
        last_only: bool,
    ) -> Result<Matrix> {
        self.0.forward_incremental_with(tokens, state, last_only)
    }
}

/// Wall-clock split of one greedy generation.
#[derive(Clone, Copy, Debug)]
pub struct DecodeTiming {
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    /// Time to consume the prompt (one batched incremental forward).
    pub prefill: Duration,
    /// Time for all subsequent one-token decode steps.
    pub decode: Duration,
}

impl DecodeTiming {
    pub fn prefill_tokens_per_s(&self) -> f64 {
        self.prompt_tokens as f64 / self.prefill.as_secs_f64().max(1e-12)
    }

    pub fn decode_tokens_per_s(&self) -> f64 {
        self.new_tokens as f64 / self.decode.as_secs_f64().max(1e-12)
    }
}

/// Greedy-generate `max_new_tokens` with per-phase timing. The loop is
/// the models' own [`block::generate_greedy_with`] — identical semantics
/// by construction — with a clock wrapped around every step: the first
/// step is the prefill, the rest are decode.
pub fn generate_timed(
    decoder: &mut dyn IncrementalDecoder,
    prompt: &[u32],
    max_new_tokens: usize,
) -> Result<(Vec<u32>, DecodeTiming)> {
    let n_ctx = decoder.n_ctx();
    let mut state = decoder.new_state();
    let mut prefill = Duration::ZERO;
    let mut decode = Duration::ZERO;
    let mut prefilled = false;
    let tokens =
        block::generate_greedy_with(n_ctx, prompt, max_new_tokens, &mut state, &mut |toks, st| {
            let t0 = Instant::now();
            let r = decoder.step(toks, st, true);
            let dt = t0.elapsed();
            if prefilled {
                decode += dt;
            } else {
                prefill = dt;
                prefilled = true;
            }
            r
        })?;
    let timing = DecodeTiming {
        prompt_tokens: prompt.len(),
        new_tokens: tokens.len(),
        prefill,
        decode,
    };
    Ok((tokens, timing))
}

/// The serial one-at-a-time baseline over a set of prompts — exactly what
/// the pre-engine executor did: each generation runs alone at M=1, the
/// next starts only when the previous finishes. Returns every output plus
/// the total wall time; `n·max_new / wall` is the baseline aggregate
/// decode throughput that `benches/continuous_batching.rs` compares the
/// engine against.
pub fn generate_serial(
    decoder: &mut dyn IncrementalDecoder,
    prompts: &[Vec<u32>],
    max_new_tokens: usize,
) -> Result<(Vec<Vec<u32>>, Duration)> {
    let t0 = Instant::now();
    let mut outputs = Vec::with_capacity(prompts.len());
    for p in prompts {
        let (tokens, _) = generate_timed(decoder, p, max_new_tokens)?;
        outputs.push(tokens);
    }
    Ok((outputs, t0.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::synthetic_weights;
    use crate::model::IdentitySite;

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            eval_batch: 2,
        }
    }

    #[test]
    fn timed_generation_matches_generate_greedy() {
        let model = NativeModel::new(synthetic_weights(cfg(), 31));
        let prompt: Vec<u32> = vec![1, 5, 9, 2];
        let reference = model.generate_greedy(&prompt, 8, &mut IdentitySite).unwrap();
        let mut site = IdentitySite;
        let mut dec = NativeDecoder { model: &model, site: &mut site };
        let (tokens, timing) = generate_timed(&mut dec, &prompt, 8).unwrap();
        assert_eq!(tokens, reference);
        assert_eq!(timing.prompt_tokens, 4);
        assert_eq!(timing.new_tokens, 8);
        assert!(timing.prefill_tokens_per_s() > 0.0);
        assert!(timing.decode_tokens_per_s() > 0.0);
    }

    #[test]
    fn serial_baseline_matches_per_prompt_generation() {
        let model = NativeModel::new(synthetic_weights(cfg(), 33));
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2], vec![7, 8, 9]];
        let mut site = IdentitySite;
        let mut dec = NativeDecoder { model: &model, site: &mut site };
        let (outs, wall) = generate_serial(&mut dec, &prompts, 5).unwrap();
        assert_eq!(outs.len(), 2);
        for (p, o) in prompts.iter().zip(&outs) {
            assert_eq!(o, &model.generate_greedy(p, 5, &mut IdentitySite).unwrap());
        }
        assert!(wall > Duration::ZERO);
    }

    #[test]
    fn timed_generation_rejects_context_overflow() {
        let model = NativeModel::new(synthetic_weights(cfg(), 32));
        let mut site = IdentitySite;
        let mut dec = NativeDecoder { model: &model, site: &mut site };
        assert!(generate_timed(&mut dec, &[1; 12], 8).is_err());
    }
}
