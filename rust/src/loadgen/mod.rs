//! Open-loop load-test harness (`repro loadtest`) — the client side of
//! the telemetry loop.
//!
//! N client threads offer requests against a `serve` worker or a `route`
//! tier on independent seeded-RNG Poisson schedules (superposition of N
//! processes at rate/N each is a Poisson process at the full rate), so
//! the *offered* load is fixed by the schedule, not by how fast the
//! server answers — the open-loop property that makes overload visible
//! instead of self-throttling around it. Arrivals that fall behind a
//! slow server are issued late rather than dropped; the gap shows up as
//! achieved < offered throughput, which is the measurement.
//!
//! Each run:
//! 1. resets the server's telemetry (`{"cmd": "metrics_reset"}`) so
//!    server-side lifetime histograms cover exactly this run,
//! 2. offers the scenario mix for the configured duration, recording
//!    client-side TTFT / inter-token / total-latency histograms (the
//!    same log-bucketed [`Histogram`] the server uses) and per-priority
//!    sent/ok/shed/error counts,
//! 3. pulls `{"cmd": "metrics"}` and `{"cmd": "slo"}` back and
//!    cross-checks the client's TTFT p99 against the server's histogram
//!    p99 — the two views of one run must agree within tolerance or the
//!    telemetry itself is lying.
//!
//! The emitted report (`BENCH_loadtest.json`) is the PR's benchmark
//! artifact: offered vs achieved throughput, both latency views, the
//! crosscheck verdict, and the priority/shedding matrix.

pub mod client;
pub mod scenario;

pub use client::{control, RequestOutcome, SplitMix64};
pub use scenario::{ReqKind, Scenario, ScenarioItem};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::metrics::NUM_PRIORITIES;
use crate::obs::Histogram;
use crate::util::Json;

/// One `repro loadtest` run's knobs.
pub struct LoadtestConfig {
    /// Worker (`serve`) or router (`route`) endpoint.
    pub addr: String,
    pub duration_s: f64,
    /// Total offered request rate (req/s), split across clients.
    pub rate: f64,
    pub clients: usize,
    pub seed: u64,
    pub scenario: Scenario,
    /// Relative tolerance for the client-vs-server TTFT p99 crosscheck.
    pub p99_tolerance: f64,
    /// Send `{"cmd": "metrics_reset"}` before the run (on by default) so
    /// server lifetime histograms cover exactly this run.
    pub reset: bool,
}

/// Absolute crosscheck slack: below this the p99s are "equal" no matter
/// the ratio — two quantizations of a sub-millisecond latency can differ
/// by a whole bucket.
const CROSSCHECK_FLOOR_US: f64 = 20_000.0;

/// Shared accumulation across client threads — the same lock-free
/// histograms the server records into, so both sides quantize alike.
struct Stats {
    ttft: Histogram,
    inter_token: Histogram,
    request: Histogram,
    sent: [AtomicU64; NUM_PRIORITIES],
    ok: [AtomicU64; NUM_PRIORITIES],
    shed: [AtomicU64; NUM_PRIORITIES],
    errors: [AtomicU64; NUM_PRIORITIES],
}

impl Stats {
    fn new() -> Stats {
        Stats {
            ttft: Histogram::new(),
            inter_token: Histogram::new(),
            request: Histogram::new(),
            sent: Default::default(),
            ok: Default::default(),
            shed: Default::default(),
            errors: Default::default(),
        }
    }

    fn sum(counters: &[AtomicU64; NUM_PRIORITIES]) -> u64 {
        counters.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// Run one load test and build the `BENCH_loadtest.json` payload.
pub fn run(cfg: &LoadtestConfig) -> Result<Json> {
    let ping = control(&cfg.addr, &Json::obj(vec![("cmd", Json::str("ping"))]))
        .with_context(|| format!("cannot reach {} (is serve/route up?)", cfg.addr))?;
    if ping.get("ok") != Some(&Json::Bool(true)) {
        return Err(anyhow!("{} did not answer ping", cfg.addr));
    }
    if cfg.reset {
        let resp = control(&cfg.addr, &Json::obj(vec![("cmd", Json::str("metrics_reset"))]))
            .context("metrics_reset failed")?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            return Err(anyhow!("metrics_reset rejected: {}", resp.render()));
        }
    }

    let stats = Arc::new(Stats::new());
    let scenario = Arc::new(cfg.scenario.clone());
    let clients = cfg.clients.max(1);
    let per_client_rate = cfg.rate / clients as f64;
    let start = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for c in 0..clients {
        let stats = stats.clone();
        let scenario = scenario.clone();
        let addr = cfg.addr.clone();
        let duration_s = cfg.duration_s;
        let seed = cfg.seed ^ (c as u64 + 1).wrapping_mul(0xA24B_AED4_963E_E407);
        let handle = std::thread::Builder::new()
            .name(format!("loadgen-{c}"))
            .spawn(move || {
                let mut rng = SplitMix64::new(seed);
                let mut next = 0.0f64;
                loop {
                    next += rng.exp_interval(per_client_rate);
                    if next > duration_s {
                        break;
                    }
                    // a badly backlogged client stops offering rather
                    // than stretching the run without bound; the deficit
                    // is visible as achieved < offered
                    if start.elapsed().as_secs_f64() > duration_s * 2.0 + 5.0 {
                        break;
                    }
                    let target = Duration::from_secs_f64(next);
                    if let Some(wait) = target.checked_sub(start.elapsed()) {
                        std::thread::sleep(wait);
                    }
                    let item = scenario.pick(rng.next_f64());
                    let p = (item.priority as usize).min(NUM_PRIORITIES - 1);
                    stats.sent[p].fetch_add(1, Ordering::Relaxed);
                    let outcome = client::run_request(&addr, item, &mut rng);
                    stats.request.record(outcome.total_us);
                    if let Some(ttft) = outcome.ttft_us {
                        stats.ttft.record(ttft);
                    }
                    for gap in &outcome.inter_token_us {
                        stats.inter_token.record(*gap);
                    }
                    if outcome.ok {
                        stats.ok[p].fetch_add(1, Ordering::Relaxed);
                    } else if outcome.shed {
                        stats.shed[p].fetch_add(1, Ordering::Relaxed);
                    } else {
                        stats.errors[p].fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
            .expect("spawn loadgen client");
        handles.push(handle);
    }
    for h in handles {
        let _ = h.join();
    }
    let elapsed_s = start.elapsed().as_secs_f64().max(1e-9);

    let metrics = control(&cfg.addr, &Json::obj(vec![("cmd", Json::str("metrics"))]))
        .context("fetching server metrics after the run")?;
    let slo = control(&cfg.addr, &Json::obj(vec![("cmd", Json::str("slo"))]))
        .context("fetching server SLO report after the run")?;

    let server_ttft_p99 = server_ttft_p99_us(&metrics);
    let client_ttft_p99 = stats.ttft.quantile_us(0.99) as f64;
    let crosscheck = crosscheck_json(
        client_ttft_p99,
        stats.ttft.count(),
        server_ttft_p99,
        cfg.p99_tolerance,
    );

    let sent = Stats::sum(&stats.sent);
    let ok = Stats::sum(&stats.ok);
    let priorities: Vec<Json> = (0..NUM_PRIORITIES)
        .map(|p| {
            Json::obj(vec![
                ("priority", Json::num(p as f64)),
                ("sent", Json::num(stats.sent[p].load(Ordering::Relaxed) as f64)),
                ("ok", Json::num(stats.ok[p].load(Ordering::Relaxed) as f64)),
                ("shed", Json::num(stats.shed[p].load(Ordering::Relaxed) as f64)),
                ("errors", Json::num(stats.errors[p].load(Ordering::Relaxed) as f64)),
            ])
        })
        .collect();

    // the flat counter object: a worker reports "counters", a router
    // reports the fleet-summed "aggregate" under the same keys
    let server_counters = metrics
        .get("counters")
        .or_else(|| metrics.get("aggregate"))
        .cloned()
        .unwrap_or(Json::Null);

    Ok(Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("addr", Json::str(cfg.addr.clone())),
                ("duration_s", Json::num(cfg.duration_s)),
                ("offered_rps", Json::num(cfg.rate)),
                ("clients", Json::num(clients as f64)),
                ("seed", Json::num(cfg.seed as f64)),
                ("p99_tolerance", Json::num(cfg.p99_tolerance)),
                ("reset", Json::Bool(cfg.reset)),
                ("scenario", cfg.scenario.json()),
            ]),
        ),
        ("elapsed_s", Json::num(elapsed_s)),
        ("offered_rps", Json::num(cfg.rate)),
        ("attempted_rps", Json::num(sent as f64 / elapsed_s)),
        ("achieved_rps", Json::num(ok as f64 / elapsed_s)),
        (
            "client",
            Json::obj(vec![
                ("sent", Json::num(sent as f64)),
                ("ok", Json::num(ok as f64)),
                ("shed", Json::num(Stats::sum(&stats.shed) as f64)),
                ("errors", Json::num(Stats::sum(&stats.errors) as f64)),
                ("ttft", stats.ttft.json()),
                ("inter_token", stats.inter_token.json()),
                ("request", stats.request.json()),
            ]),
        ),
        ("priorities", Json::arr(priorities)),
        (
            "server",
            Json::obj(vec![
                ("counters", server_counters),
                ("slo", slo.get("slo").or_else(|| slo.get("workers")).cloned().unwrap_or(Json::Null)),
                ("shedding", slo.get("shedding").cloned().unwrap_or(Json::Null)),
            ]),
        ),
        ("crosscheck", crosscheck),
    ]))
}

/// Server-side TTFT p99, handling both response shapes. A worker answers
/// with its own `latency` block; a router answers with per-worker rows,
/// so each healthy worker's histogram is fetched directly and the fleet
/// p99 approximated as the worst worker's p99 (an upper bound — exact
/// cross-worker quantile merging would need raw buckets on the wire, and
/// the crosscheck tolerance absorbs the difference).
fn server_ttft_p99_us(metrics: &Json) -> Option<f64> {
    let own = |m: &Json| -> Option<f64> {
        let total = m.get("latency")?.get("ttft")?.get("total")?;
        if total.get("count")?.as_f64()? < 1.0 {
            return None;
        }
        total.get("p99_us")?.as_f64()
    };
    if let Some(p99) = own(metrics) {
        return Some(p99);
    }
    let workers = metrics.get("workers")?.as_arr()?;
    let mut worst: Option<f64> = None;
    for w in workers {
        if w.get("healthy") != Some(&Json::Bool(true)) {
            continue;
        }
        let Some(addr) = w.get("addr").and_then(|a| a.as_str()) else { continue };
        let Ok(resp) = control(addr, &Json::obj(vec![("cmd", Json::str("metrics"))])) else {
            continue;
        };
        if let Some(p99) = own(&resp) {
            worst = Some(worst.map_or(p99, |b: f64| b.max(p99)));
        }
    }
    worst
}

fn crosscheck_json(
    client_p99_us: f64,
    client_samples: u64,
    server_p99_us: Option<f64>,
    tolerance: f64,
) -> Json {
    let mut fields = vec![
        ("ttft_p99_client_us", Json::num(client_p99_us)),
        ("client_samples", Json::num(client_samples as f64)),
        ("tolerance", Json::num(tolerance)),
    ];
    match server_p99_us {
        Some(server) if client_samples > 0 => {
            let rel_err = (client_p99_us - server).abs() / client_p99_us.max(server).max(1.0);
            let within =
                rel_err <= tolerance || (client_p99_us - server).abs() <= CROSSCHECK_FLOOR_US;
            fields.push(("ttft_p99_server_us", Json::num(server)));
            fields.push(("rel_err", Json::num(rel_err)));
            fields.push(("within_tolerance", Json::Bool(within)));
        }
        _ => {
            // nothing to compare: no streamed client samples, or the
            // server saw no generation — report that honestly rather
            // than a vacuous pass/fail
            fields.push(("ttft_p99_server_us", Json::Null));
            fields.push(("rel_err", Json::Null));
            fields.push(("within_tolerance", Json::Null));
        }
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crosscheck_agrees_within_tolerance_or_floor() {
        let j = crosscheck_json(100_000.0, 50, Some(120_000.0), 0.5);
        assert_eq!(j.get("within_tolerance"), Some(&Json::Bool(true)));
        // 10x apart and far beyond the absolute floor: disagreement
        let j = crosscheck_json(1_000_000.0, 50, Some(100_000.0), 0.5);
        assert_eq!(j.get("within_tolerance"), Some(&Json::Bool(false)));
        // sub-floor absolute gap passes even at a huge ratio
        let j = crosscheck_json(15_000.0, 50, Some(1_000.0), 0.1);
        assert_eq!(j.get("within_tolerance"), Some(&Json::Bool(true)));
        // no samples: verdict is null, not a fake pass
        let j = crosscheck_json(0.0, 0, Some(1_000.0), 0.5);
        assert_eq!(j.get("within_tolerance"), Some(&Json::Null));
    }

    #[test]
    fn server_p99_reads_the_worker_shape() {
        let metrics = Json::obj(vec![(
            "latency",
            Json::obj(vec![(
                "ttft",
                Json::obj(vec![(
                    "total",
                    Json::obj(vec![
                        ("count", Json::num(10.0)),
                        ("p99_us", Json::num(42_000.0)),
                    ]),
                )]),
            )]),
        )]);
        assert_eq!(server_ttft_p99_us(&metrics), Some(42_000.0));
        // zero-count histograms yield no p99 rather than 0
        let empty = Json::obj(vec![(
            "latency",
            Json::obj(vec![(
                "ttft",
                Json::obj(vec![(
                    "total",
                    Json::obj(vec![("count", Json::num(0.0)), ("p99_us", Json::num(0.0))]),
                )]),
            )]),
        )]);
        assert_eq!(server_ttft_p99_us(&empty), None);
    }
}
