//! The per-request client: build one wire frame from a scenario item,
//! drive it over its own TCP connection, and time what the server can't
//! see — client-observed TTFT, inter-token gaps, and total latency.
//!
//! Connection-per-request keeps the generator honest as an open-loop
//! source: a slow response never pins a reused socket, and the server's
//! connection cap is exercised the way a real fleet of clients would.
//! All randomness flows from a [`SplitMix64`] seeded by the harness, so
//! a run is reproducible token-for-token.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::scenario::{ReqKind, ScenarioItem};
use crate::util::Json;

/// SplitMix64: tiny, seedable, and statistically fine for load shapes —
/// the same mixer the trace-id allocator uses.
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` (53-bit mantissa).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        lo + (self.next_u64() % (hi - lo + 1) as u64) as usize
    }

    /// Exponential inter-arrival gap for a Poisson process of `rate`
    /// events/second.
    pub fn exp_interval(&mut self, rate: f64) -> f64 {
        let u = self.next_f64();
        -(1.0 - u).ln() / rate.max(1e-9)
    }
}

/// What one request looked like from the client's side of the socket.
#[derive(Debug, Default)]
pub struct RequestOutcome {
    pub ok: bool,
    /// The server shed the request (structured retryable shed error) —
    /// accounted separately from hard errors.
    pub shed: bool,
    pub error: Option<String>,
    /// First-token latency — streaming requests only (the one kind whose
    /// TTFT a client can observe).
    pub ttft_us: Option<u64>,
    /// Gaps between consecutive streamed tokens.
    pub inter_token_us: Vec<u64>,
    /// Send-to-final-line latency.
    pub total_us: u64,
}

/// Build the wire frame for one drawn request. Token ids stay below 64
/// and lengths small, so every served config (synthetic or artifact)
/// accepts them without context overflow.
fn build_frame(item: &ScenarioItem, rng: &mut SplitMix64) -> Json {
    let prompt_len = rng.range(item.prompt_len.0.max(1), item.prompt_len.1.max(1));
    let tokens: Vec<Json> =
        (0..prompt_len).map(|_| Json::num((1 + rng.next_u64() % 60) as f64)).collect();
    let mut fields = vec![
        ("tokens", Json::arr(tokens)),
        ("scheme", Json::str("crossquant")),
        ("alpha", Json::num(0.15)),
        ("priority", Json::num(item.priority as f64)),
    ];
    if item.kind != ReqKind::Score {
        let max_new = rng.range(item.max_new.0.max(1), item.max_new.1.max(1));
        fields.push(("max_new_tokens", Json::num(max_new as f64)));
    }
    if item.kind == ReqKind::Stream {
        fields.push(("stream", Json::Bool(true)));
    }
    Json::obj(fields)
}

/// Classify a structured error line: the admission-control shed paths
/// (engine queue-full eviction, burn-rate shedding, router retry
/// exhaustion wrapping a worker shed) all carry "request shed".
fn is_shed(msg: &str) -> bool {
    msg.contains("request shed")
}

/// Drive one request over a fresh connection. IO failures become
/// `RequestOutcome` errors, never panics — under deliberate overload a
/// torn connection is data, not a harness bug.
pub fn run_request(addr: &str, item: &ScenarioItem, rng: &mut SplitMix64) -> RequestOutcome {
    let frame = build_frame(item, rng);
    let streaming = item.kind == ReqKind::Stream;
    let t0 = Instant::now();
    let mut outcome = RequestOutcome::default();
    match drive(addr, &frame, streaming, t0, &mut outcome) {
        Ok(()) => {}
        Err(e) => {
            let msg = format!("{e}");
            outcome.ok = false;
            outcome.shed = is_shed(&msg);
            outcome.error = Some(msg);
        }
    }
    outcome.total_us = t0.elapsed().as_micros() as u64;
    outcome
}

fn drive(
    addr: &str,
    frame: &Json,
    streaming: bool,
    t0: Instant,
    outcome: &mut RequestOutcome,
) -> Result<()> {
    let stream = TcpStream::connect(addr)?;
    let timeout = Some(Duration::from_secs(30));
    stream.set_read_timeout(timeout)?;
    stream.set_write_timeout(timeout)?;
    let mut writer = stream.try_clone()?;
    writer.write_all(frame.render().as_bytes())?;
    writer.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut last_token_at: Option<Instant> = None;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("connection closed before a final response line"));
        }
        let resp = Json::parse(&line)?;
        match resp.get("ok") {
            None if streaming && resp.get("token").is_some() => {
                let now = Instant::now();
                match last_token_at {
                    None => {
                        outcome.ttft_us =
                            Some(now.duration_since(t0).as_micros() as u64);
                    }
                    Some(prev) => {
                        outcome
                            .inter_token_us
                            .push(now.duration_since(prev).as_micros() as u64);
                    }
                }
                last_token_at = Some(now);
            }
            None => return Err(anyhow!("response frame without 'ok' field")),
            Some(ok) => {
                outcome.ok = ok == &Json::Bool(true);
                if !outcome.ok {
                    let msg = resp
                        .get("error")
                        .and_then(|e| e.as_str())
                        .unwrap_or("unspecified server error")
                        .to_string();
                    outcome.shed = is_shed(&msg);
                    outcome.error = Some(msg);
                }
                return Ok(());
            }
        }
    }
}

/// Send one control frame (`{"cmd": ...}`) and parse the single reply
/// line — how the harness resets metrics before a run and pulls the
/// server-side histograms after.
pub fn control(addr: &str, req: &Json) -> Result<Json> {
    let stream = TcpStream::connect(addr)?;
    let timeout = Some(Duration::from_secs(5));
    stream.set_read_timeout(timeout)?;
    stream.set_write_timeout(timeout)?;
    let mut writer = stream.try_clone()?;
    writer.write_all(req.render().as_bytes())?;
    writer.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(&line)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic_and_uniformish() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = SplitMix64::new(7);
        let mean =
            (0..10_000).map(|_| c.next_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        for _ in 0..100 {
            let v = c.range(3, 9);
            assert!((3..=9).contains(&v));
        }
        assert_eq!(c.range(5, 5), 5);
    }

    #[test]
    fn poisson_gaps_average_the_inverse_rate() {
        let mut rng = SplitMix64::new(1);
        let rate = 50.0;
        let mean =
            (0..20_000).map(|_| rng.exp_interval(rate)).sum::<f64>() / 20_000.0;
        assert!((mean - 1.0 / rate).abs() < 0.002, "mean gap {mean}");
    }

    #[test]
    fn frames_carry_priority_and_respect_ranges() {
        let mut rng = SplitMix64::new(9);
        let item = ScenarioItem {
            kind: ReqKind::Stream,
            weight: 1.0,
            priority: 3,
            prompt_len: (2, 4),
            max_new: (1, 2),
        };
        for _ in 0..50 {
            let f = build_frame(&item, &mut rng);
            assert_eq!(f.get("priority"), Some(&Json::num(3.0)));
            assert_eq!(f.get("stream"), Some(&Json::Bool(true)));
            let n = f.get("tokens").and_then(|t| t.as_arr()).unwrap().len();
            assert!((2..=4).contains(&n));
            let m = f.get("max_new_tokens").and_then(|m| m.as_usize()).unwrap();
            assert!((1..=2).contains(&m));
        }
        let score = ScenarioItem { kind: ReqKind::Score, ..item };
        let f = build_frame(&score, &mut rng);
        assert!(f.get("max_new_tokens").is_none());
        assert!(f.get("stream").is_none());
    }

    #[test]
    fn shed_classification_matches_the_engine_messages() {
        assert!(is_shed("request shed (priority 0): SLO burn rate over threshold"));
        assert!(is_shed(
            "worker error: request shed (priority 1): engine at capacity, 4 sequences \
             active, admission queue full (2)"
        ));
        assert!(!is_shed("deadline exceeded"));
        assert!(!is_shed("unknown weight set w2"));
    }
}
