//! Load-test scenarios: a weighted mix of request shapes.
//!
//! A scenario is a list of [`ScenarioItem`]s — request kind (score /
//! generate / streaming generate), scheduling priority, and the prompt-
//! and output-length ranges — with relative weights. The generator draws
//! from the mix with a seeded RNG, so two runs with the same seed offer
//! an identical request sequence.
//!
//! Wire format (`repro loadtest --scenario FILE`):
//!
//! ```json
//! {"mix": [
//!   {"kind": "stream",   "weight": 3, "priority": "normal",
//!    "prompt_len": [4, 16], "max_new": [4, 12]},
//!   {"kind": "score",    "weight": 1, "priority": "batch",
//!    "prompt_len": [8, 24]}
//! ]}
//! ```
//!
//! `priority` takes the wire forms the server takes (0–3 or
//! "batch"/"low"/"normal"/"high"); `max_new` is ignored for `score`.
//! Presets `default` and `overload` cover the common cases without a
//! file.

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::{self, metrics::PRIORITY_DEFAULT};
use crate::util::Json;

/// What one drawn request does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqKind {
    /// Per-position NLL scoring (no decode).
    Score,
    /// Greedy generation, single response line.
    Generate,
    /// Greedy generation with `"stream": true` — the only kind whose
    /// client-side TTFT and inter-token gaps are observable.
    Stream,
}

impl ReqKind {
    pub fn name(&self) -> &'static str {
        match self {
            ReqKind::Score => "score",
            ReqKind::Generate => "generate",
            ReqKind::Stream => "stream",
        }
    }

    fn parse(s: &str) -> Result<ReqKind> {
        Ok(match s {
            "score" => ReqKind::Score,
            "generate" => ReqKind::Generate,
            "stream" => ReqKind::Stream,
            other => bail!("unknown scenario kind '{other}' (score|generate|stream)"),
        })
    }
}

/// One weighted entry in the mix. Length ranges are inclusive.
#[derive(Clone, Debug)]
pub struct ScenarioItem {
    pub kind: ReqKind,
    pub weight: f64,
    pub priority: u8,
    pub prompt_len: (usize, usize),
    pub max_new: (usize, usize),
}

impl ScenarioItem {
    fn json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind.name())),
            ("weight", Json::num(self.weight)),
            ("priority", Json::num(self.priority as f64)),
            (
                "prompt_len",
                Json::arr(vec![
                    Json::num(self.prompt_len.0 as f64),
                    Json::num(self.prompt_len.1 as f64),
                ]),
            ),
            (
                "max_new",
                Json::arr(vec![
                    Json::num(self.max_new.0 as f64),
                    Json::num(self.max_new.1 as f64),
                ]),
            ),
        ])
    }
}

/// A weighted request mix.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub items: Vec<ScenarioItem>,
}

impl Scenario {
    /// Built-in mixes: `default` (streaming-heavy, all normal priority —
    /// the steady-state latency measurement) and `overload` (short, hot
    /// requests across all four classes, best-effort-heavy — what the
    /// shedding matrix is demonstrated on).
    pub fn preset(name: &str) -> Result<Scenario> {
        let item = |kind, weight, priority, prompt_len, max_new| ScenarioItem {
            kind,
            weight,
            priority,
            prompt_len,
            max_new,
        };
        Ok(match name {
            "default" => Scenario {
                items: vec![
                    item(ReqKind::Stream, 3.0, 2, (4, 16), (4, 12)),
                    item(ReqKind::Generate, 1.0, 2, (4, 16), (4, 12)),
                    item(ReqKind::Score, 1.0, 2, (8, 24), (0, 0)),
                ],
            },
            "overload" => Scenario {
                items: vec![
                    item(ReqKind::Stream, 1.0, 3, (4, 8), (4, 8)),
                    item(ReqKind::Generate, 2.0, 2, (4, 12), (4, 12)),
                    item(ReqKind::Generate, 2.0, 1, (8, 16), (8, 16)),
                    item(ReqKind::Generate, 3.0, 0, (8, 16), (8, 16)),
                ],
            },
            other => bail!("unknown preset '{other}' (default|overload)"),
        })
    }

    /// Parse the `{"mix": [...]}` wire format.
    pub fn from_json(j: &Json) -> Result<Scenario> {
        let mix = j
            .get("mix")
            .and_then(|m| m.as_arr())
            .ok_or_else(|| anyhow!("scenario needs a 'mix' array"))?;
        ensure!(!mix.is_empty(), "scenario 'mix' must not be empty");
        let range = |item: &Json, key: &str, default: (usize, usize)| -> Result<(usize, usize)> {
            match item.get(key).and_then(|r| r.as_arr()) {
                None => Ok(default),
                Some([lo, hi]) => {
                    let lo = lo.as_usize().ok_or_else(|| anyhow!("bad '{key}' low bound"))?;
                    let hi = hi.as_usize().ok_or_else(|| anyhow!("bad '{key}' high bound"))?;
                    ensure!(lo <= hi, "'{key}' range [{lo}, {hi}] is inverted");
                    Ok((lo, hi))
                }
                Some(_) => bail!("'{key}' must be a [lo, hi] pair"),
            }
        };
        let items = mix
            .iter()
            .map(|item| {
                let kind = ReqKind::parse(
                    item.get("kind")
                        .and_then(|k| k.as_str())
                        .ok_or_else(|| anyhow!("scenario item needs a 'kind'"))?,
                )?;
                let weight = item.get("weight").and_then(|w| w.as_f64()).unwrap_or(1.0);
                ensure!(weight.is_finite() && weight > 0.0, "item weight must be > 0");
                let priority = match item.get("priority") {
                    Some(v) => coordinator::parse_priority(v).ok_or_else(|| {
                        anyhow!("bad 'priority' (0-3 or batch/low/normal/high)")
                    })?,
                    None => PRIORITY_DEFAULT,
                };
                let prompt_len = range(item, "prompt_len", (4, 16))?;
                ensure!(prompt_len.0 >= 1, "'prompt_len' low bound must be >= 1");
                Ok(ScenarioItem {
                    kind,
                    weight,
                    priority,
                    prompt_len,
                    max_new: range(item, "max_new", (4, 12))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Scenario { items })
    }

    /// Load a scenario file.
    pub fn from_file(path: &std::path::Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading scenario {}: {e}", path.display()))?;
        Scenario::from_json(&Json::parse(&text)?)
    }

    /// Weighted draw: map `u ∈ [0, 1)` onto the mix.
    pub fn pick(&self, u: f64) -> &ScenarioItem {
        let total: f64 = self.items.iter().map(|i| i.weight).sum();
        let mut target = u.clamp(0.0, 1.0) * total;
        for item in &self.items {
            if target < item.weight {
                return item;
            }
            target -= item.weight;
        }
        self.items.last().expect("scenario mix is never empty")
    }

    /// Echo of the mix for the result file's `config` block.
    pub fn json(&self) -> Json {
        Json::obj(vec![("mix", Json::arr(self.items.iter().map(|i| i.json()).collect()))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_and_pick_covers_the_mix() {
        for name in ["default", "overload"] {
            let s = Scenario::preset(name).unwrap();
            assert!(!s.items.is_empty());
            // both edges of the draw space land on valid items
            assert!(s.pick(0.0).weight > 0.0);
            assert!(s.pick(0.999_999).weight > 0.0);
        }
        assert!(Scenario::preset("nope").is_err());
    }

    #[test]
    fn overload_preset_skews_toward_best_effort() {
        let s = Scenario::preset("overload").unwrap();
        let w = |p: u8| -> f64 {
            s.items.iter().filter(|i| i.priority == p).map(|i| i.weight).sum()
        };
        assert!(w(0) > w(3), "overload must offer more best-effort than interactive");
        assert!(w(3) > 0.0, "overload still carries interactive traffic to protect");
    }

    #[test]
    fn wire_format_round_trips() {
        let text = r#"{"mix": [
            {"kind": "stream", "weight": 2, "priority": "high",
             "prompt_len": [2, 6], "max_new": [1, 3]},
            {"kind": "score", "priority": 0}
        ]}"#;
        let s = Scenario::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(s.items.len(), 2);
        assert_eq!(s.items[0].kind, ReqKind::Stream);
        assert_eq!(s.items[0].priority, 3);
        assert_eq!(s.items[0].prompt_len, (2, 6));
        assert_eq!(s.items[1].kind, ReqKind::Score);
        assert_eq!(s.items[1].priority, 0);
        assert_eq!(s.items[1].weight, 1.0); // default
        // a pure-u draw at 0 hits the heavier first item
        assert_eq!(s.pick(0.0).kind, ReqKind::Stream);
    }

    #[test]
    fn malformed_scenarios_are_rejected() {
        for bad in [
            r#"{"mix": []}"#,
            r#"{"nope": 1}"#,
            r#"{"mix": [{"kind": "fly"}]}"#,
            r#"{"mix": [{"kind": "score", "priority": "urgent"}]}"#,
            r#"{"mix": [{"kind": "score", "prompt_len": [9, 2]}]}"#,
            r#"{"mix": [{"kind": "score", "weight": 0}]}"#,
        ] {
            assert!(Scenario::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }
}
