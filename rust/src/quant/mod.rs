//! The quantization library: the paper's method (CrossQuant) plus every
//! baseline it compares against, all operating on [`Matrix`] activations /
//! weights in the paper's *fake-quant* protocol (quantize to the integer
//! grid, immediately dequantize — Appendix B.1), which is what all of the
//! paper's tables measure.
//!
//! Scheme inventory (paper §3–§4, §5.1):
//! - [`per_token`]    — eq. (1), the activation baseline
//! - [`per_channel`]  — eq. (2) + group-wise variant, the weight baseline
//! - [`crossquant`]   — eq. (5), the contribution (also weight mode, App. B.1)
//! - [`smoothquant`]  — Xiao et al. 2023 baseline (scale migration)
//! - [`awq`]          — Lin et al. 2024 baseline (activation-aware weight scale)
//! - [`clipping`]     — OmniQuant stand-in (grid-searched clipping)
//! - [`remove_kernel`]— the "Remove Kernel" ablation operator (Figs. 1/6/7/9)
//! - [`pack`]         — real INT8/INT4 bit-packing for storage accounting
//! - [`gemm`]         — packed-panel int8 GEMM microkernel (deployment path)
//! - [`qlinear`]      — true-integer linear layers over [`gemm`]
//! - [`artifact`]     — `.cqa` deployable quantized-model artifacts
//!                      (calibrate once, ship int8, serve via mmap)
//! - [`gptq`]         — GPTQ-style error-minimising weight rounding (OBS)
//! - [`lorc`]         — ZeroQuant-V2-style low-rank correction of the
//!                      weight-quantization residual
//! - [`registry`]     — the unified scheme registry: canonical names,
//!                      artifact scheme IDs, and the one static pipeline
//!                      (quantize → calibrate → fold → serve) every scheme
//!                      is built through

pub mod artifact;
pub mod awq;
pub mod clipping;
pub mod crossquant;
pub mod gemm;
pub mod gptq;
pub mod lorc;
pub mod pack;
pub mod qlinear;
pub mod per_channel;
pub mod per_token;
pub mod registry;
pub mod remove_kernel;
pub mod smoothquant;

use crate::tensor::{par, Matrix};

/// Guard against all-zero rows/columns (matches python `ref.EPS`).
pub const EPS: f32 = 1e-9;

/// Integer grid width. The paper's experiments use symmetric INT8/INT4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Bits {
    Int4,
    Int8,
    /// Arbitrary width (used by sweeps / property tests).
    Other(u8),
}

impl Bits {
    /// qmax = 2^(N−1) − 1, the paper's grid bound.
    ///
    /// `Other(n)` is validated to `2 ≤ n ≤ 32`: n = 0 and n ≥ 33 overflow
    /// the shift (a debug-build panic, garbage in release), and n = 1 has
    /// qmax 0, which divides by zero in every delta field downstream.
    pub fn qmax(self) -> f32 {
        match self {
            Bits::Int4 => 7.0,
            Bits::Int8 => 127.0,
            Bits::Other(n) => {
                assert!(
                    (2..=32).contains(&n),
                    "Bits::Other({n}): bit-width must be in 2..=32 \
                     (1 bit has qmax 0, widths above 32 overflow the grid)"
                );
                ((1u64 << (n - 1)) - 1) as f32
            }
        }
    }
}

impl std::fmt::Display for Bits {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Bits::Int4 => write!(f, "A4"),
            Bits::Int8 => write!(f, "A8"),
            Bits::Other(n) => write!(f, "A{n}"),
        }
    }
}

/// The per-element quantization step Δ_ij of a scheme on a given matrix,
/// stored in factored form so analysis can query any element in O(1)
/// without materialising a T×I scale matrix (the paper's storage argument:
/// CrossQuant stores only one extra length-I vector).
#[derive(Clone, Debug)]
pub enum DeltaField {
    /// Δ_ij = row[i] — per-token (and per-group after reshape).
    PerRow(Vec<f32>),
    /// Δ_ij = col[j] — per-channel weight quantization.
    PerCol(Vec<f32>),
    /// Δ_ij = row_pow[i] · col_pow[j] — CrossQuant's factored cross scale,
    /// with row_pow = t^α/qmax-part and col_pow = c^(1−α) pre-raised.
    Cross { row_pow: Vec<f32>, col_pow: Vec<f32> },
}

impl DeltaField {
    #[inline]
    pub fn delta(&self, i: usize, j: usize) -> f32 {
        match self {
            DeltaField::PerRow(r) => r[i],
            DeltaField::PerCol(c) => c[j],
            DeltaField::Cross { row_pow, col_pow } => row_pow[i] * col_pow[j],
        }
    }

    /// Zero bound B_ij = 0.5 · Δ_ij (paper Definition 1 / eq. 4).
    #[inline]
    pub fn zero_bound(&self, i: usize, j: usize) -> f32 {
        0.5 * self.delta(i, j)
    }
}

/// An activation quantization scheme: produces the scale field for a matrix
/// and fake-quantizes it. Object-safe so the eval harness can iterate over
/// `Box<dyn ActQuantizer>` method lists.
pub trait ActQuantizer: Send + Sync {
    fn name(&self) -> String;

    /// The factored per-element scale Δ for this matrix.
    fn delta_field(&self, x: &Matrix) -> DeltaField;

    /// Fake quantization: round to grid, clip, dequantize.
    fn fake_quant(&self, x: &Matrix) -> Matrix {
        let field = self.delta_field(x);
        let qmax = self.qmax();
        fake_quant_with(x, &field, qmax)
    }

    fn qmax(&self) -> f32;
}

/// Shared fake-quant loop over a factored scale field — row-parallel (see
/// [`crate::tensor::par`]); every row is computed by the exact same
/// per-row kernel regardless of worker count, so
/// [`fake_quant_with_threads`]`(x, field, qmax, 1)` is a bit-exact serial
/// reference.
pub fn fake_quant_with(x: &Matrix, field: &DeltaField, qmax: f32) -> Matrix {
    fake_quant_with_threads(x, field, qmax, par::workers_for(x.rows, x.len()))
}

/// [`fake_quant_with`] with an explicit worker count.
pub fn fake_quant_with_threads(
    x: &Matrix,
    field: &DeltaField,
    qmax: f32,
    workers: usize,
) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    if out.is_empty() {
        return out;
    }
    let cols = x.cols;
    par::par_rows_mut(&mut out.data, cols, workers, |row0, chunk| {
        for (local_i, dst) in chunk.chunks_mut(cols).enumerate() {
            let i = row0 + local_i;
            fake_quant_row(x.row(i), dst, field, i, qmax);
        }
    });
    out
}

/// The per-row fake-quant kernel, specialised per scale-field variant so
/// the per-row factor hoists and the inner loop stays branchless and
/// vectorizable. Serial, parallel and fused (`analysis::
/// quantize_with_report`) paths all route through this one function —
/// that is what makes them bit-exact with each other.
#[inline]
pub(crate) fn fake_quant_row(
    src: &[f32],
    dst: &mut [f32],
    field: &DeltaField,
    i: usize,
    qmax: f32,
) {
    match field {
        DeltaField::PerRow(rows) => {
            let d = rows[i];
            for (o, &v) in dst.iter_mut().zip(src) {
                *o = (v / d).round().clamp(-qmax, qmax) * d;
            }
        }
        DeltaField::PerCol(cols) => {
            for ((o, &v), &d) in dst.iter_mut().zip(src).zip(cols) {
                *o = (v / d).round().clamp(-qmax, qmax) * d;
            }
        }
        DeltaField::Cross { row_pow, col_pow } => {
            let rp = row_pow[i];
            for ((o, &v), &cp) in dst.iter_mut().zip(src).zip(col_pow) {
                let d = rp * cp;
                *o = (v / d).round().clamp(-qmax, qmax) * d;
            }
        }
    }
}

/// Debug-build guard at every `delta_field` entry: a NaN/Inf activation
/// would flow through `max(EPS)` into a plausible-looking scale field
/// (abs-max is NaN-propagating, but `NaN.max(EPS)` discards the NaN
/// again) and silently corrupt every downstream kernel statistic. Release
/// builds skip the scan.
#[inline]
pub(crate) fn debug_assert_finite(x: &Matrix, scheme: &str) {
    if cfg!(debug_assertions) {
        if let Some(pos) = x.data.iter().position(|v| !v.is_finite()) {
            panic!(
                "{scheme}::delta_field: non-finite activation {} at flat index {pos} \
                 of a {}x{} matrix",
                x.data[pos], x.rows, x.cols
            );
        }
    }
}

/// Quantization error ‖X − Q(X)‖_F / ‖X‖_F, the generic quality metric.
pub fn relative_error(x: &Matrix, q: &Matrix) -> f32 {
    let denom = x.frobenius().max(EPS);
    x.distance(q) / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(Bits::Int8.qmax(), 127.0);
        assert_eq!(Bits::Int4.qmax(), 7.0);
        assert_eq!(Bits::Other(6).qmax(), 31.0);
    }

    #[test]
    fn qmax_other_full_valid_range() {
        assert_eq!(Bits::Other(2).qmax(), 1.0);
        assert_eq!(Bits::Other(8).qmax(), 127.0);
        assert_eq!(Bits::Other(32).qmax(), (u32::MAX / 2) as f32);
    }

    #[test]
    #[should_panic(expected = "bit-width must be in 2..=32")]
    fn qmax_rejects_zero_bits() {
        Bits::Other(0).qmax();
    }

    #[test]
    #[should_panic(expected = "bit-width must be in 2..=32")]
    fn qmax_rejects_one_bit() {
        // qmax would be 0 → division by zero in every delta field
        Bits::Other(1).qmax();
    }

    #[test]
    #[should_panic(expected = "bit-width must be in 2..=32")]
    fn qmax_rejects_oversized_bits() {
        Bits::Other(33).qmax();
    }

    #[test]
    fn delta_field_factored_lookup() {
        let f = DeltaField::Cross { row_pow: vec![2.0, 3.0], col_pow: vec![0.5, 1.0, 2.0] };
        assert_eq!(f.delta(0, 0), 1.0);
        assert_eq!(f.delta(1, 2), 6.0);
        assert_eq!(f.zero_bound(1, 2), 3.0);
    }
}
