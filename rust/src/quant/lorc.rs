//! LoRC — low-rank compensation of the weight-quantization residual
//! (ZeroQuant-V2, Yao et al., 2023).
//!
//! After rounding a weight to the integer grid, the residual
//! E = W − dequant(Q) is approximated by a rank-r factorization U·V and
//! added back *in fp* after the int8 GEMM: y = gemm_int8(x) + (x·U)·V.
//! Two skinny fp matmuls (I×r and r×O) recover most of the rounding error
//! at a cost that vanishes for r ≪ min(I, O) — the mechanism that makes
//! INT4 weights usable.
//!
//! The factorization is a deterministic randomized subspace iteration
//! (seeded [`SplitMix64`], Gram-Schmidt orthonormalization): no LAPACK in
//! the build environment, and determinism is required for the `.cqa`
//! resave byte-identity guarantee.

use crate::tensor::{Matrix, SplitMix64};

/// Rank-r factorization of `e` (I × O): returns `(U: I × r, V: r × O)`
/// with U·V = Q·Qᵀ·e for an orthonormal Q spanning an approximate top-r
/// column subspace of `e`. Since U·V is an orthogonal projection of `e`,
/// ‖e − U·V‖_F ≤ ‖e‖_F always, with equality only when the subspace
/// misses `e` entirely. `rank` is clamped to the matrix dimensions.
/// Deterministic in `seed`.
pub fn factor(e: &Matrix, rank: usize, seed: u64) -> (Matrix, Matrix) {
    let r = rank.clamp(1, e.rows.min(e.cols).max(1));
    if e.is_empty() {
        return (Matrix::zeros(e.rows, r), Matrix::zeros(r, e.cols));
    }
    let mut rng = SplitMix64::new(seed);
    let g = Matrix::randn(e.cols, r, 1.0, &mut rng);
    let mut y = e.matmul(&g); // I × r
    let et = e.transpose();
    // two rounds of subspace iteration sharpen the captured spectrum
    for _ in 0..2 {
        let q = orthonormal_cols(&y);
        let z = orthonormal_cols(&et.matmul(&q)); // O × r
        y = e.matmul(&z);
    }
    let u = orthonormal_cols(&y); // I × r
    let v = u.transpose().matmul(e); // r × O
    (u, v)
}

/// Gram-Schmidt orthonormalization of the columns of `m` (modified GS,
/// f64 accumulation). Numerically dead columns become zero columns, which
/// keeps U·V a (partial) orthogonal projection.
fn orthonormal_cols(m: &Matrix) -> Matrix {
    let mut t = m.transpose(); // rows of t = columns of m
    let cols = t.cols;
    for i in 0..t.rows {
        for p in 0..i {
            let dot: f64 = (0..cols)
                .map(|k| t.get(i, k) as f64 * t.get(p, k) as f64)
                .sum();
            if dot != 0.0 {
                for k in 0..cols {
                    let v = t.get(i, k) - (dot * t.get(p, k) as f64) as f32;
                    t.set(i, k, v);
                }
            }
        }
        let norm: f64 = (0..cols).map(|k| (t.get(i, k) as f64).powi(2)).sum::<f64>().sqrt();
        if norm > 1e-12 {
            for k in 0..cols {
                let v = (t.get(i, k) as f64 / norm) as f32;
                t.set(i, k, v);
            }
        } else {
            for k in 0..cols {
                t.set(i, k, 0.0);
            }
        }
    }
    t.transpose()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor_reduces_residual_norm() {
        let mut rng = SplitMix64::new(3);
        let e = Matrix::randn(24, 16, 1.0, &mut rng);
        let (u, v) = factor(&e, 4, 42);
        assert_eq!((u.rows, u.cols), (24, 4));
        assert_eq!((v.rows, v.cols), (4, 16));
        let res = e.distance(&u.matmul(&v));
        assert!(res < e.frobenius(), "res={res} norm={}", e.frobenius());
    }

    #[test]
    fn full_rank_is_near_exact() {
        let mut rng = SplitMix64::new(9);
        let e = Matrix::randn(10, 6, 1.0, &mut rng);
        let (u, v) = factor(&e, 6, 1);
        let rel = e.distance(&u.matmul(&v)) / e.frobenius();
        assert!(rel < 1e-3, "rel={rel}");
    }

    #[test]
    fn low_rank_structure_is_recovered() {
        // a genuinely rank-2 residual is captured almost exactly at r = 2
        let mut rng = SplitMix64::new(17);
        let a = Matrix::randn(20, 2, 1.0, &mut rng);
        let b = Matrix::randn(2, 12, 1.0, &mut rng);
        let e = a.matmul(&b);
        let (u, v) = factor(&e, 2, 7);
        let rel = e.distance(&u.matmul(&v)) / e.frobenius();
        assert!(rel < 1e-3, "rel={rel}");
    }

    #[test]
    fn deterministic_in_seed() {
        let mut rng = SplitMix64::new(5);
        let e = Matrix::randn(12, 8, 1.0, &mut rng);
        let (u1, v1) = factor(&e, 3, 99);
        let (u2, v2) = factor(&e, 3, 99);
        assert_eq!(u1.data, u2.data);
        assert_eq!(v1.data, v2.data);
    }

    #[test]
    fn rank_is_clamped_to_dims() {
        let mut rng = SplitMix64::new(6);
        let e = Matrix::randn(4, 3, 1.0, &mut rng);
        let (u, v) = factor(&e, 64, 2);
        assert_eq!(u.cols, 3);
        assert_eq!(v.rows, 3);
    }
}
