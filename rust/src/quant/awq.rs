//! AWQ-style baseline (Lin et al., 2024): activation-aware weight scaling.
//!
//! AWQ protects salient weight channels by scaling them up before group-wise
//! weight quantization (and scaling activations down correspondingly). The
//! scale is s_j = mean|X_:,j|^β with β grid-searched per layer to minimise
//! the quantized-matmul output error on a calibration batch — the same
//! search AWQ's released code performs (`auto_scale.py`), minus kernel
//! fusion. Used in the W4A8-g128 rows of Tables 2/3/5, where activations
//! are quantized per-token on top (the paper's protocol for the AWQ rows).

use super::{per_channel::GroupWise, Bits, EPS};
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct Awq {
    /// Chosen saliency exponent β.
    pub beta: f32,
    /// Per-input-channel scales s_j = mean|X_:,j|^β (normalised).
    pub scales: Vec<f32>,
    pub group: usize,
    pub bits: Bits,
}

impl Awq {
    /// Grid-search β on a calibration batch, minimising
    /// ‖X·W − (X/s)·GWQ(s·W)‖_F.
    pub fn search(x_calib: &Matrix, w: &Matrix, bits: Bits, group: usize) -> Self {
        assert_eq!(x_calib.cols, w.rows);
        let act_mean = col_abs_mean(x_calib);
        let y_ref = x_calib.matmul(w);

        let mut best = (f32::INFINITY, 0.0f32, Vec::new());
        for step in 0..=10 {
            let beta = step as f32 / 10.0;
            let scales = normalised_scales(&act_mean, beta);
            let wq = GroupWise::new(bits, group).fake_quant(&scale_rows(w, &scales));
            let y = scale_cols_inv(x_calib, &scales).matmul(&wq);
            let err = y_ref.distance(&y);
            if err < best.0 {
                best = (err, beta, scales);
            }
        }
        Awq { beta: best.1, scales: best.2, group, bits }
    }

    /// The AWQ-quantized weight: GWQ(s·W) with the scale pre-applied. The
    /// runtime divides activations column-wise by s (see
    /// [`Awq::smooth_activation`]) so the product is function-preserving up
    /// to quantization error.
    pub fn quantize_weight(&self, w: &Matrix) -> Matrix {
        GroupWise::new(self.bits, self.group).fake_quant(&scale_rows(w, &self.scales))
    }

    pub fn smooth_activation(&self, x: &Matrix) -> Matrix {
        scale_cols_inv(x, &self.scales)
    }

    /// Effective (dequantized, unscaled) weight for running through an
    /// unmodified FP pipeline: diag(1/s)·GWQ(s·W).
    pub fn effective_weight(&self, w: &Matrix) -> Matrix {
        let q = self.quantize_weight(w);
        scale_rows_inv(&q, &self.scales)
    }
}

fn col_abs_mean(x: &Matrix) -> Vec<f32> {
    let mut acc = vec![0.0f64; x.cols];
    for i in 0..x.rows {
        for (a, &v) in acc.iter_mut().zip(x.row(i)) {
            *a += v.abs() as f64;
        }
    }
    acc.iter().map(|&a| (a / x.rows as f64) as f32).collect()
}

fn normalised_scales(act_mean: &[f32], beta: f32) -> Vec<f32> {
    let raw: Vec<f32> = act_mean.iter().map(|&m| m.max(EPS).powf(beta)).collect();
    // normalise the geometric mean to 1 so the overall weight magnitude is
    // unchanged (AWQ's trick to keep group scales in range)
    let log_mean = raw.iter().map(|&r| r.ln() as f64).sum::<f64>() / raw.len() as f64;
    let norm = (log_mean.exp()) as f32;
    raw.iter().map(|&r| (r / norm).max(EPS)).collect()
}

fn scale_rows(w: &Matrix, s: &[f32]) -> Matrix {
    let mut out = w.clone();
    for (j, &sj) in s.iter().enumerate() {
        for v in out.row_mut(j) {
            *v *= sj;
        }
    }
    out
}

fn scale_rows_inv(w: &Matrix, s: &[f32]) -> Matrix {
    let mut out = w.clone();
    for (j, &sj) in s.iter().enumerate() {
        for v in out.row_mut(j) {
            *v /= sj;
        }
    }
    out
}

fn scale_cols_inv(x: &Matrix, s: &[f32]) -> Matrix {
    let mut out = x.clone();
    for i in 0..out.rows {
        for (v, &sj) in out.row_mut(i).iter_mut().zip(s) {
            *v /= sj;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn calib_pair() -> (Matrix, Matrix) {
        let mut rng = SplitMix64::new(33);
        let mut x = Matrix::randn(128, 64, 1.0, &mut rng);
        for i in 0..x.rows {
            for j in 0..2 {
                let v = x.get(i, j) * 25.0;
                x.set(i, j, v);
            }
        }
        let w = Matrix::randn(64, 32, 0.1, &mut rng);
        (x, w)
    }

    #[test]
    fn search_beats_or_matches_plain_groupwise() {
        let (x, w) = calib_pair();
        let y_ref = x.matmul(&w);
        let plain = GroupWise::new(Bits::Int4, 32).fake_quant(&w);
        let e_plain = y_ref.distance(&x.matmul(&plain));
        let awq = Awq::search(&x, &w, Bits::Int4, 32);
        let e_awq = y_ref.distance(&awq.smooth_activation(&x).matmul(&awq.quantize_weight(&w)));
        assert!(e_awq <= e_plain * 1.0001, "awq={e_awq} plain={e_plain}");
    }

    #[test]
    fn effective_weight_function_preserving_shape() {
        let (x, w) = calib_pair();
        let awq = Awq::search(&x, &w, Bits::Int4, 32);
        let eff = awq.effective_weight(&w);
        assert_eq!((eff.rows, eff.cols), (w.rows, w.cols));
        // effective weight ≈ w up to 4-bit group quantization error
        let rel = w.distance(&eff) / w.frobenius();
        assert!(rel < 0.2, "rel {rel}");
    }

    #[test]
    fn beta_zero_means_no_scaling() {
        let act_mean = vec![1.0f32, 10.0, 100.0];
        let s = normalised_scales(&act_mean, 0.0);
        for v in s {
            assert!((v - 1.0).abs() < 1e-6);
        }
    }
}
