//! Packed-panel int8 GEMM — the integer deployment kernel behind
//! [`super::qlinear`].
//!
//! The seed kernel walked the weight row-major with a per-`k` scalar
//! broadcast and a memory-resident accumulator row: every activation row
//! re-streamed the whole weight from cache, and the accumulator row was
//! re-read and re-written once per `k` step. This module replaces it with
//! the classic packed-panel design:
//!
//! * **[`PackedInt8`]** — weight codes laid out in column panels of width
//!   [`NR`], K-major within a panel, so the microkernel streams one
//!   contiguous buffer. The remainder panel is zero-padded to `NR` (the
//!   inner loop stays uniform; writeback clips to the true width). Built
//!   once per weight in `QuantizedLinear::from_weight`, and rebuilt by the
//!   dynamic CrossQuant rescale via [`PackedInt8::pack_with`].
//! * **microkernels** — an [`MR`]×[`NR`] register tile of i8×i8→i32
//!   accumulators: each loaded weight value feeds `MR` rows and each loaded
//!   activation value feeds `NR` columns, cutting cache traffic ~`MR`× and
//!   keeping the accumulators out of memory. Three implementations share
//!   one contract (portable [`scalar`], AVX2 `maddubs`/`madd`, NEON
//!   `smull`/`sadalp`) and are selected at runtime by [`dispatch`]:
//!   `is_x86_feature_detected!` probing cached process-wide, with a
//!   `CROSSQUANT_ISA=scalar|avx2|neon` override for testing. All paths are
//!   bit-identical over the quantization code range — pinned against
//!   [`gemm_i32_ref`] in `rust/tests/gemm.rs`.
//! * **zero-block skip** — where the quantization-kernel sparsity actually
//!   pays: per row group, `k` is scanned **once** (word-at-a-time, shared
//!   across every panel and tile) into per-[`KB`]-block "any nonzero"
//!   flags, and every microkernel skips dead blocks. One branch per
//!   `MR`×`KB` block instead of one per element.
//! * **2-D tiling** — the parallel path splits work over a grid of
//!   (row-group chunk × panel chunk) tiles (see `par::tile_grid`), not just
//!   rows: an M=4 decode step or an M=N engine tick fans out across
//!   N-panels instead of leaving all but `M` workers idle. Each tile owns a
//!   disjoint region of the output, and per-element arithmetic is
//!   tile-independent, so results stay bit-exact for any worker count.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::tensor::{par, Matrix};
use crate::util::Mmap;

pub mod dispatch;
mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;

pub use dispatch::Isa;

/// Microkernel row tile: activation rows per register block.
pub const MR: usize = 4;
/// Panel width: output columns per packed panel (microkernel column tile).
pub const NR: usize = 8;
/// Granularity (in `k`) of the all-zero activation-block skip.
pub const KB: usize = 64;

/// Alignment the SIMD microkernels want panel buffers to start at so their
/// widest loads never straddle more cache lines than necessary. Correctness
/// never depends on it (every kernel uses unaligned loads), but
/// [`PackedInt8::from_mapped`] refuses to *borrow* a mapped buffer below
/// this alignment and copies it instead — see [`unaligned_panel_copies`].
pub const PANEL_ALIGN: usize = 16;

/// How many mapped panel sections failed the [`PANEL_ALIGN`] check and were
/// copied to owned memory instead of borrowed zero-copy. Non-zero means a
/// `.cqa` artifact's 64-byte section alignment did not survive the mapping
/// (or the file came from a foreign writer) — served results are still
/// correct, but the zero-copy property is lost for those sections.
pub fn unaligned_panel_copies() -> u64 {
    UNALIGNED_PANEL_COPIES.load(Ordering::Relaxed)
}

static UNALIGNED_PANEL_COPIES: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static GEMM_TIMING: Cell<bool> = const { Cell::new(false) };
    static GEMM_CALLS: Cell<u64> = const { Cell::new(0) };
    static GEMM_NANOS: Cell<u64> = const { Cell::new(0) };
}

/// Arm (or disarm) per-thread GEMM timing for request tracing. Timing is
/// thread-local because GEMMs run synchronously on the thread that drives
/// the forward pass (the executor), so span attribution never needs a
/// cross-thread handoff. Arming resets the accumulators.
pub fn gemm_timing_enable(on: bool) {
    GEMM_TIMING.with(|t| t.set(on));
    if on {
        GEMM_CALLS.with(|c| c.set(0));
        GEMM_NANOS.with(|n| n.set(0));
    }
}

/// Drain this thread's accumulated `(calls, nanoseconds)` spent inside
/// [`gemm_i32_packed`] since timing was armed, resetting both to zero.
/// Timing stays armed until [`gemm_timing_enable`]`(false)`.
pub fn gemm_timing_take() -> (u64, u64) {
    (GEMM_CALLS.with(|c| c.replace(0)), GEMM_NANOS.with(|n| n.replace(0)))
}

/// The owned/borrowed split behind [`PackedInt8`]: panels either own
/// their buffer (built by `pack_with`) or borrow it in place from a file
/// mapping (`quant::artifact`'s zero-copy load path — the Arc keeps the
/// map alive, the microkernel streams the mapped bytes directly).
#[derive(Clone, Debug)]
enum PanelData {
    Owned(Vec<i8>),
    Mapped { map: Arc<Mmap>, offset: usize, len: usize },
}

impl PanelData {
    #[inline]
    fn as_slice(&self) -> &[i8] {
        match self {
            PanelData::Owned(v) => v,
            PanelData::Mapped { map, offset, len } => {
                let bytes = &map.bytes()[*offset..*offset + *len];
                // i8 and u8 share layout; the panel bytes are plain codes
                unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const i8, bytes.len()) }
            }
        }
    }
}

/// Weight codes packed for the microkernel: `n.div_ceil(NR)` column panels,
/// each storing its `NR` columns K-major (`panel[kk*NR + jj]` is column
/// `p*NR + jj` at depth `kk`), zero-padded to full width.
#[derive(Clone, Debug)]
pub struct PackedInt8 {
    /// Contraction depth (weight rows).
    pub k: usize,
    /// True output columns (excluding panel padding).
    pub n: usize,
    data: PanelData,
}

impl PackedInt8 {
    /// Pack row-major (k × n) codes into panels.
    pub fn from_row_major(codes: &[i8], k: usize, n: usize) -> PackedInt8 {
        assert_eq!(codes.len(), k * n, "codes/shape mismatch");
        Self::pack_with(k, n, 1, |kk, j| codes[kk * n + j])
    }

    /// Packed-buffer size in bytes for a (k × n) layout, padding included
    /// — the byte contract between pack_with, [`PackedInt8::from_raw`],
    /// and the `quant::artifact` panel sections.
    pub fn layout_bytes(k: usize, n: usize) -> usize {
        n.div_ceil(NR) * k * NR
    }

    /// Rebuild from a raw packed buffer (the inverse of
    /// [`PackedInt8::raw_bytes`]) — the owned load path for payloads that
    /// cannot be referenced in place (nibble-packed INT4 sections).
    pub fn from_raw(k: usize, n: usize, data: Vec<i8>) -> PackedInt8 {
        assert_eq!(data.len(), Self::layout_bytes(k, n), "raw panel buffer size");
        PackedInt8 { k, n, data: PanelData::Owned(data) }
    }

    /// Borrow panels in place from a file mapping — the zero-copy load
    /// path of `quant::artifact`. The `layout_bytes(k, n)` bytes at
    /// `offset` must hold a buffer produced by `pack_with` (length is
    /// verified here; content integrity is the artifact CRC's job).
    ///
    /// The mapped pointer is validated against [`PANEL_ALIGN`] — the
    /// artifact writer 64-byte-aligns panel sections, but a foreign writer
    /// (or an owned fallback read of the file) can break that promise. A
    /// misaligned view is copied to an owned buffer instead of borrowed,
    /// counted by [`unaligned_panel_copies`]; results are identical either
    /// way, only zero-copy is lost.
    pub fn from_mapped(
        k: usize,
        n: usize,
        map: Arc<Mmap>,
        offset: usize,
    ) -> anyhow::Result<PackedInt8> {
        let len = Self::layout_bytes(k, n);
        anyhow::ensure!(
            offset.checked_add(len).is_some_and(|end| end <= map.len()),
            "mapped panels out of bounds: need {len} bytes at offset {offset}, map has {}",
            map.len()
        );
        if len > 0 {
            let ptr = map.bytes()[offset..].as_ptr();
            if (ptr as usize) % PANEL_ALIGN != 0 {
                UNALIGNED_PANEL_COPIES.fetch_add(1, Ordering::Relaxed);
                let bytes = &map.bytes()[offset..offset + len];
                let data: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
                return Ok(PackedInt8 { k, n, data: PanelData::Owned(data) });
            }
        }
        Ok(PackedInt8 { k, n, data: PanelData::Mapped { map, offset, len } })
    }

    /// True when the codes are served from a file mapping rather than
    /// owned memory (the zero-copy invariant pinned by
    /// rust/tests/artifact.rs).
    pub fn is_mapped(&self) -> bool {
        matches!(self.data, PanelData::Mapped { .. })
    }

    /// The raw packed buffer (padding included) — the bytes
    /// `quant::artifact` writes verbatim.
    pub fn raw_bytes(&self) -> &[u8] {
        let s = self.data.as_slice();
        unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, s.len()) }
    }

    /// Pack from a generator, panel-parallel — used by the dynamic
    /// CrossQuant rescale to fold scales and pack in a single pass with no
    /// row-major intermediate. `f(kk, j)` must be pure: panels are filled
    /// concurrently in arbitrary order.
    pub fn pack_with(
        k: usize,
        n: usize,
        workers: usize,
        f: impl Fn(usize, usize) -> i8 + Sync,
    ) -> PackedInt8 {
        let n_panels = n.div_ceil(NR);
        let mut data = vec![0i8; n_panels * k * NR];
        if data.is_empty() {
            return PackedInt8 { k, n, data: PanelData::Owned(data) };
        }
        par::par_rows_mut(&mut data, k * NR, workers, |p0, chunk| {
            for (local, panel) in chunk.chunks_mut(k * NR).enumerate() {
                let j0 = (p0 + local) * NR;
                let width = NR.min(n - j0);
                for kk in 0..k {
                    for jj in 0..width {
                        panel[kk * NR + jj] = f(kk, j0 + jj);
                    }
                }
            }
        });
        PackedInt8 { k, n, data: PanelData::Owned(data) }
    }

    /// Number of column panels (last one possibly padded).
    pub fn n_panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// Decode back to row-major (k × n) codes — the inverse of
    /// [`PackedInt8::from_row_major`], dropping panel padding.
    pub fn to_row_major(&self) -> Vec<i8> {
        let mut out = vec![0i8; self.k * self.n];
        for p in 0..self.n_panels() {
            let j0 = p * NR;
            let width = NR.min(self.n - j0);
            let panel = self.panel(p);
            for kk in 0..self.k {
                for jj in 0..width {
                    out[kk * self.n + j0 + jj] = panel[kk * NR + jj];
                }
            }
        }
        out
    }

    /// Packed buffer size in bytes, padding included.
    pub fn packed_bytes(&self) -> usize {
        self.data.as_slice().len()
    }

    #[inline]
    fn panel(&self, p: usize) -> &[i8] {
        &self.data.as_slice()[p * self.k * NR..(p + 1) * self.k * NR]
    }
}

/// The shared microkernel contract: `mr` (≤ [`MR`]) activation rows
/// against one K-major panel, skipping [`KB`]-blocks whose `live` flag is
/// false. Every implementation must return identical i32 sums for codes
/// in the quantization range (±127; the AVX2 operand fix-up documents the
/// one excluded weight value, −128, which no quantizer emits).
pub(crate) type Microkernel = fn(&[i8], usize, usize, &[i8], &[bool]) -> [[i32; NR]; MR];

/// Word-at-a-time "any nonzero byte" scan — the zero-skip flag pass must
/// not cost more than the skip saves at small `k`, so it reads u64 words,
/// not bytes.
#[inline]
fn any_nonzero(bytes: &[i8]) -> bool {
    // i8 → u64 reinterpret of the aligned middle is sound: both are plain
    // integers, and a word is nonzero iff one of its bytes is
    let (pre, mid, post) = unsafe { bytes.align_to::<u64>() };
    pre.iter().any(|&v| v != 0) || mid.iter().any(|&w| w != 0) || post.iter().any(|&v| v != 0)
}

/// Fill per-[`KB`]-block "any nonzero activation" flags for one `mr`-row
/// group. Called once per row group per GEMM — the flags are shared across
/// every panel and every column tile that touches the group.
fn scan_live(a_block: &[i8], mr: usize, k: usize, flags: &mut [bool]) {
    for (b, flag) in flags.iter_mut().enumerate() {
        let k0 = b * KB;
        let k1 = (k0 + KB).min(k);
        *flag = (0..mr).any(|r| any_nonzero(&a_block[r * k + k0..r * k + k1]));
    }
}

/// A raw output pointer smuggled into the tile workers. Each tile writes a
/// disjoint (row range × column range) region, so concurrent writes never
/// alias — the reason row-chunk splitting via `split_at_mut` is not enough
/// here (column tiles of one row interleave in the row-major buffer).
#[derive(Clone, Copy)]
struct SendPtr(*mut i32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Integer-only packed GEMM: `out[i*n + j] = Σ_k a[i,k]·w[k,j]` in i32,
/// on the runtime-dispatched microkernel ([`dispatch::active`]).
/// The bit-exactness oracle surface — every ISA, worker count, and tile
/// shape returns identical bytes.
pub fn gemm_i32_packed(a_codes: &[i8], m: usize, w: &PackedInt8, workers: usize) -> Vec<i32> {
    if !GEMM_TIMING.with(|t| t.get()) {
        return gemm_i32_packed_isa(a_codes, m, w, workers, dispatch::active());
    }
    let t0 = Instant::now();
    let out = gemm_i32_packed_isa(a_codes, m, w, workers, dispatch::active());
    GEMM_CALLS.with(|c| c.set(c.get() + 1));
    GEMM_NANOS.with(|n| n.set(n.get() + t0.elapsed().as_nanos() as u64));
    out
}

/// [`gemm_i32_packed`] with an explicit microkernel choice — the oracle
/// tests and the per-ISA bench sections compare paths inside one process,
/// where the `CROSSQUANT_ISA` override (read once) cannot be varied.
/// Panics if `isa` is not supported on this host.
pub fn gemm_i32_packed_isa(
    a_codes: &[i8],
    m: usize,
    w: &PackedInt8,
    workers: usize,
    isa: Isa,
) -> Vec<i32> {
    let kern = dispatch::kernel(isa);
    let (k, n) = (w.k, w.n);
    assert_eq!(a_codes.len(), m * k, "activation codes/shape mismatch");
    let mut out = vec![0i32; m * n];
    if out.is_empty() || k == 0 {
        return out; // empty output, or empty contraction (all-zero output)
    }
    let row_groups = m.div_ceil(MR);
    let kblocks = k.div_ceil(KB);
    // hoisted live-flag pass: one O(m·k) scan for the whole GEMM, instead
    // of one per (row group × column tile) inside the parallel closure
    let mut live = vec![false; row_groups * kblocks];
    par::par_rows_mut(&mut live, kblocks, workers.min(row_groups), |g0, chunk| {
        for (local, flags) in chunk.chunks_mut(kblocks).enumerate() {
            let i = (g0 + local) * MR;
            let mr = MR.min(m - i);
            scan_live(&a_codes[i * k..i * k + mr * k], mr, k, flags);
        }
    });
    let n_panels = w.n_panels();
    let (row_chunks, col_chunks) = par::tile_grid(row_groups, n_panels, workers);
    let g_per = row_groups.div_ceil(row_chunks);
    let p_per = n_panels.div_ceil(col_chunks);
    let tiles = row_chunks * col_chunks;
    let out_ptr = SendPtr(out.as_mut_ptr());
    par::par_map_rows(tiles, workers.min(tiles), |range| {
        for t in range {
            let (rc, cc) = (t / col_chunks, t % col_chunks);
            let (g0, g1) = (rc * g_per, ((rc + 1) * g_per).min(row_groups));
            let (p0, p1) = (cc * p_per, ((cc + 1) * p_per).min(n_panels));
            for g in g0..g1 {
                let i = g * MR;
                let mr = MR.min(m - i);
                let a_block = &a_codes[i * k..i * k + mr * k];
                let lv = &live[g * kblocks..(g + 1) * kblocks];
                for p in p0..p1 {
                    let acc = kern(a_block, mr, k, w.panel(p), lv);
                    let j0 = p * NR;
                    let width = NR.min(n - j0);
                    for (r, acc_r) in acc.iter().enumerate().take(mr) {
                        // safety: tile (rc, cc) exclusively owns rows
                        // [g0·MR, g1·MR) × cols [p0·NR, p1·NR) of out
                        let dst = unsafe {
                            std::slice::from_raw_parts_mut(out_ptr.0.add((i + r) * n + j0), width)
                        };
                        dst.copy_from_slice(&acc_r[..width]);
                    }
                }
            }
        }
    });
    out
}

/// Packed GEMM with rank-1 dequantization:
/// `out[i,j] = (Σ_k a[i,k]·w[k,j]) · row_scale[i] · col_scale[j]`.
/// This is the W8A8 serving entry point used by `QuantizedLinear`.
/// Delegates the tiling to [`gemm_i32_packed`] — one driver, one set of
/// bit-exactness tests — then applies the scales in a second row-parallel
/// pass (O(M·N), negligible next to the O(M·K·N) accumulation).
pub fn gemm_dequant(
    a_codes: &[i8],
    m: usize,
    w: &PackedInt8,
    row_scale: &[f32],
    col_scale: &[f32],
    workers: usize,
) -> Matrix {
    let n = w.n;
    assert_eq!(row_scale.len(), m, "row scale length");
    assert_eq!(col_scale.len(), n, "col scale length");
    let acc = gemm_i32_packed(a_codes, m, w, workers);
    let mut out = Matrix::zeros(m, n);
    if out.is_empty() {
        return out;
    }
    par::par_rows_mut(&mut out.data, n, workers, |row0, chunk| {
        for (local, dst) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + local;
            let rs = row_scale[i];
            let src = &acc[i * n..(i + 1) * n];
            for ((d, &a), &cs) in dst.iter_mut().zip(src).zip(col_scale) {
                *d = a as f32 * rs * cs;
            }
        }
    });
    out
}

/// Naive i32 reference GEMM over row-major codes (ascending `k`, no skips,
/// no packing) — the correctness oracle the packed kernel is
/// property-tested against in `rust/tests/gemm.rs`.
pub fn gemm_i32_ref(a_codes: &[i8], m: usize, k: usize, w_codes: &[i8], n: usize) -> Vec<i32> {
    assert_eq!(a_codes.len(), m * k, "activation codes/shape mismatch");
    assert_eq!(w_codes.len(), k * n, "weight codes/shape mismatch");
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let a = a_codes[i * k + kk] as i32;
            let w_row = &w_codes[kk * n..(kk + 1) * n];
            let o_row = &mut out[i * n..(i + 1) * n];
            for (o, &wv) in o_row.iter_mut().zip(w_row) {
                *o += a * wv as i32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn arb_codes(rng: &mut SplitMix64, len: usize, zero_frac: f64) -> Vec<i8> {
        (0..len)
            .map(|_| {
                if rng.uniform() < zero_frac {
                    0i8
                } else {
                    (rng.below(255) as i64 - 127) as i8
                }
            })
            .collect()
    }

    #[test]
    fn pack_roundtrips_row_major_layout() {
        let mut rng = SplitMix64::new(3);
        let (k, n) = (5, NR + 3); // remainder panel
        let codes = arb_codes(&mut rng, k * n, 0.2);
        let packed = PackedInt8::from_row_major(&codes, k, n);
        assert_eq!(packed.n_panels(), 2);
        assert_eq!(packed.packed_bytes(), 2 * k * NR);
        for p in 0..packed.n_panels() {
            let panel = packed.panel(p);
            for kk in 0..k {
                for jj in 0..NR {
                    let j = p * NR + jj;
                    let expect = if j < n { codes[kk * n + j] } else { 0 };
                    assert_eq!(panel[kk * NR + jj], expect, "panel {p} k {kk} j {jj}");
                }
            }
        }
    }

    #[test]
    fn to_row_major_inverts_packing() {
        let mut rng = SplitMix64::new(7);
        for (k, n) in [(5, NR + 3), (3, NR), (0, 4), (6, 1)] {
            let codes = arb_codes(&mut rng, k * n, 0.2);
            let packed = PackedInt8::from_row_major(&codes, k, n);
            assert_eq!(packed.to_row_major(), codes, "k={k} n={n}");
        }
    }

    #[test]
    fn mapped_and_raw_panels_match_owned() {
        let mut rng = SplitMix64::new(9);
        let (k, n) = (7, NR + 5);
        let codes = arb_codes(&mut rng, k * n, 0.3);
        let owned = PackedInt8::from_row_major(&codes, k, n);
        assert_eq!(owned.raw_bytes().len(), PackedInt8::layout_bytes(k, n));
        // raw round-trip (the owned artifact load path)
        let raw: Vec<i8> = owned.raw_bytes().iter().map(|&b| b as i8).collect();
        let rebuilt = PackedInt8::from_raw(k, n, raw);
        assert!(!rebuilt.is_mapped());
        assert_eq!(rebuilt.to_row_major(), codes);
        // borrowed round-trip (the zero-copy artifact load path): the
        // microkernel must produce identical sums over the mapped view
        let map = std::sync::Arc::new(crate::util::Mmap::from_vec(owned.raw_bytes().to_vec()));
        let mapped = PackedInt8::from_mapped(k, n, map.clone(), 0).unwrap();
        assert_eq!(mapped.to_row_major(), codes);
        let a = arb_codes(&mut rng, 3 * k, 0.2);
        assert_eq!(gemm_i32_packed(&a, 3, &mapped, 2), gemm_i32_packed(&a, 3, &owned, 1));
        // an out-of-bounds view is rejected, not sliced past the map
        assert!(PackedInt8::from_mapped(k, n, map, 8).is_err());
    }

    #[test]
    fn misaligned_mapped_panels_fall_back_to_owned_copy() {
        let mut rng = SplitMix64::new(11);
        let (k, n) = (6, NR);
        let codes = arb_codes(&mut rng, k * n, 0.2);
        let packed = PackedInt8::from_row_major(&codes, k, n);
        // prepend one byte so the panel bytes start at alignment 1 mod
        // PANEL_ALIGN — the artifact's 64-byte promise, deliberately broken
        let mut buf = vec![0u8];
        buf.extend_from_slice(packed.raw_bytes());
        let map = std::sync::Arc::new(crate::util::Mmap::from_vec(buf));
        let before = unaligned_panel_copies();
        let view = PackedInt8::from_mapped(k, n, map, 1).unwrap();
        assert!(!view.is_mapped(), "misaligned view must be copied, not borrowed");
        assert!(unaligned_panel_copies() > before, "fallback must be counted");
        assert_eq!(view.to_row_major(), codes, "the copy must decode identically");
    }

    // the full bit-exactness property suite (random shapes, structured
    // sparsity, dequant scaling, worker grids, every dispatch path) lives
    // in rust/tests/gemm.rs — only layout-internal and degenerate checks
    // stay in-module

    #[test]
    fn degenerate_shapes_are_safe() {
        // k = 0: empty contraction, all-zero output
        let packed = PackedInt8::from_row_major(&[], 0, 3);
        assert_eq!(gemm_i32_packed(&[], 2, &packed, 4), vec![0i32; 6]);
        // n = 0 and m = 0: empty outputs
        let packed = PackedInt8::from_row_major(&[], 5, 0);
        assert!(gemm_i32_packed(&[0i8; 10], 2, &packed, 1).is_empty());
        let packed = PackedInt8::from_row_major(&[1, 2, 3], 1, 3);
        assert!(gemm_i32_packed(&[], 0, &packed, 1).is_empty());
    }

    #[test]
    fn gemm_timing_counts_calls_only_while_armed() {
        let mut rng = SplitMix64::new(13);
        let (k, n) = (16, NR);
        let packed = PackedInt8::from_row_major(&arb_codes(&mut rng, k * n, 0.2), k, n);
        let a = arb_codes(&mut rng, 2 * k, 0.2);
        gemm_timing_enable(false);
        let _ = gemm_i32_packed(&a, 2, &packed, 1);
        assert_eq!(gemm_timing_take(), (0, 0), "disarmed GEMMs must not count");
        gemm_timing_enable(true);
        let _ = gemm_i32_packed(&a, 2, &packed, 1);
        let _ = gemm_i32_packed(&a, 2, &packed, 1);
        let (calls, _ns) = gemm_timing_take();
        assert_eq!(calls, 2);
        assert_eq!(gemm_timing_take(), (0, 0), "take drains the accumulators");
        gemm_timing_enable(false);
    }

    #[test]
    fn word_scan_sees_every_byte_position() {
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65] {
            assert!(!any_nonzero(&vec![0i8; len]));
            for pos in 0..len {
                let mut v = vec![0i8; len];
                v[pos] = -1;
                assert!(any_nonzero(&v), "len={len} pos={pos}");
            }
        }
    }
}
