//! NEON microkernel: i8×i8→i32 via widening multiply + pairwise
//! accumulate — `smull` (`vmull_s8`) then `sadalp` (`vpadalq_s16`).
//!
//! Unlike the AVX2 path there is no operand-signedness fix-up to make:
//! `vmull_s8` is a true signed i8×i8→i16 widening multiply, exact for
//! every i8 value including −128, and `vpadalq_s16` adds adjacent i16
//! pairs into i32 accumulators without any saturation. The kernel is
//! therefore bit-exact over the full i8 domain.
//!
//! Register scheme, per 2 `k`-steps: one 16-byte unaligned load covers 2
//! K-major panel rows of [`NR`] = 8 columns; `vzip_s8` interleaves them
//! into per-column (k, k+1) byte pairs. Each activation row contributes
//! its `[a(k) a(k+1)]` pair broadcast across 8 bytes; `vmull_s8` produces
//! the 8 pair products and `vpadalq_s16` folds each column's pair into
//! one of two i32×4 accumulators (columns 0‥3 and 4‥7).

#[allow(clippy::wildcard_imports)]
use std::arch::aarch64::*;

use super::{KB, MR, NR};

/// Safe wrapper: NEON (asimd) is a baseline feature of aarch64, so the
/// kernel is always callable once the target architecture matches.
pub(super) fn microkernel(
    a_block: &[i8],
    mr: usize,
    k: usize,
    panel: &[i8],
    live: &[bool],
) -> [[i32; NR]; MR] {
    // safety: neon is mandatory on aarch64; slices are bounds-checked inside
    unsafe { kernel_neon(a_block, mr, k, panel, live) }
}

#[target_feature(enable = "neon")]
unsafe fn kernel_neon(
    a_block: &[i8],
    mr: usize,
    k: usize,
    panel: &[i8],
    live: &[bool],
) -> [[i32; NR]; MR] {
    debug_assert!(a_block.len() >= mr * k);
    debug_assert!(panel.len() >= k * NR);
    let mut acc = [[0i32; NR]; MR];
    let mut acc_lo = [vdupq_n_s32(0); MR];
    let mut acc_hi = [vdupq_n_s32(0); MR];
    for (b, &is_live) in live.iter().enumerate() {
        if !is_live {
            continue;
        }
        let k0 = b * KB;
        let k1 = (k0 + KB).min(k);
        let mut kk = k0;
        while kk + 2 <= k1 {
            // 16 bytes = 2 K-major panel rows: [k0c0‥k0c7 | k1c0‥k1c7]
            let w16 = vld1q_s8(panel.as_ptr().add(kk * NR));
            // zip into per-column (k0, k1) pairs: z.0 = cols 0‥3, z.1 = 4‥7
            let z = vzip_s8(vget_low_s8(w16), vget_high_s8(w16));
            for r in 0..mr {
                let a0 = *a_block.get_unchecked(r * k + kk) as u8 as u16;
                let a1 = *a_block.get_unchecked(r * k + kk + 1) as u8 as u16;
                // little-endian: byte 0 = a(k0), byte 1 = a(k1), ×8
                let apair = vreinterpret_s8_u16(vdup_n_u16(a0 | (a1 << 8)));
                // smull widen-multiply, sadalp pairwise widen-accumulate
                acc_lo[r] = vpadalq_s16(acc_lo[r], vmull_s8(z.0, apair));
                acc_hi[r] = vpadalq_s16(acc_hi[r], vmull_s8(z.1, apair));
            }
            kk += 2;
        }
        // scalar tail: odd-length final block (KB itself is even)
        while kk < k1 {
            let w_row = &panel[kk * NR..kk * NR + NR];
            for (r, acc_r) in acc.iter_mut().enumerate().take(mr) {
                let ar = a_block[r * k + kk] as i32;
                for (jj, &wv) in w_row.iter().enumerate() {
                    acc_r[jj] += ar * wv as i32;
                }
            }
            kk += 1;
        }
    }
    for r in 0..mr {
        let mut lanes = [0i32; NR];
        vst1q_s32(lanes.as_mut_ptr(), acc_lo[r]);
        vst1q_s32(lanes.as_mut_ptr().add(4), acc_hi[r]);
        for (a, l) in acc[r].iter_mut().zip(lanes) {
            *a += l;
        }
    }
    acc
}
