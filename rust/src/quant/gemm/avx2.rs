//! AVX2 microkernel: i8×i8→i32 dot-product accumulation via
//! `_mm256_maddubs_epi16` + `_mm256_madd_epi16`.
//!
//! `maddubs` multiplies **unsigned** bytes by **signed** bytes, but both
//! our operands are signed. The classic operand fix-up makes the pair
//! legal without changing the product: feed it `|a|` (unsigned) and
//! `w·sign(a)` (signed, via `_mm256_sign_epi8`) — `|a| · w·sign(a) =
//! a·w`, and `sign_epi8` zeroing the weight where `a == 0` is exactly
//! right. Saturation is then impossible: `|a| ≤ 128`, `|w| ≤ 127`, so a
//! pair sum is at most `2·128·127 = 32512 < 32767` (and `2·128·(−128) =
//! −32768` is representable). The single value outside the contract is a
//! **weight** byte of −128 combined with a negative activation —
//! `sign_epi8` cannot negate −128 — which no quantizer emits (codes are
//! clamped to ±qmax ≤ 127 and panel padding is 0). Activations of −128
//! are handled exactly (`abs_epi8(−128)` reads back as u8 128 = |−128|).
//!
//! Register scheme, per 4 `k`-steps: one 32-byte unaligned panel load
//! covers 4 K-major rows of [`NR`] = 8 columns. Three shuffles transpose
//! it to column-major quads `[w(k0,cj) w(k1,cj) w(k2,cj) w(k3,cj)] × 8`.
//! Each activation row contributes a 4-byte quad `[a(k0)..a(k3)]`
//! broadcast across the register; `maddubs` reduces (k0,k1) and (k2,k3)
//! pairs to i16, `madd_epi16` against ones reduces the two pairs to one
//! i32 per column — a full 8-column FMA per row per instruction pair.
//! All loads are unaligned (`loadu`): owned panel buffers guarantee no
//! alignment, mapped ones guarantee [`super::PANEL_ALIGN`]; alignment
//! only moves loads off cache-line splits, never correctness.

#[allow(clippy::wildcard_imports)]
use std::arch::x86_64::*;

use super::{KB, MR, NR};

/// Safe wrapper: the caller ([`super::dispatch`]) only hands out this
/// kernel after `is_x86_feature_detected!("avx2")` has confirmed support.
pub(super) fn microkernel(
    a_block: &[i8],
    mr: usize,
    k: usize,
    panel: &[i8],
    live: &[bool],
) -> [[i32; NR]; MR] {
    debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
    // safety: avx2 presence is guaranteed by dispatch (asserted above in
    // debug); slices are bounds-checked inside
    unsafe { kernel_avx2(a_block, mr, k, panel, live) }
}

#[target_feature(enable = "avx2")]
unsafe fn kernel_avx2(
    a_block: &[i8],
    mr: usize,
    k: usize,
    panel: &[i8],
    live: &[bool],
) -> [[i32; NR]; MR] {
    debug_assert!(a_block.len() >= mr * k);
    debug_assert!(panel.len() >= k * NR);
    let mut acc = [[0i32; NR]; MR];
    let mut vacc = [_mm256_setzero_si256(); MR];
    let ones = _mm256_set1_epi16(1);
    // per-128-lane byte shuffle interleaving the lane's two 8-byte K-rows
    // into 16-bit (k, k+1) column pairs: [x0 y0 x1 y1 … x7 y7]
    let interleave = _mm256_setr_epi8(
        0, 8, 1, 9, 2, 10, 3, 11, 4, 12, 5, 13, 6, 14, 7, 15, //
        0, 8, 1, 9, 2, 10, 3, 11, 4, 12, 5, 13, 6, 14, 7, 15,
    );
    for (b, &is_live) in live.iter().enumerate() {
        if !is_live {
            continue;
        }
        let k0 = b * KB;
        let k1 = (k0 + KB).min(k);
        let mut kk = k0;
        while kk + 4 <= k1 {
            // 32 bytes = 4 K-major panel rows: [k0c0‥k0c7 | k1… | k2… | k3…]
            let w_raw = _mm256_loadu_si256(panel.as_ptr().add(kk * NR) as *const __m256i);
            // transpose 4×8 bytes → 8 column quads [w(k0,cj)‥w(k3,cj)]:
            // lane-local interleave to (k0,k1)/(k2,k3) 16-bit pairs…
            let t = _mm256_shuffle_epi8(w_raw, interleave);
            // …gather each lane's pairs for columns 0-3 / 4-7 together…
            let s = _mm256_permute4x64_epi64(t, 0b11_01_10_00);
            // …and zip the (k0,k1) pairs with the (k2,k3) pairs per column
            let sw = _mm256_shuffle_epi32(s, 0b01_00_11_10);
            let wt = _mm256_unpacklo_epi16(s, sw);
            for (r, vr) in vacc.iter_mut().enumerate().take(mr) {
                // 4 consecutive activation codes of row r as one i32 quad
                let quad = (a_block.as_ptr().add(r * k + kk) as *const i32).read_unaligned();
                let av = _mm256_set1_epi32(quad);
                // signed×signed → unsigned×signed operand fix-up (see
                // module docs): maddubs needs its first operand unsigned
                let au = _mm256_abs_epi8(av);
                let ws = _mm256_sign_epi8(wt, av);
                let p16 = _mm256_maddubs_epi16(au, ws); // (k0,k1)+(k2,k3) pairs
                let p32 = _mm256_madd_epi16(p16, ones); // pair-of-pairs → i32
                *vr = _mm256_add_epi32(*vr, p32);
            }
            kk += 4;
        }
        // scalar tail: k-block length not a multiple of 4 (only possible
        // in the final partial block — KB is a multiple of 4)
        while kk < k1 {
            let w_row = &panel[kk * NR..kk * NR + NR];
            for (r, acc_r) in acc.iter_mut().enumerate().take(mr) {
                let ar = a_block[r * k + kk] as i32;
                for (jj, &wv) in w_row.iter().enumerate() {
                    acc_r[jj] += ar * wv as i32;
                }
            }
            kk += 1;
        }
    }
    for (acc_r, vr) in acc.iter_mut().zip(vacc.iter()).take(mr) {
        let mut lanes = [0i32; NR];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, *vr);
        for (a, l) in acc_r.iter_mut().zip(lanes) {
            *a += l;
        }
    }
    acc
}
