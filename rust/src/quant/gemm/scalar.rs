//! The portable scalar microkernel — the reference implementation every
//! SIMD path must match bit-for-bit, and the fallback [`super::dispatch`]
//! selects when no vector ISA is available (or `CROSSQUANT_ISA=scalar`
//! forces it).

use super::{KB, MR, NR};

/// Register-tiled i8×i8→i32 microkernel: `mr` (≤ [`MR`]) activation rows
/// against one K-major panel. The element loop is branch-free; the only
/// data-dependent branch is the per-[`KB`]-block skip.
pub(super) fn microkernel(
    a_block: &[i8],
    mr: usize,
    k: usize,
    panel: &[i8],
    live: &[bool],
) -> [[i32; NR]; MR] {
    let mut acc = [[0i32; NR]; MR];
    if mr == MR {
        // full-height fast path: fixed trip counts so the 4×8 accumulator
        // tile stays in registers (MR is hardcoded in the a0..a3 loads)
        for (b, &is_live) in live.iter().enumerate() {
            if !is_live {
                continue;
            }
            let k0 = b * KB;
            let k1 = (k0 + KB).min(k);
            for kk in k0..k1 {
                let w_row = &panel[kk * NR..kk * NR + NR];
                let a0 = a_block[kk] as i32;
                let a1 = a_block[k + kk] as i32;
                let a2 = a_block[2 * k + kk] as i32;
                let a3 = a_block[3 * k + kk] as i32;
                for (jj, &wv) in w_row.iter().enumerate() {
                    let wv = wv as i32;
                    acc[0][jj] += a0 * wv;
                    acc[1][jj] += a1 * wv;
                    acc[2][jj] += a2 * wv;
                    acc[3][jj] += a3 * wv;
                }
            }
        }
    } else {
        // remainder row group (< MR rows): same math, rolled over rows
        for (b, &is_live) in live.iter().enumerate() {
            if !is_live {
                continue;
            }
            let k0 = b * KB;
            let k1 = (k0 + KB).min(k);
            for kk in k0..k1 {
                let w_row = &panel[kk * NR..kk * NR + NR];
                for (r, acc_r) in acc.iter_mut().enumerate().take(mr) {
                    let ar = a_block[r * k + kk] as i32;
                    for (jj, &wv) in w_row.iter().enumerate() {
                        acc_r[jj] += ar * wv as i32;
                    }
                }
            }
        }
    }
    acc
}
