//! Runtime ISA dispatch for the packed-GEMM microkernel.
//!
//! The host is probed **once** per process (`is_x86_feature_detected!` on
//! x86_64; NEON is a baseline feature of aarch64) and the winning kernel
//! is cached as a plain function pointer — the hot path pays one atomic
//! load, no per-call feature detection. The `CROSSQUANT_ISA` environment
//! variable (`scalar` | `avx2` | `neon`, read at the same single probe)
//! forces a specific path for testing; requesting an ISA the host cannot
//! run, or an unknown name, is a loud startup panic rather than a silent
//! fallback — a forced-ISA test run must never silently measure the wrong
//! kernel.
//!
//! Every kernel is bit-identical over the quantization code range (the
//! AVX2 operand fix-up excludes only weight byte −128, which no quantizer
//! emits), so dispatch is a pure speed decision — pinned per-path against
//! `gemm_i32_ref` in `rust/tests/gemm.rs`.

use std::sync::OnceLock;

use super::Microkernel;

/// The instruction sets the packed GEMM can run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar microkernel — always available, and the reference
    /// the SIMD paths are pinned against.
    Scalar,
    /// x86_64 AVX2: `_mm256_maddubs_epi16`/`_mm256_madd_epi16` dot-product
    /// accumulation with the unsigned×signed operand fix-up.
    Avx2,
    /// aarch64 NEON: `smull` widening multiply + `sadalp` pairwise
    /// accumulate.
    Neon,
}

impl Isa {
    /// The wire/env name (`CROSSQUANT_ISA` values, bench labels).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Every ISA this build knows about (supported or not).
    pub const ALL: [Isa; 3] = [Isa::Scalar, Isa::Avx2, Isa::Neon];
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Isa {
    type Err = String;

    fn from_str(s: &str) -> Result<Isa, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(Isa::Scalar),
            "avx2" => Ok(Isa::Avx2),
            "neon" => Ok(Isa::Neon),
            other => Err(format!("unknown ISA '{other}' (expected scalar|avx2|neon)")),
        }
    }
}

/// Can this host execute `isa`'s microkernel?
pub fn supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => false,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => true,
        #[cfg(not(target_arch = "aarch64"))]
        Isa::Neon => false,
    }
}

/// The fastest supported ISA on this host (ignoring any override).
pub fn best() -> Isa {
    if supported(Isa::Avx2) {
        Isa::Avx2
    } else if supported(Isa::Neon) {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

/// Resolve an optional `CROSSQUANT_ISA` value against the probe — split
/// out from the cached [`active`] so the selection rules are unit-testable
/// without touching process-global state.
fn resolve(env_override: Option<&str>) -> Isa {
    match env_override {
        None => best(),
        Some(v) => {
            let isa: Isa = v
                .parse()
                .unwrap_or_else(|e: String| panic!("CROSSQUANT_ISA: {e}"));
            assert!(
                supported(isa),
                "CROSSQUANT_ISA={} requested but this host cannot run it \
                 (supported: {})",
                isa.name(),
                Isa::ALL
                    .iter()
                    .filter(|&&i| supported(i))
                    .map(|i| i.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
            isa
        }
    }
}

/// The ISA serving [`super::gemm_i32_packed`]: probed (and the
/// `CROSSQUANT_ISA` override read) once per process, then cached.
pub fn active() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| resolve(std::env::var("CROSSQUANT_ISA").ok().as_deref()))
}

/// The microkernel implementing `isa`. Panics if the host cannot run it —
/// the explicit-ISA entry points are for tests and benches, which must
/// fail loudly rather than quietly measure a different kernel.
pub(super) fn kernel(isa: Isa) -> Microkernel {
    assert!(
        supported(isa),
        "ISA {} is not supported on this host (arch {})",
        isa.name(),
        std::env::consts::ARCH
    );
    match isa {
        Isa::Scalar => super::scalar::microkernel,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => super::avx2::microkernel,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => super::neon::microkernel,
        #[allow(unreachable_patterns)] // unsupported ISAs die in the assert
        _ => unreachable!("kernel() past a failed support check"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_known_names_case_insensitively() {
        assert_eq!("scalar".parse::<Isa>().unwrap(), Isa::Scalar);
        assert_eq!(" AVX2 ".parse::<Isa>().unwrap(), Isa::Avx2);
        assert_eq!("neon".parse::<Isa>().unwrap(), Isa::Neon);
        assert!("sse9".parse::<Isa>().is_err());
        assert!("".parse::<Isa>().is_err());
    }

    #[test]
    fn scalar_is_always_supported_and_best_is_runnable() {
        assert!(supported(Isa::Scalar));
        assert!(supported(best()));
        // the cached active ISA must be runnable too (env override or not)
        assert!(supported(active()));
        let _ = kernel(active());
    }

    #[test]
    fn resolve_honors_explicit_override() {
        assert_eq!(resolve(None), best());
        assert_eq!(resolve(Some("scalar")), Isa::Scalar);
    }

    #[test]
    #[should_panic(expected = "unknown ISA")]
    fn resolve_rejects_unknown_names_loudly() {
        let _ = resolve(Some("quantum"));
    }

    #[test]
    fn unsupported_isas_exist_per_arch() {
        // exactly one of avx2/neon can ever be supported on one host
        assert!(!(supported(Isa::Avx2) && supported(Isa::Neon)));
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    #[should_panic(expected = "cannot run it")]
    fn resolve_rejects_foreign_arch_isa_loudly() {
        let _ = resolve(Some("neon"));
    }
}
