//! `.cqa` deployable quantized-model artifacts — the persisted form of a
//! calibrated static-scale CrossQuant model.
//!
//! The rest of the crate calibrates lazily: every serve process pays FP
//! weight load + calibration forwards + panel packing before the first
//! static-scale request. This module closes the paper's deployment story
//! (calibrate **once**, fold the eq. (5) ĉ^(1−α) factors into the codes
//! **once**, ship int8): a versioned, checksummed, 64-byte-aligned binary
//! file holding the model config, the folded packed weight panels, the
//! folded per-output scales, the activation-side column factors, the raw
//! calibration statistics, and α — laid out so the int8 panels are
//! readable **in place** through [`crate::util::Mmap`]
//! ([`PackedInt8::from_mapped`]): the serving microkernel streams the
//! mapped bytes with zero copy.
//!
//! ## Byte layout (version 2)
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------------
//!      0     4  magic  b"CQA1"
//!      4     4  format version (u32 LE) = 2 (1 still readable)
//!      8    28  ModelConfig: vocab, d_model, n_layers, n_heads, d_ff,
//!               seq_len, eval_batch (7 × u32 LE)
//!     36     4  α (f32 LE) — the calibration exponent of every fold
//!     40     1  weight bit-width (4 = INT4, 8 = INT8)
//!     41     1  activation bit-width
//!     42     2  quantizer-scheme ID (u16 LE, see
//!               `registry::SchemeId::artifact_code`; version-1 files
//!               wrote zeros here, which decodes to crossquant-static)
//!     44     4  section count N (u32 LE)
//!     48     8  total file length (u64 LE) — truncation detector
//!     56     4  CRC-32 of the section table
//!     60     4  CRC-32 of header bytes 0..60
//!     64  N×64  section table, one 64-byte entry per section:
//!               name[32] (NUL-padded) | kind u32 | rows u32 | cols u32
//!               | offset u64 | len u64 | payload CRC-32 u32
//!      …     …  payloads, each starting on a 64-byte boundary
//! ```
//!
//! Section kinds: `1` = f32 LE values (`rows × cols`), `2` = int8 packed
//! panels written verbatim in the [`PackedInt8`] NR=8 layout (`rows` = k,
//! `cols` = n), `3` = the same panel buffer nibble-packed two codes per
//! byte (INT4 weights — halves the shipped bytes; decoded to an owned
//! buffer at load, since nibbles cannot be referenced in place).
//!
//! Every load error is structured and distinct (truncated file, bad
//! magic, unsupported version, header/table/section CRC mismatch, shape
//! mismatch) — pinned by the corruption suite in rust/tests/artifact.rs.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::gemm::PackedInt8;
use super::{pack, Bits};
use crate::model::config::ModelConfig;
use crate::tensor::Matrix;
use crate::util::{crc32, Mmap};

/// File magic: "CQA" + format generation.
pub const MAGIC: [u8; 4] = *b"CQA1";
/// Format version this build writes. Version 1 (identical layout, the
/// scheme-ID bytes reserved as zero) is still readable.
pub const VERSION: u32 = 2;
/// Oldest format version this build still reads.
pub const MIN_VERSION: u32 = 1;
/// Every payload section starts on this boundary (cache-line / SIMD
/// friendly, and what `PackedInt8::from_mapped` is handed).
pub const ALIGN: usize = 64;
/// Fixed header size.
pub const HEADER_BYTES: usize = 64;
/// Fixed section-table entry size.
pub const ENTRY_BYTES: usize = 64;
/// NUL-padded name field inside an entry.
const NAME_BYTES: usize = 32;

/// What a section's payload holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SectionKind {
    /// `rows × cols` f32 LE values (embeddings, LN affines, scale vectors).
    F32,
    /// Verbatim [`PackedInt8`] panel buffer (`rows` = k, `cols` = n) —
    /// mmap-servable in place.
    PanelsI8,
    /// Nibble-packed panel buffer (two INT4 codes per byte).
    PanelsI4,
}

impl SectionKind {
    fn code(self) -> u32 {
        match self {
            SectionKind::F32 => 1,
            SectionKind::PanelsI8 => 2,
            SectionKind::PanelsI4 => 3,
        }
    }

    fn from_code(c: u32) -> Result<SectionKind> {
        match c {
            1 => Ok(SectionKind::F32),
            2 => Ok(SectionKind::PanelsI8),
            3 => Ok(SectionKind::PanelsI4),
            other => bail!("unknown section kind {other}"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SectionKind::F32 => "f32",
            SectionKind::PanelsI8 => "i8-panels",
            SectionKind::PanelsI4 => "i4-panels",
        }
    }

    /// Payload byte length a `rows × cols` section of this kind must have.
    fn expected_len(self, rows: usize, cols: usize) -> usize {
        match self {
            SectionKind::F32 => rows * cols * 4,
            SectionKind::PanelsI8 => PackedInt8::layout_bytes(rows, cols),
            SectionKind::PanelsI4 => PackedInt8::layout_bytes(rows, cols).div_ceil(2),
        }
    }
}

/// One parsed section-table entry.
#[derive(Clone, Debug)]
pub struct Section {
    pub name: String,
    pub kind: SectionKind,
    pub rows: usize,
    pub cols: usize,
    /// Payload offset from the start of the file (64-byte aligned).
    pub offset: usize,
    /// Payload byte length.
    pub len: usize,
    pub crc: u32,
}

fn bits_code(bits: Bits) -> Result<u8> {
    let code = match bits {
        Bits::Int4 => 4,
        Bits::Int8 => 8,
        Bits::Other(n) => n,
    };
    // artifact payloads are i8 codes — wider grids are not representable
    ensure!((2..=8).contains(&code), "bit width {code} is not representable in i8 codes");
    Ok(code)
}

fn bits_from_code(code: u8) -> Result<Bits> {
    match code {
        4 => Ok(Bits::Int4),
        8 => Ok(Bits::Int8),
        n if (2..=8).contains(&n) => Ok(Bits::Other(n)),
        other => bail!("unsupported bit width {other} (this build serves 2..=8-bit i8 codes)"),
    }
}

fn align_up(x: usize) -> usize {
    x.div_ceil(ALIGN) * ALIGN
}

fn u32_le(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

fn u64_le(b: &[u8], off: usize) -> u64 {
    let mut v = [0u8; 8];
    v.copy_from_slice(&b[off..off + 8]);
    u64::from_le_bytes(v)
}

/// Builds a `.cqa` file section by section; `write` lays out, checksums
/// and emits the bytes. Section names must be unique and ≤ 31 bytes.
pub struct ArtifactWriter {
    config: ModelConfig,
    alpha: f32,
    weight_bits: Bits,
    act_bits: Bits,
    scheme: u16,
    sections: Vec<(Section, Vec<u8>)>,
}

impl ArtifactWriter {
    pub fn new(config: ModelConfig, alpha: f32, weight_bits: Bits, act_bits: Bits) -> Self {
        ArtifactWriter { config, alpha, weight_bits, act_bits, scheme: 0, sections: Vec::new() }
    }

    /// Stamp the quantizer-scheme ID into the header (default 0 =
    /// crossquant-static, the only scheme version-1 files could hold).
    pub fn set_scheme(&mut self, scheme: u16) {
        self.scheme = scheme;
    }

    fn push(
        &mut self,
        name: &str,
        kind: SectionKind,
        rows: usize,
        cols: usize,
        payload: Vec<u8>,
    ) -> Result<()> {
        ensure!(
            !name.is_empty() && name.len() < NAME_BYTES && name.is_ascii(),
            "section name '{name}' must be 1..{NAME_BYTES} ASCII bytes"
        );
        ensure!(
            !self.sections.iter().any(|(s, _)| s.name == name),
            "duplicate section '{name}'"
        );
        ensure!(
            payload.len() == kind.expected_len(rows, cols),
            "section '{name}': payload is {} bytes, its {rows}x{cols} {} shape needs {}",
            payload.len(),
            kind.label(),
            kind.expected_len(rows, cols)
        );
        let crc = crc32(&payload);
        let len = payload.len();
        let section = Section { name: name.to_string(), kind, rows, cols, offset: 0, len, crc };
        self.sections.push((section, payload));
        Ok(())
    }

    /// Add a `rows × cols` f32 section.
    pub fn add_f32(&mut self, name: &str, rows: usize, cols: usize, data: &[f32]) -> Result<()> {
        ensure!(
            data.len() == rows * cols,
            "section '{name}': {rows}x{cols} needs {} values",
            rows * cols
        );
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.push(name, SectionKind::F32, rows, cols, bytes)
    }

    /// Add a matrix as an f32 section.
    pub fn add_matrix(&mut self, name: &str, m: &Matrix) -> Result<()> {
        self.add_f32(name, m.rows, m.cols, &m.data)
    }

    /// Add packed weight panels: the buffer is written verbatim for
    /// byte-wide grids (mmap-servable in place) and nibble-packed for
    /// INT4 weights (half the shipped bytes).
    pub fn add_panels(&mut self, name: &str, p: &PackedInt8) -> Result<()> {
        match self.weight_bits {
            Bits::Int4 => {
                let codes: Vec<i8> = p.raw_bytes().iter().map(|&b| b as i8).collect();
                self.push(name, SectionKind::PanelsI4, p.k, p.n, pack::pack_nibbles(&codes))
            }
            _ => self.push(name, SectionKind::PanelsI8, p.k, p.n, p.raw_bytes().to_vec()),
        }
    }

    /// Sections added so far.
    pub fn section_count(&self) -> usize {
        self.sections.len()
    }

    /// Serialize the full artifact to bytes (header | table | payloads).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let n = self.sections.len();
        ensure!(n > 0, "artifact has no sections");
        let payload_start = align_up(HEADER_BYTES + n * ENTRY_BYTES);
        let mut offsets = Vec::with_capacity(n);
        let mut off = payload_start;
        for (_, payload) in &self.sections {
            offsets.push(off);
            off = align_up(off + payload.len());
        }
        let file_len = off;

        let mut table = Vec::with_capacity(n * ENTRY_BYTES);
        for (i, (s, _)) in self.sections.iter().enumerate() {
            let mut name = [0u8; NAME_BYTES];
            name[..s.name.len()].copy_from_slice(s.name.as_bytes());
            table.extend_from_slice(&name);
            table.extend_from_slice(&s.kind.code().to_le_bytes());
            table.extend_from_slice(&(s.rows as u32).to_le_bytes());
            table.extend_from_slice(&(s.cols as u32).to_le_bytes());
            table.extend_from_slice(&(offsets[i] as u64).to_le_bytes());
            table.extend_from_slice(&(s.len as u64).to_le_bytes());
            table.extend_from_slice(&s.crc.to_le_bytes());
        }
        debug_assert_eq!(table.len(), n * ENTRY_BYTES);

        let cfg = self.config;
        let mut head = Vec::with_capacity(HEADER_BYTES);
        head.extend_from_slice(&MAGIC);
        head.extend_from_slice(&VERSION.to_le_bytes());
        for v in [
            cfg.vocab, cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.d_ff, cfg.seq_len,
            cfg.eval_batch,
        ] {
            head.extend_from_slice(&(v as u32).to_le_bytes());
        }
        head.extend_from_slice(&self.alpha.to_le_bytes());
        head.push(bits_code(self.weight_bits)?);
        head.push(bits_code(self.act_bits)?);
        head.extend_from_slice(&self.scheme.to_le_bytes());
        head.extend_from_slice(&(n as u32).to_le_bytes());
        head.extend_from_slice(&(file_len as u64).to_le_bytes());
        head.extend_from_slice(&crc32(&table).to_le_bytes());
        let hcrc = crc32(&head);
        head.extend_from_slice(&hcrc.to_le_bytes());
        debug_assert_eq!(head.len(), HEADER_BYTES);

        let mut out = vec![0u8; file_len];
        out[..HEADER_BYTES].copy_from_slice(&head);
        out[HEADER_BYTES..HEADER_BYTES + table.len()].copy_from_slice(&table);
        for (i, (_, payload)) in self.sections.iter().enumerate() {
            out[offsets[i]..offsets[i] + payload.len()].copy_from_slice(payload);
        }
        Ok(out)
    }

    /// Serialize and write the artifact file **atomically**: the bytes go
    /// to a temporary sibling first and are renamed over `path`, so an
    /// interrupted write (kill, ENOSPC) can never destroy a previously
    /// good artifact at the destination.
    pub fn write(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes()?;
        let mut tmp = path.as_os_str().to_os_string();
        tmp.push(format!(".tmp.{}", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("writing artifact {}", tmp.display()))?;
        if let Err(e) = std::fs::rename(&tmp, path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| {
                format!("renaming {} over {}", tmp.display(), path.display())
            });
        }
        Ok(())
    }
}

/// A validated, opened `.cqa` artifact: header fields plus typed section
/// accessors. Every section CRC is verified at open, so downstream reads
/// never observe corrupt payloads.
#[derive(Debug)]
pub struct Artifact {
    map: Arc<Mmap>,
    pub version: u32,
    pub config: ModelConfig,
    pub alpha: f32,
    pub weight_bits: Bits,
    pub act_bits: Bits,
    /// Quantizer-scheme ID (`registry::SchemeId::artifact_code`). Always
    /// 0 (crossquant-static) for version-1 files, whose reserved bytes
    /// were written as zero.
    pub scheme: u16,
    sections: Vec<Section>,
}

impl Artifact {
    /// Open + validate an artifact file (memory-mapped where the platform
    /// allows; int8 panel sections are then servable in place).
    pub fn open(path: &Path) -> Result<Artifact> {
        let map = Mmap::map(path)
            .with_context(|| format!("opening artifact {}", path.display()))?;
        Self::from_mmap(Arc::new(map))
            .with_context(|| format!("loading artifact {}", path.display()))
    }

    /// Validate an in-memory artifact image (tests, pre-write checks).
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Artifact> {
        Self::from_mmap(Arc::new(Mmap::from_vec(bytes)))
    }

    fn from_mmap(map: Arc<Mmap>) -> Result<Artifact> {
        let b = map.bytes();
        ensure!(
            b.len() >= HEADER_BYTES,
            "truncated artifact: {} bytes, the fixed header needs {HEADER_BYTES}",
            b.len()
        );
        ensure!(
            b[..4] == MAGIC,
            "bad magic {:02x?} — not a .cqa artifact (expected {:02x?})",
            &b[..4],
            MAGIC
        );
        let version = u32_le(b, 4);
        ensure!(
            (MIN_VERSION..=VERSION).contains(&version),
            "unsupported artifact version {version} \
             (this build reads versions {MIN_VERSION}..={VERSION})"
        );
        ensure!(
            crc32(&b[..HEADER_BYTES - 4]) == u32_le(b, HEADER_BYTES - 4),
            "header CRC mismatch (corrupt header)"
        );
        let u = |i: usize| u32_le(b, 8 + 4 * i) as usize;
        let config = ModelConfig {
            vocab: u(0),
            d_model: u(1),
            n_layers: u(2),
            n_heads: u(3),
            d_ff: u(4),
            seq_len: u(5),
            eval_batch: u(6),
        };
        let alpha = f32::from_le_bytes([b[36], b[37], b[38], b[39]]);
        let weight_bits = bits_from_code(b[40]).context("weight bit-width field")?;
        let act_bits = bits_from_code(b[41]).context("activation bit-width field")?;
        // version-1 files reserved these bytes as zero — which is exactly
        // scheme 0 (crossquant-static), so one unconditional read serves
        // both versions
        let scheme = u16::from_le_bytes([b[42], b[43]]);
        let n = u32_le(b, 44) as usize;
        let file_len = u64_le(b, 48) as usize;
        ensure!(
            b.len() >= file_len,
            "truncated artifact: file has {} bytes, header records {file_len}",
            b.len()
        );
        ensure!(
            b.len() == file_len,
            "artifact has {} trailing bytes past the recorded length {file_len}",
            b.len() - file_len
        );
        let table_end = HEADER_BYTES + n * ENTRY_BYTES;
        ensure!(
            table_end <= b.len(),
            "truncated artifact: the {n}-entry section table needs {table_end} bytes, \
             file has {}",
            b.len()
        );
        let table = &b[HEADER_BYTES..table_end];
        ensure!(
            crc32(table) == u32_le(b, 56),
            "section table CRC mismatch (corrupt table)"
        );
        let mut sections = Vec::with_capacity(n);
        for i in 0..n {
            let e = &table[i * ENTRY_BYTES..(i + 1) * ENTRY_BYTES];
            let name_end = e[..NAME_BYTES].iter().position(|&c| c == 0).unwrap_or(NAME_BYTES);
            let name = std::str::from_utf8(&e[..name_end])
                .map_err(|_| anyhow!("section {i}: name is not UTF-8"))?
                .to_string();
            let kind = SectionKind::from_code(u32_le(e, 32))
                .with_context(|| format!("section '{name}'"))?;
            let rows = u32_le(e, 36) as usize;
            let cols = u32_le(e, 40) as usize;
            let offset = u64_le(e, 44) as usize;
            let len = u64_le(e, 52) as usize;
            let crc = u32_le(e, 60);
            // keep `expected_len`'s products far from usize overflow even
            // for adversarial table contents
            ensure!(
                rows <= (1 << 30) && cols <= (1 << 30),
                "section '{name}': implausible shape {rows}x{cols}"
            );
            ensure!(
                offset % ALIGN == 0,
                "section '{name}': payload offset {offset} is not {ALIGN}-byte aligned"
            );
            ensure!(
                offset.checked_add(len).is_some_and(|end| end <= b.len()),
                "truncated artifact: section '{name}' spans {offset}..{offset}+{len} \
                 past {} file bytes",
                b.len()
            );
            ensure!(
                len == kind.expected_len(rows, cols),
                "section '{name}': {len} bytes, its {rows}x{cols} {} shape needs {}",
                kind.label(),
                kind.expected_len(rows, cols)
            );
            ensure!(
                crc32(&b[offset..offset + len]) == crc,
                "CRC mismatch in section '{name}' (corrupt payload)"
            );
            sections.push(Section { name, kind, rows, cols, offset, len, crc });
        }
        Ok(Artifact { map, version, config, alpha, weight_bits, act_bits, scheme, sections })
    }

    /// All sections in file order.
    pub fn sections(&self) -> &[Section] {
        &self.sections
    }

    /// Total file size in bytes.
    pub fn file_bytes(&self) -> usize {
        self.map.len()
    }

    /// True when the artifact is served by a real file mapping (int8
    /// panel sections then reach the microkernel with zero copy).
    pub fn is_mapped(&self) -> bool {
        self.map.is_mapped()
    }

    /// Look a section up by name.
    pub fn section(&self, name: &str) -> Result<&Section> {
        self.sections
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("artifact has no section '{name}'"))
    }

    fn payload(&self, s: &Section) -> &[u8] {
        &self.map.bytes()[s.offset..s.offset + s.len]
    }

    /// Decode an f32 section into a flat vector.
    pub fn f32_vec(&self, name: &str) -> Result<Vec<f32>> {
        let s = self.section(name)?;
        ensure!(s.kind == SectionKind::F32, "section '{name}' is {}, not f32", s.kind.label());
        Ok(self
            .payload(s)
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decode an f32 section into a `rows × cols` matrix.
    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        let s = self.section(name)?;
        let (rows, cols) = (s.rows, s.cols);
        Ok(Matrix::from_vec(rows, cols, self.f32_vec(name)?))
    }

    /// Reconstruct a panel section: int8 panels are **borrowed in place**
    /// from the mapping (zero copy — `PackedInt8::is_mapped` holds);
    /// nibble-packed INT4 panels are decoded to an owned buffer.
    pub fn panels(&self, name: &str) -> Result<PackedInt8> {
        let s = self.section(name)?;
        match s.kind {
            SectionKind::PanelsI8 => {
                PackedInt8::from_mapped(s.rows, s.cols, self.map.clone(), s.offset)
            }
            SectionKind::PanelsI4 => {
                let codes =
                    pack::unpack_nibbles(self.payload(s), PackedInt8::layout_bytes(s.rows, s.cols));
                Ok(PackedInt8::from_raw(s.rows, s.cols, codes))
            }
            SectionKind::F32 => bail!("section '{name}' is f32, not packed panels"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            seq_len: 8,
            eval_batch: 2,
        }
    }

    fn sample() -> ArtifactWriter {
        let mut w = ArtifactWriter::new(cfg(), 0.15, Bits::Int8, Bits::Int8);
        w.add_f32("scales", 1, 3, &[1.0, 2.5, -0.5]).unwrap();
        let codes: Vec<i8> = (0..(5 * 11)).map(|v| (v % 13) as i8 - 6).collect();
        w.add_panels("w.panels", &PackedInt8::from_row_major(&codes, 5, 11)).unwrap();
        w
    }

    #[test]
    fn roundtrip_header_sections_and_payloads() {
        let w = sample();
        let bytes = w.to_bytes().unwrap();
        let art = Artifact::from_bytes(bytes).unwrap();
        assert_eq!(art.version, VERSION);
        assert_eq!(art.config, cfg());
        assert!((art.alpha - 0.15).abs() < 1e-7);
        assert_eq!(art.weight_bits, Bits::Int8);
        assert_eq!(art.sections().len(), 2);
        assert_eq!(art.f32_vec("scales").unwrap(), vec![1.0, 2.5, -0.5]);
        let p = art.panels("w.panels").unwrap();
        assert_eq!((p.k, p.n), (5, 11));
        let codes: Vec<i8> = (0..(5 * 11)).map(|v| (v % 13) as i8 - 6).collect();
        assert_eq!(p.to_row_major(), codes);
        // every payload is aligned
        for s in art.sections() {
            assert_eq!(s.offset % ALIGN, 0, "section {}", s.name);
        }
    }

    #[test]
    fn scheme_id_round_trips_through_the_header() {
        let mut w = sample();
        w.set_scheme(2);
        let art = Artifact::from_bytes(w.to_bytes().unwrap()).unwrap();
        assert_eq!(art.scheme, 2);
        // default writer stamps scheme 0
        let art = Artifact::from_bytes(sample().to_bytes().unwrap()).unwrap();
        assert_eq!(art.scheme, 0);
    }

    #[test]
    fn version_1_files_still_load_with_scheme_zero() {
        // forge a version-1 image: same layout, version stamp 1, the
        // scheme bytes reserved as zero, header CRC re-stamped
        let mut v1 = sample().to_bytes().unwrap();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        v1[42..44].copy_from_slice(&[0u8; 2]);
        let c = crc32(&v1[..HEADER_BYTES - 4]);
        v1[HEADER_BYTES - 4..HEADER_BYTES].copy_from_slice(&c.to_le_bytes());
        let art = Artifact::from_bytes(v1).unwrap();
        assert_eq!(art.version, 1);
        assert_eq!(art.scheme, 0);
        assert_eq!(art.f32_vec("scales").unwrap(), vec![1.0, 2.5, -0.5]);
    }

    #[test]
    fn int4_panels_nibble_pack_and_decode() {
        let mut w = ArtifactWriter::new(cfg(), 0.15, Bits::Int4, Bits::Int8);
        let codes: Vec<i8> = (0..(6 * 9)).map(|v| (v % 15) as i8 - 7).collect();
        let panels = PackedInt8::from_row_major(&codes, 6, 9);
        w.add_panels("w.panels", &panels).unwrap();
        let art = Artifact::from_bytes(w.to_bytes().unwrap()).unwrap();
        let s = art.section("w.panels").unwrap();
        assert_eq!(s.kind, SectionKind::PanelsI4);
        assert_eq!(s.len, PackedInt8::layout_bytes(6, 9).div_ceil(2));
        let p = art.panels("w.panels").unwrap();
        assert!(!p.is_mapped(), "nibbles decode to an owned buffer");
        assert_eq!(p.to_row_major(), codes);
    }

    #[test]
    fn duplicate_and_oversized_names_rejected() {
        let mut w = sample();
        assert!(w.add_f32("scales", 1, 1, &[0.0]).is_err());
        let long = "x".repeat(NAME_BYTES);
        assert!(w.add_f32(&long, 1, 1, &[0.0]).is_err());
    }

    #[test]
    fn distinct_structured_load_errors() {
        let good = sample().to_bytes().unwrap();

        // truncation below the header
        let e = Artifact::from_bytes(good[..10].to_vec()).unwrap_err();
        assert!(format!("{e:#}").contains("truncated"), "{e:#}");
        // truncation inside the payloads
        let e = Artifact::from_bytes(good[..good.len() - 1].to_vec()).unwrap_err();
        assert!(format!("{e:#}").contains("truncated"), "{e:#}");
        // trailing junk
        let mut long = good.clone();
        long.push(0);
        let e = Artifact::from_bytes(long).unwrap_err();
        assert!(format!("{e:#}").contains("trailing"), "{e:#}");

        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        let e = Artifact::from_bytes(bad).unwrap_err();
        assert!(format!("{e:#}").contains("magic"), "{e:#}");

        // unsupported version (header CRC re-stamped so the version check
        // is what fires)
        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&99u32.to_le_bytes());
        let c = crc32(&bad[..HEADER_BYTES - 4]);
        bad[HEADER_BYTES - 4..HEADER_BYTES].copy_from_slice(&c.to_le_bytes());
        let e = Artifact::from_bytes(bad).unwrap_err();
        assert!(format!("{e:#}").contains("version"), "{e:#}");

        // header corruption
        let mut bad = good.clone();
        bad[20] ^= 0x01;
        let e = Artifact::from_bytes(bad).unwrap_err();
        assert!(format!("{e:#}").contains("header CRC"), "{e:#}");

        // table corruption
        let mut bad = good.clone();
        bad[HEADER_BYTES + 2] ^= 0x01;
        let e = Artifact::from_bytes(bad).unwrap_err();
        assert!(format!("{e:#}").contains("table CRC"), "{e:#}");

        // payload corruption names the section
        let art = Artifact::from_bytes(good.clone()).unwrap();
        let s = art.section("w.panels").unwrap();
        let (off, name) = (s.offset, s.name.clone());
        drop(art);
        let mut bad = good;
        bad[off] ^= 0x40;
        let e = Artifact::from_bytes(bad).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("CRC mismatch") && msg.contains(&name), "{msg}");
    }
}
