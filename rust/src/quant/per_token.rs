//! Per-token quantization — the paper's activation baseline, eq. (1).
//!
//! Δ_ij = t_i / qmax with t_i = max|X_i,:|. When a token row contains an
//! outlier (20×+ the typical magnitude), t_i blows up and small elements of
//! that row round to zero — the quantization-kernel failure mode the paper
//! diagnoses (§4.1, Appendix A).

use super::{ActQuantizer, Bits, DeltaField, EPS};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct PerToken {
    pub bits: Bits,
}

impl PerToken {
    pub fn new(bits: Bits) -> Self {
        PerToken { bits }
    }
}

impl ActQuantizer for PerToken {
    fn name(&self) -> String {
        format!("per-token[{}]", self.bits)
    }

    fn delta_field(&self, x: &Matrix) -> DeltaField {
        super::debug_assert_finite(x, "PerToken");
        let qmax = self.bits.qmax();
        let t = x.row_abs_max();
        DeltaField::PerRow(t.iter().map(|&ti| ti.max(EPS) / qmax).collect())
    }

    fn qmax(&self) -> f32 {
        self.bits.qmax()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    #[test]
    fn zero_matrix_is_fixed_point() {
        let x = Matrix::zeros(4, 4);
        let q = PerToken::new(Bits::Int8).fake_quant(&x);
        assert_eq!(q.data, vec![0.0; 16]);
    }

    #[test]
    fn row_max_survives_exactly() {
        let mut rng = SplitMix64::new(1);
        let x = Matrix::randn(16, 32, 1.0, &mut rng);
        let q = PerToken::new(Bits::Int8).fake_quant(&x);
        for i in 0..x.rows {
            let t_in = x.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let t_out = q.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert!((t_in - t_out).abs() < 1e-5 * t_in.max(1.0));
        }
    }

    #[test]
    fn error_bounded_by_half_delta_outside_kernel() {
        let mut rng = SplitMix64::new(2);
        let x = Matrix::randn(32, 32, 1.0, &mut rng);
        let quant = PerToken::new(Bits::Int8);
        let field = quant.delta_field(&x);
        let q = quant.fake_quant(&x);
        for i in 0..x.rows {
            for j in 0..x.cols {
                let err = (x.get(i, j) - q.get(i, j)).abs();
                assert!(err <= 0.5 * field.delta(i, j) * 1.0001);
            }
        }
    }

    #[test]
    fn outlier_creates_large_kernel() {
        // one 50× outlier per row → many small values round to zero
        let mut rng = SplitMix64::new(3);
        let mut x = Matrix::randn(64, 128, 1.0, &mut rng);
        for i in 0..x.rows {
            x.set(i, 0, 50.0);
        }
        let q = PerToken::new(Bits::Int8).fake_quant(&x);
        let zeroed = x
            .data
            .iter()
            .zip(&q.data)
            .filter(|(&v, &qv)| v != 0.0 && qv == 0.0)
            .count();
        let frac = zeroed as f32 / x.len() as f32;
        assert!(frac > 0.1, "kernel fraction {frac}");
    }
}
