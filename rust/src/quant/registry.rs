//! The unified quantizer-scheme registry.
//!
//! One canonical name table and one static build pipeline for every
//! scheme the crate serves. The wire protocol (`coordinator::server`),
//! the CLI (`main`), and the `.cqa` artifact header all speak
//! [`SchemeId`]; everything that needs a calibrated integer model —
//! the scheduler, the continuous-batching engine, `repro quantize`,
//! the eval sweeps — goes through [`build_static_model`], which runs the
//! same four lifecycle stages for every scheme:
//!
//! ```text
//! quantize ──► calibrate ──► fold ──► serve
//!    │            │            │        │
//!    │            │            │        └ CrossQuantStatic int8 GEMM
//!    │            │            └ ĉ^(1−α) into the codes; SmoothQuant /
//!    │            │              AWQ scale migration into LN affines;
//!    │            │              GPTQ re-rounding; LoRC U·V residual
//!    │            └ observer over the 4·L+1 activation sites
//!    └ FP weights → per-column integer grids
//! ```
//!
//! Schemes differ only in which hooks they use: per-token and the
//! CrossQuant family are pure (quantize, calibrate); SmoothQuant and AWQ
//! add a pre-quantization fold of activation scale into the LayerNorm
//! affines; GPTQ replaces the nearest-rounded codes with
//! error-minimising ones ([`super::gptq`]); LoRC attaches a rank-r fp
//! correction of the rounding residual ([`super::lorc`]). The serving
//! kernel — [`super::gemm`] over [`super::qlinear`] — is identical for
//! all of them, which is what makes the registry a registry rather than
//! five pipelines.

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::awq::Awq;
use super::smoothquant::SmoothQuant;
use super::{gptq, lorc, Bits};
use crate::exp::common::{ln_site_name, site_consumers};
use crate::model::forward::CaptureSite;
use crate::model::qforward::{QuantPath, QuantizedModel};
use crate::model::quantized::apply_smoothquant;
use crate::model::weights::Weights;
use crate::model::NativeModel;
use crate::tensor::Matrix;

/// SmoothQuant migration strength for the registry's served path (the
/// synthetic model's activation statistics sit in the OPT regime).
const SMOOTH_STRENGTH: f32 = 0.5;
/// AWQ group size (paper default g128, clamped to the weight size).
const AWQ_GROUP: usize = 128;
/// Base seed for the deterministic LoRC factorization (xor'd with the
/// linear-slot index so every layer gets an independent sketch).
const LORC_SEED: u64 = 0x10C0_57A7;

/// Every scheme the crate knows, by canonical wire/CLI/artifact name.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeId {
    /// FP reference (no quantization).
    Fp,
    /// Per-token activation quantization, eq. (1) — CrossQuant at α = 1.
    PerToken,
    /// CrossQuant eq. (5), dynamic scales.
    CrossQuant,
    /// CrossQuant with the quantize-GEMM fusion.
    CrossQuantFused,
    /// CrossQuant with calibrated static column factors — the integer
    /// serving path, and the base every other static scheme folds onto.
    CrossQuantStatic,
    /// The paper's "Remove Kernel" ablation operator.
    RemoveKernel,
    /// SmoothQuant (Xiao et al. 2023): scale migration into LN affines,
    /// then per-token — served here as α = 1 static on smoothed weights.
    SmoothQuant,
    /// AWQ (Lin et al. 2024): activation-aware per-channel weight scale,
    /// folded the same way.
    Awq,
    /// CrossQuant on AWQ-scaled weights (offline eval tables only).
    CrossQuantAwq,
    /// OmniQuant stand-in (grid-searched clipping; offline eval only).
    OmniQuant,
    /// GPTQ-style error-minimising weight rounding on the static fold.
    Gptq,
    /// ZeroQuant-V2-style low-rank correction of the rounding residual.
    Lorc,
}

/// All registered schemes, in display order.
pub const ALL: [SchemeId; 12] = [
    SchemeId::Fp,
    SchemeId::PerToken,
    SchemeId::CrossQuant,
    SchemeId::CrossQuantFused,
    SchemeId::CrossQuantStatic,
    SchemeId::RemoveKernel,
    SchemeId::SmoothQuant,
    SchemeId::Awq,
    SchemeId::CrossQuantAwq,
    SchemeId::OmniQuant,
    SchemeId::Gptq,
    SchemeId::Lorc,
];

impl SchemeId {
    /// Canonical name — what the wire protocol, the CLI and the docs use.
    pub fn name(self) -> &'static str {
        match self {
            SchemeId::Fp => "fp",
            SchemeId::PerToken => "per-token",
            SchemeId::CrossQuant => "crossquant",
            SchemeId::CrossQuantFused => "crossquant-fused",
            SchemeId::CrossQuantStatic => "crossquant-static",
            SchemeId::RemoveKernel => "remove-kernel",
            SchemeId::SmoothQuant => "smoothquant",
            SchemeId::Awq => "awq",
            SchemeId::CrossQuantAwq => "cq+awq",
            SchemeId::OmniQuant => "omniquant",
            SchemeId::Gptq => "gptq",
            SchemeId::Lorc => "lorc",
        }
    }

    /// True for schemes served by the calibrated integer model (built
    /// through [`build_static_model`], persistable as a `.cqa` artifact).
    pub fn is_static(self) -> bool {
        matches!(
            self,
            SchemeId::CrossQuantStatic
                | SchemeId::SmoothQuant
                | SchemeId::Awq
                | SchemeId::Gptq
                | SchemeId::Lorc
        )
    }

    /// The u16 stamped into the `.cqa` header for a static scheme.
    /// CrossQuantStatic is 0 so version-1 artifacts (reserved-zero bytes)
    /// decode to the only scheme they could hold.
    pub fn artifact_code(self) -> u16 {
        match self {
            SchemeId::CrossQuantStatic => 0,
            SchemeId::Gptq => 1,
            SchemeId::Lorc => 2,
            SchemeId::SmoothQuant => 3,
            SchemeId::Awq => 4,
            other => panic!("{} is not an artifact scheme", other.name()),
        }
    }

    /// Inverse of [`SchemeId::artifact_code`] — structured error on an
    /// unknown code (artifact written by a newer build).
    pub fn from_artifact_code(code: u16) -> Result<SchemeId> {
        match code {
            0 => Ok(SchemeId::CrossQuantStatic),
            1 => Ok(SchemeId::Gptq),
            2 => Ok(SchemeId::Lorc),
            3 => Ok(SchemeId::SmoothQuant),
            4 => Ok(SchemeId::Awq),
            other => bail!("unknown artifact scheme code {other} (newer format?)"),
        }
    }
}

impl std::str::FromStr for SchemeId {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SchemeId> {
        // "fp16" kept as an alias: the eval tables' historical name
        if s == "fp16" {
            return Ok(SchemeId::Fp);
        }
        ALL.iter().copied().find(|id| id.name() == s).ok_or_else(|| {
            let known: Vec<&str> = ALL.iter().map(|id| id.name()).collect();
            anyhow!("unknown scheme '{s}' (known: {})", known.join(", "))
        })
    }
}

impl std::fmt::Display for SchemeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Everything that determines a calibrated static model's bits: the
/// scheme, the CrossQuant exponent of its fold, and (LoRC only) the
/// correction rank. Two requests with equal specs share one model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StaticSpec {
    pub id: SchemeId,
    pub alpha: f32,
    /// LoRC correction rank; 0 for every other scheme.
    pub rank: usize,
}

impl StaticSpec {
    pub fn new(id: SchemeId, alpha: f32, rank: usize) -> StaticSpec {
        StaticSpec { id, alpha, rank }
    }

    /// Hashable cache key (α at micro precision — well past f32's).
    pub fn cache_key(&self) -> (u16, i64, usize) {
        (self.id.artifact_code(), (self.alpha as f64 * 1e6).round() as i64, self.rank)
    }
}

/// The effective CrossQuant exponent a scheme's static fold uses:
/// SmoothQuant and AWQ are per-token methods (their migration already
/// moved the channel scale into the weights), so their fold runs at
/// α = 1, where eq. (5) degenerates to per-token.
pub fn effective_alpha(id: SchemeId, alpha: f32) -> f32 {
    match id {
        SchemeId::SmoothQuant | SchemeId::Awq | SchemeId::PerToken => 1.0,
        _ => alpha,
    }
}

/// The one static pipeline: build the calibrated integer model for any
/// static scheme. `calib` is the calibration token stream (also what the
/// observer stage replays for SmoothQuant/AWQ/GPTQ statistics). For
/// `SchemeId::CrossQuantStatic` this is *exactly* the historical
/// `QuantizedModel::new` + `calibrate_static` sequence — bit-identical
/// by construction, pinned by rust/tests/registry.rs.
pub fn build_static_model(
    weights: &Weights,
    weight_bits: Bits,
    act_bits: Bits,
    spec: &StaticSpec,
    calib: &[Vec<u32>],
) -> Result<QuantizedModel> {
    ensure!(
        spec.id.is_static(),
        "scheme '{}' has no static integer model (dynamic/offline only)",
        spec.id.name()
    );
    ensure!(
        spec.alpha.is_finite() && (0.0..=1.0).contains(&spec.alpha),
        "calibration alpha must be in [0,1], got {}",
        spec.alpha
    );
    ensure!(!calib.is_empty(), "scheme calibration needs at least one sequence");
    let alpha = effective_alpha(spec.id, spec.alpha);
    let cfg = weights.config;

    // ---- fold stage (pre-quantization): scale migration ----
    let mut w = weights.clone();
    if matches!(spec.id, SchemeId::SmoothQuant | SchemeId::Awq) {
        let acts = capture_site_activations(weights, calib)?;
        let mut folds = Vec::new();
        for site in 0..cfg.n_quant_sites() {
            if let Some(ln) = ln_site_name(cfg.n_layers, site) {
                let consumer = &site_consumers(cfg.n_layers, site)[0];
                let wm = w.get(consumer)?;
                let scales = match spec.id {
                    SchemeId::SmoothQuant => {
                        SmoothQuant::calibrate(&acts[site], &wm, SMOOTH_STRENGTH).scales
                    }
                    _ => Awq::search(&acts[site], &wm, weight_bits, AWQ_GROUP.min(wm.len())).scales,
                };
                folds.push((ln, scales));
            }
        }
        apply_smoothquant(&mut w, &folds)?;
    }

    // ---- quantize + calibrate stages (shared by every scheme) ----
    let mut qm = QuantizedModel::new(&w, weight_bits, act_bits, QuantPath::CrossQuant { alpha })?;
    qm.calibrate_static(alpha, calib)?;

    // ---- fold stage (post-quantization): code refinement ----
    match spec.id {
        SchemeId::Gptq => apply_gptq(&mut qm, &w, calib)?,
        SchemeId::Lorc => apply_lorc(&mut qm, spec.rank)?,
        _ => {}
    }
    qm.scheme_code = spec.id.artifact_code();
    Ok(qm)
}

/// Run the FP model over the calibration stream capturing the matrix
/// entering each of the 4·L+1 quantization sites (concatenated across
/// sequences) — the registry's observer stage.
fn capture_site_activations(weights: &Weights, calib: &[Vec<u32>]) -> Result<Vec<Matrix>> {
    let model = NativeModel::new(weights.clone());
    let cfg = weights.config;
    let mut cap = CaptureSite::all();
    for toks in calib {
        model.forward_nll(toks, &mut cap)?;
    }
    let n_sites = cfg.n_quant_sites();
    let mut per_site: Vec<Vec<&Matrix>> = vec![Vec::new(); n_sites];
    for (site, m) in &cap.captured {
        ensure!(*site < n_sites, "captured site {site} out of range ({n_sites} sites)");
        per_site[*site].push(m);
    }
    Ok(per_site
        .into_iter()
        .map(|mats| {
            let rows: usize = mats.iter().map(|m| m.rows).sum();
            let cols = mats.first().map(|m| m.cols).unwrap_or(0);
            let mut out = Matrix::zeros(rows, cols);
            let mut r = 0;
            for m in mats {
                out.data[r * cols..(r + m.rows) * cols].copy_from_slice(&m.data);
                r += m.rows;
            }
            out
        })
        .collect())
}

/// Replace every linear's nearest-rounded codes with GPTQ
/// error-minimising ones, on the *folded* weight W′ = diag(ĉ^(1−α))·W
/// against the *effective* calibration activations X̃ = X·diag(ĉ^(α−1))
/// — the pair the static int8 GEMM actually multiplies, so minimising
/// ‖X̃·(W′ − Q·diag(s))‖ minimises the served layer's output error.
fn apply_gptq(qm: &mut QuantizedModel, folded_weights: &Weights, calib: &[Vec<u32>]) -> Result<()> {
    let acts = capture_site_activations(folded_weights, calib)?;
    let qmax = qm.weight_bits.qmax();
    for (name, site, lin) in qm.linear_slots_mut() {
        let (cp, scale) = {
            let (_, col_pow, _, scale) = lin
                .static_parts()
                .ok_or_else(|| anyhow!("linear '{name}' has no static fold"))?;
            (col_pow.to_vec(), scale.to_vec())
        };
        let folded = {
            let w_fp = lin.fp_weight();
            Matrix::from_fn(w_fp.rows, w_fp.cols, |j, k| w_fp.get(j, k) * cp[j])
        };
        let x = &acts[site];
        ensure!(
            x.cols == cp.len(),
            "site {site} activations are {} wide, linear '{name}' takes {}",
            x.cols,
            cp.len()
        );
        let x_eff = Matrix::from_fn(x.rows, x.cols, |i, j| x.get(i, j) / cp[j]);
        let codes = gptq::round_weight(&folded, &scale, &x_eff, qmax, gptq::DEFAULT_DAMPING)
            .with_context(|| format!("gptq rounding '{name}'"))?;
        lin.set_static_codes(&codes);
    }
    Ok(())
}

/// Attach the rank-r LoRC correction to every linear: factor the
/// *effective-weight* rounding residual E = W − Q·diag(s)/diag(ĉ^(1−α))
/// (what the static GEMM's output is missing in fp space) and store
/// U·V ≈ E so serving adds x·U·V after the int8 GEMM.
fn apply_lorc(qm: &mut QuantizedModel, rank: usize) -> Result<()> {
    ensure!(rank >= 1, "lorc rank must be >= 1, got {rank}");
    for (idx, (name, _site, lin)) in qm.linear_slots_mut().into_iter().enumerate() {
        let (cp, scale, codes) = {
            let (_, col_pow, panels, scale) = lin
                .static_parts()
                .ok_or_else(|| anyhow!("linear '{name}' has no static fold"))?;
            (col_pow.to_vec(), scale.to_vec(), panels.to_row_major())
        };
        let e = {
            let w_fp = lin.fp_weight();
            let cols = w_fp.cols;
            Matrix::from_fn(w_fp.rows, cols, |j, k| {
                w_fp.get(j, k) - codes[j * cols + k] as f32 * scale[k] / cp[j]
            })
        };
        let (u, v) = lorc::factor(&e, rank, LORC_SEED ^ idx as u64);
        lin.set_lorc(u, v);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusGen;
    use crate::model::config::ModelConfig;
    use crate::model::weights::synthetic_weights;

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 20,
            eval_batch: 2,
        }
    }

    fn calib() -> Vec<Vec<u32>> {
        let mut gen = CorpusGen::new(cfg().vocab, 0x5CA1E);
        (0..4).map(|_| gen.sequence(cfg().seq_len)).collect()
    }

    fn toks() -> Vec<u32> {
        (0..20).map(|i| (i * 7) % 64).collect()
    }

    #[test]
    fn names_round_trip_for_every_scheme() {
        for id in ALL {
            assert_eq!(id.name().parse::<SchemeId>().unwrap(), id);
        }
        assert_eq!("fp16".parse::<SchemeId>().unwrap(), SchemeId::Fp);
        let e = "nope".parse::<SchemeId>().unwrap_err();
        assert!(e.to_string().contains("unknown scheme"), "{e}");
    }

    #[test]
    fn artifact_codes_round_trip() {
        for id in ALL.into_iter().filter(|id| id.is_static()) {
            assert_eq!(SchemeId::from_artifact_code(id.artifact_code()).unwrap(), id);
        }
        assert_eq!(SchemeId::CrossQuantStatic.artifact_code(), 0, "v1 compat");
        assert!(SchemeId::from_artifact_code(999).is_err());
    }

    #[test]
    fn registry_crossquant_static_is_bit_identical_to_direct_build() {
        let w = synthetic_weights(cfg(), 7);
        let spec = StaticSpec::new(SchemeId::CrossQuantStatic, 0.15, 0);
        let via_registry =
            build_static_model(&w, Bits::Int8, Bits::Int8, &spec, &calib()).unwrap();
        let mut direct =
            QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::CrossQuant { alpha: 0.15 })
                .unwrap();
        direct.calibrate_static(0.15, &calib()).unwrap();
        let a = via_registry.forward_logits(&toks()).unwrap();
        let b = direct.forward_logits(&toks()).unwrap();
        assert_eq!(a.data, b.data);
        assert_eq!(via_registry.scheme_code, 0);
    }

    #[test]
    fn every_static_scheme_builds_and_scores() {
        let w = synthetic_weights(cfg(), 7);
        for id in ALL.into_iter().filter(|id| id.is_static()) {
            let spec = StaticSpec::new(id, 0.15, 4);
            let qm = build_static_model(&w, Bits::Int8, Bits::Int8, &spec, &calib())
                .unwrap_or_else(|e| panic!("{id}: {e:#}"));
            assert_eq!(qm.scheme_code, id.artifact_code());
            let nll = qm.forward_nll(&toks()).unwrap();
            assert!(nll.iter().all(|v| v.is_finite()), "{id}");
        }
    }

    #[test]
    fn gptq_and_lorc_track_the_fp_model_at_least_as_well_as_nearest() {
        // both refinements only ever shrink the weight-rounding error, so
        // their logits should stay close to the plain static build's
        let w = synthetic_weights(cfg(), 7);
        let base = build_static_model(
            &w,
            Bits::Int4,
            Bits::Int8,
            &StaticSpec::new(SchemeId::CrossQuantStatic, 0.15, 0),
            &calib(),
        )
        .unwrap();
        let fp = NativeModel::new(w.clone());
        let fp_nll: f32 =
            fp.forward_nll(&toks(), &mut crate::model::IdentitySite).unwrap().iter().sum();
        let sum = |m: &QuantizedModel| m.forward_nll(&toks()).unwrap().iter().sum::<f32>();
        let base_gap = (sum(&base) - fp_nll).abs();
        for (id, rank) in [(SchemeId::Gptq, 0), (SchemeId::Lorc, 8)] {
            let qm = build_static_model(
                &w,
                Bits::Int4,
                Bits::Int8,
                &StaticSpec::new(id, 0.15, rank),
                &calib(),
            )
            .unwrap();
            let gap = (sum(&qm) - fp_nll).abs();
            assert!(
                gap <= base_gap * 1.5 + 0.05,
                "{id}: refined gap {gap} vs nearest-rounding gap {base_gap}"
            );
        }
    }

    #[test]
    fn non_static_schemes_are_rejected_by_the_pipeline() {
        let w = synthetic_weights(cfg(), 7);
        let e = build_static_model(
            &w,
            Bits::Int8,
            Bits::Int8,
            &StaticSpec::new(SchemeId::CrossQuant, 0.15, 0),
            &calib(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("no static integer model"), "{e}");
    }

    #[test]
    fn cache_keys_separate_schemes_alphas_and_ranks() {
        let k = |id, a, r| StaticSpec::new(id, a, r).cache_key();
        assert_ne!(k(SchemeId::Gptq, 0.15, 0), k(SchemeId::CrossQuantStatic, 0.15, 0));
        assert_ne!(
            k(SchemeId::CrossQuantStatic, 0.15, 0),
            k(SchemeId::CrossQuantStatic, 0.2, 0)
        );
        assert_ne!(k(SchemeId::Lorc, 0.15, 4), k(SchemeId::Lorc, 0.15, 8));
    }
}
