//! Per-channel and group-wise weight quantization — eq. (2) and §3.
//!
//! Weights are stored (I × O) with Y = X·W; the quantization unit is one
//! output channel (a column of W). Group-wise quantization reshapes W to
//! (I·O/g × g) row-major and quantizes per group row — the W4-g128 setting
//! used throughout the paper's second experiment group.

use super::{ActQuantizer, Bits, DeltaField, EPS};
use crate::tensor::Matrix;

/// Per-output-channel weight quantizer.
#[derive(Clone, Copy, Debug)]
pub struct PerChannel {
    pub bits: Bits,
}

impl PerChannel {
    pub fn new(bits: Bits) -> Self {
        PerChannel { bits }
    }
}

impl ActQuantizer for PerChannel {
    fn name(&self) -> String {
        format!("per-channel[{}]", self.bits)
    }

    fn delta_field(&self, w: &Matrix) -> DeltaField {
        super::debug_assert_finite(w, "PerChannel");
        let qmax = self.bits.qmax();
        DeltaField::PerCol(w.col_abs_max().iter().map(|&c| c.max(EPS) / qmax).collect())
    }

    fn qmax(&self) -> f32 {
        self.bits.qmax()
    }
}

/// Group-wise weight quantizer (group size g along the flattened weight).
#[derive(Clone, Copy, Debug)]
pub struct GroupWise {
    pub bits: Bits,
    pub group: usize,
}

impl GroupWise {
    pub fn new(bits: Bits, group: usize) -> Self {
        assert!(group > 0);
        GroupWise { bits, group }
    }

    /// W4-g128, the paper's group-wise setting.
    pub fn w4_g128() -> Self {
        GroupWise::new(Bits::Int4, 128)
    }

    /// Fake-quantize a weight matrix group-wise. Handles a trailing partial
    /// group (when I·O is not divisible by g) as its own smaller group.
    pub fn fake_quant(&self, w: &Matrix) -> Matrix {
        let qmax = self.bits.qmax();
        let mut out = w.clone();
        for chunk in out.data.chunks_mut(self.group) {
            let t = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(EPS);
            let d = t / qmax;
            for v in chunk.iter_mut() {
                *v = (*v / d).round().clamp(-qmax, qmax) * d;
            }
        }
        out
    }

    /// Per-element scale of the group containing (i, j) — used by the
    /// weight-kernel analysis in Appendix B.1.
    pub fn delta_at(&self, w: &Matrix, i: usize, j: usize) -> f32 {
        let flat = i * w.cols + j;
        let start = (flat / self.group) * self.group;
        let end = (start + self.group).min(w.len());
        let t = w.data[start..end].iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(EPS);
        t / self.bits.qmax()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    #[test]
    fn per_channel_column_max_survives() {
        let mut rng = SplitMix64::new(4);
        let w = Matrix::randn(32, 16, 0.1, &mut rng);
        let q = PerChannel::new(Bits::Int8).fake_quant(&w);
        let c_in = w.col_abs_max();
        let c_out = q.col_abs_max();
        for (a, b) in c_in.iter().zip(&c_out) {
            assert!((a - b).abs() < 1e-5 * a.max(1e-3));
        }
    }

    #[test]
    fn groupwise_smaller_groups_lower_error() {
        let mut rng = SplitMix64::new(8);
        // heavy-tailed weights: scatter a few large values
        let mut w = Matrix::randn(64, 64, 0.05, &mut rng);
        for k in 0..32 {
            let idx = rng.below(w.len());
            w.data[idx] = if k % 2 == 0 { 1.0 } else { -1.0 };
        }
        let e_g32 =
            crate::quant::relative_error(&w, &GroupWise::new(Bits::Int4, 32).fake_quant(&w));
        let e_g512 =
            crate::quant::relative_error(&w, &GroupWise::new(Bits::Int4, 512).fake_quant(&w));
        assert!(e_g32 < e_g512, "g32={e_g32} g512={e_g512}");
    }

    #[test]
    fn groupwise_partial_trailing_group() {
        let mut rng = SplitMix64::new(9);
        let w = Matrix::randn(3, 7, 1.0, &mut rng); // 21 elements, group 8 → partial
        let q = GroupWise::new(Bits::Int8, 8).fake_quant(&w);
        assert_eq!(q.len(), 21);
        for (a, b) in w.data.iter().zip(&q.data) {
            assert!((a - b).abs() <= 0.5 * 1.0 / 127.0 * 60.0); // loose sanity bound
        }
    }

    #[test]
    fn delta_at_matches_group_layout() {
        let w = Matrix::from_vec(2, 4, vec![1., 2., 4., 8., 16., 32., 64., 128.]);
        let g = GroupWise::new(Bits::Int8, 4);
        // group 0 = [1,2,4,8] → t=8 ; group 1 = [16,32,64,128] → t=128
        assert!((g.delta_at(&w, 0, 0) - 8.0 / 127.0).abs() < 1e-6);
        assert!((g.delta_at(&w, 1, 3) - 128.0 / 127.0).abs() < 1e-6);
    }
}
