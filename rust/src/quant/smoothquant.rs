//! SmoothQuant baseline (Xiao et al., 2023) — scale migration.
//!
//! Offline, per linear layer: s_j = max|X_:,j|^a / max|W_j,:|^(1−a); the
//! activation is divided column-wise by s and the compensating diag(s) is
//! folded into the weight rows, moving quantization difficulty from
//! activations to weights. Then standard per-token (activations) and
//! per-channel (weights) quantization apply.
//!
//! Migration strength a follows the paper's Appendix B.1: 0.5 for OPT-like
//! and 0.8 for LLaMA-like models.

use super::EPS;
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct SmoothQuant {
    /// Migration strength a ∈ [0, 1].
    pub strength: f32,
    /// Per-input-channel smoothing scales, computed from calibration data.
    pub scales: Vec<f32>,
}

impl SmoothQuant {
    /// Calibrate smoothing scales from a calibration activation batch and
    /// the layer weight (I × O).
    pub fn calibrate(x_calib: &Matrix, w: &Matrix, strength: f32) -> Self {
        assert_eq!(x_calib.cols, w.rows, "activation/weight channel mismatch");
        assert!((0.0..=1.0).contains(&strength));
        let act_max = x_calib.col_abs_max(); // per input channel j
        // per input channel max over the weight row j
        let w_row_max: Vec<f32> = (0..w.rows)
            .map(|j| w.row(j).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect();
        let scales = act_max
            .iter()
            .zip(&w_row_max)
            .map(|(&a, &wm)| {
                let s = a.max(EPS).powf(strength) / wm.max(EPS).powf(1.0 - strength);
                s.max(EPS)
            })
            .collect();
        SmoothQuant { strength, scales }
    }

    /// X' = X · diag(1/s): divide activation columns by the smoothing scale.
    pub fn smooth_activation(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.scales.len());
        let mut out = x.clone();
        for i in 0..out.rows {
            for (v, &s) in out.row_mut(i).iter_mut().zip(&self.scales) {
                *v /= s;
            }
        }
        out
    }

    /// W' = diag(s) · W: fold the compensation into the weight rows, so
    /// X'·W' == X·W exactly (before quantization).
    pub fn fold_into_weight(&self, w: &Matrix) -> Matrix {
        assert_eq!(w.rows, self.scales.len());
        let mut out = w.clone();
        for (j, &s) in self.scales.iter().enumerate() {
            for v in out.row_mut(j) {
                *v *= s;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{crossquant::CrossQuant, per_token::PerToken, ActQuantizer, Bits};
    use crate::tensor::SplitMix64;

    fn calib_pair(outlier_scale: f32) -> (Matrix, Matrix) {
        let mut rng = SplitMix64::new(21);
        let mut x = Matrix::randn(128, 64, 1.0, &mut rng);
        for i in 0..x.rows {
            for j in 0..3 {
                let v = x.get(i, j) * outlier_scale;
                x.set(i, j, v);
            }
        }
        let w = Matrix::randn(64, 32, 0.1, &mut rng);
        (x, w)
    }

    #[test]
    fn smoothing_is_function_preserving() {
        let (x, w) = calib_pair(30.0);
        let sq = SmoothQuant::calibrate(&x, &w, 0.5);
        let y = x.matmul(&w);
        let y2 = sq.smooth_activation(&x).matmul(&sq.fold_into_weight(&w));
        let rel = y.distance(&y2) / y.frobenius();
        assert!(rel < 1e-5, "rel {rel}");
    }

    #[test]
    fn reduces_activation_outlier_ratio() {
        let (x, w) = calib_pair(30.0);
        let sq = SmoothQuant::calibrate(&x, &w, 0.5);
        let xs = sq.smooth_activation(&x);
        let ratio = |m: &Matrix| {
            let c = m.col_abs_max();
            let max = c.iter().cloned().fold(0.0f32, f32::max);
            let med = {
                let mut v = c.clone();
                v.sort_by(f32::total_cmp);
                v[v.len() / 2]
            };
            max / med
        };
        assert!(ratio(&xs) < ratio(&x) * 0.5, "{} vs {}", ratio(&xs), ratio(&x));
    }

    #[test]
    fn improves_per_token_matmul_error_under_outliers() {
        let (x, w) = calib_pair(30.0);
        let y = x.matmul(&w);
        let quant = PerToken::new(Bits::Int8);

        // naive per-token W8A8
        let y_naive = quant.fake_quant(&x).matmul(&w);
        // smoothquant W8A8
        let sq = SmoothQuant::calibrate(&x, &w, 0.5);
        let y_sq = quant
            .fake_quant(&sq.smooth_activation(&x))
            .matmul(&sq.fold_into_weight(&w));

        let e_naive = y.distance(&y_naive) / y.frobenius();
        let e_sq = y.distance(&y_sq) / y.frobenius();
        assert!(e_sq < e_naive, "sq={e_sq} naive={e_naive}");
    }

    #[test]
    fn crossquant_competitive_without_calibration() {
        // CrossQuant needs no calibration pass yet lands in the same error
        // regime as calibrated SmoothQuant (paper Table 2 W8A8 group).
        let (x, w) = calib_pair(30.0);
        let y = x.matmul(&w);
        let sq = SmoothQuant::calibrate(&x, &w, 0.5);
        let y_sq = PerToken::new(Bits::Int8)
            .fake_quant(&sq.smooth_activation(&x))
            .matmul(&sq.fold_into_weight(&w));
        let y_cq = CrossQuant::new(0.15, Bits::Int8).fake_quant(&x).matmul(&w);
        let e_sq = y.distance(&y_sq) / y.frobenius();
        let e_cq = y.distance(&y_cq) / y.frobenius();
        assert!(e_cq < e_sq * 3.0, "cq={e_cq} sq={e_sq}");
    }
}
