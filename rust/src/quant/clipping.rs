//! OmniQuant stand-in: learnable-equivalent clipping, searched not trained.
//!
//! OmniQuant (Shao et al., 2024) learns per-layer clipping thresholds for
//! weights and activations with gradient descent. The mechanism that matters
//! for the paper's W4A4 comparison rows is the *clipped quantization range*:
//! instead of Δ = t_i/qmax the scale is Δ = γ·t_i/qmax with γ < 1, trading
//! outlier clipping error against finer resolution for the bulk. We recover
//! the same mechanism with a calibration grid search over γ (per matrix),
//! which is the standard LAC (learned-activation-clipping) approximation —
//! see DESIGN.md §7 for the fidelity note.

use super::{fake_quant_with, ActQuantizer, Bits, DeltaField, EPS};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct ClippedPerToken {
    pub bits: Bits,
    /// Clipping ratio γ ∈ (0, 1]; 1.0 is plain per-token.
    pub gamma: f32,
}

impl ClippedPerToken {
    pub fn new(bits: Bits, gamma: f32) -> Self {
        assert!(gamma > 0.0 && gamma <= 1.0);
        ClippedPerToken { bits, gamma }
    }

    /// Grid-search γ on a calibration matrix minimising ‖X − Q(X)‖_F —
    /// the OmniQuant-equivalent calibration.
    pub fn search(x_calib: &Matrix, bits: Bits) -> Self {
        let mut best = (f32::INFINITY, 1.0f32);
        for step in 1..=20 {
            let gamma = step as f32 / 20.0;
            let q = ClippedPerToken { bits, gamma }.fake_quant(x_calib);
            let err = x_calib.distance(&q);
            if err < best.0 {
                best = (err, gamma);
            }
        }
        ClippedPerToken { bits, gamma: best.1 }
    }
}

impl ActQuantizer for ClippedPerToken {
    fn name(&self) -> String {
        format!("omniquant-clip[γ={:.2},{}]", self.gamma, self.bits)
    }

    fn delta_field(&self, x: &Matrix) -> DeltaField {
        super::debug_assert_finite(x, "ClippedPerToken");
        let qmax = self.bits.qmax();
        DeltaField::PerRow(
            x.row_abs_max()
                .iter()
                .map(|&t| (self.gamma * t).max(EPS) / qmax)
                .collect(),
        )
    }

    /// Clipped fake quant: values beyond γ·t_i saturate at the grid edge.
    fn fake_quant(&self, x: &Matrix) -> Matrix {
        fake_quant_with(x, &self.delta_field(x), self.qmax())
    }

    fn qmax(&self) -> f32 {
        self.bits.qmax()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::per_token::PerToken;
    use crate::tensor::SplitMix64;

    #[test]
    fn gamma_one_equals_per_token() {
        let mut rng = SplitMix64::new(6);
        let x = Matrix::randn(32, 32, 1.0, &mut rng);
        let a = ClippedPerToken::new(Bits::Int4, 1.0).fake_quant(&x);
        let b = PerToken::new(Bits::Int4).fake_quant(&x);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn search_clips_under_outliers_at_int4() {
        let mut rng = SplitMix64::new(7);
        let mut x = Matrix::randn(64, 512, 1.0, &mut rng);
        for i in 0..x.rows {
            x.set(i, 0, 40.0); // heavy outlier per row
        }
        let clipped = ClippedPerToken::search(&x, Bits::Int4);
        assert!(clipped.gamma < 1.0, "search should clip, got γ={}", clipped.gamma);
        let e_clip = crate::quant::relative_error(&x, &clipped.fake_quant(&x));
        let e_plain =
            crate::quant::relative_error(&x, &PerToken::new(Bits::Int4).fake_quant(&x));
        assert!(e_clip < e_plain, "clip={e_clip} plain={e_plain}");
    }

    #[test]
    fn saturates_at_grid_edge() {
        let x = Matrix::from_vec(1, 4, vec![10.0, 1.0, 0.5, -10.0]);
        let q = ClippedPerToken::new(Bits::Int8, 0.1).fake_quant(&x);
        // bound = 1.0 → outliers clamp to ±1.0
        assert!((q.get(0, 0) - 1.0).abs() < 1e-5);
        assert!((q.get(0, 3) + 1.0).abs() < 1e-5);
    }
}
