//! GPTQ-style error-minimising weight rounding (Frantar et al., 2023).
//!
//! Rounds a weight matrix onto a fixed per-output-column integer grid while
//! minimising the *layer output* error ‖X·(W − Q·diag(s))‖_F instead of the
//! element-wise error naive rounding minimises. The algorithm is OBS
//! (optimal brain surgeon) applied greedily per input row: quantize row j,
//! then redistribute its rounding error onto the not-yet-quantized rows
//! through the inverse Hessian H⁻¹ = (XᵀX + λI)⁻¹.
//!
//! The rounded codes ride the existing [`crate::quant::gemm::PackedInt8`]
//! panels untouched — GPTQ changes *which* integer each weight becomes,
//! not the storage format or the serving kernel. The registry
//! ([`crate::quant::registry`]) applies it to the already-folded static
//! weight (W′ = diag(c^{1−α})·W on the grid `scale[k]`), feeding the
//! effective calibration activations X̃ = X·diag(1/c^{1−α}).

use anyhow::{ensure, Result};

use crate::tensor::Matrix;

/// Default relative diagonal damping λ/mean(diag(H)) (GPTQ's `percdamp`).
pub const DEFAULT_DAMPING: f32 = 0.01;

/// Naive nearest rounding of `w` (I × O) onto the per-column grids
/// `scale[k]`: the reference GPTQ must never be worse than.
pub fn naive_codes(w: &Matrix, scale: &[f32], qmax: f32) -> Vec<i8> {
    assert_eq!(scale.len(), w.cols);
    let mut codes = vec![0i8; w.rows * w.cols];
    for j in 0..w.rows {
        for (k, &s) in scale.iter().enumerate() {
            codes[j * w.cols + k] = (w.get(j, k) / s).round().clamp(-qmax, qmax) as i8;
        }
    }
    codes
}

/// GPTQ rounding: quantize `w` (I × O) onto the per-output-column grids
/// `scale[k]`, minimising ‖X·(W − Q·diag(scale))‖_F over the calibration
/// activations `x` (rows × I). Returns row-major I × O codes.
///
/// Deterministic (fixed iteration order, f64 accumulation). Falls back to
/// naive rounding when the Hessian carries no signal (all-zero
/// calibration) or loses positive-definiteness mid-sweep.
pub fn round_weight(
    w: &Matrix,
    scale: &[f32],
    x: &Matrix,
    qmax: f32,
    damping: f32,
) -> Result<Vec<i8>> {
    let (n, out) = (w.rows, w.cols);
    ensure!(x.cols == n, "calibration width {} does not match weight rows {n}", x.cols);
    ensure!(scale.len() == out, "scale length {} does not match weight cols {out}", scale.len());
    ensure!(qmax >= 1.0 && qmax.is_finite(), "bad qmax {qmax}");
    ensure!(damping > 0.0 && damping.is_finite(), "bad damping {damping}");
    ensure!(
        scale.iter().all(|s| s.is_finite() && *s > 0.0),
        "non-positive or non-finite grid scale"
    );
    if n == 0 || out == 0 {
        return Ok(Vec::new());
    }

    // H = XᵀX + λI in f64 (n is a model width — small; rows may be many)
    let mut h = vec![0.0f64; n * n];
    for i in 0..x.rows {
        let row = x.row(i);
        for j in 0..n {
            let vj = row[j] as f64;
            if vj == 0.0 {
                continue;
            }
            for (r, &vr) in row.iter().enumerate() {
                h[j * n + r] += vj * vr as f64;
            }
        }
    }
    let mean_diag = (0..n).map(|j| h[j * n + j]).sum::<f64>() / n as f64;
    // no calibration signal at all: the objective degenerates to the
    // element-wise one, i.e. naive rounding
    let lam = if mean_diag > 0.0 { damping as f64 * mean_diag } else { 1.0 };
    for j in 0..n {
        h[j * n + j] += lam;
    }

    let Some(mut hinv) = invert(&h, n) else {
        return Ok(naive_codes(w, scale, qmax));
    };

    let mut work: Vec<f32> = w.data.clone();
    let mut codes = vec![0i8; n * out];
    let mut err = vec![0.0f64; out];
    for j in 0..n {
        let d = hinv[j * n + j];
        if !(d.is_finite() && d > 0.0) {
            // lost positive-definiteness: finish with plain rounding
            for r in j..n {
                for (k, &s) in scale.iter().enumerate() {
                    codes[r * out + k] =
                        (work[r * out + k] / s).round().clamp(-qmax, qmax) as i8;
                }
            }
            return Ok(codes);
        }
        for (k, &s) in scale.iter().enumerate() {
            let v = work[j * out + k];
            let q = (v / s).round().clamp(-qmax, qmax);
            codes[j * out + k] = q as i8;
            err[k] = (v as f64 - q as f64 * s as f64) / d;
        }
        // redistribute the rounding error onto the remaining rows, then
        // downdate H⁻¹ (rank-1, zeroes row/col j for the rest of the sweep)
        for r in (j + 1)..n {
            let c = hinv[r * n + j];
            if c != 0.0 {
                for (k, e) in err.iter().enumerate() {
                    work[r * out + k] -= (e * c) as f32;
                }
            }
        }
        for r in (j + 1)..n {
            let cr = hinv[r * n + j] / d;
            if cr != 0.0 {
                for c2 in (j + 1)..n {
                    hinv[r * n + c2] -= cr * hinv[j * n + c2];
                }
            }
        }
    }
    Ok(codes)
}

/// Gauss-Jordan inverse with partial pivoting; `None` on a (numerically)
/// singular matrix. `m` is row-major n×n.
fn invert(m: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut a = m.to_vec();
    let mut inv = vec![0.0f64; n * n];
    for j in 0..n {
        inv[j * n + j] = 1.0;
    }
    for col in 0..n {
        // partial pivot
        let mut piv = col;
        let mut best = a[col * n + col].abs();
        for r in (col + 1)..n {
            let v = a[r * n + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if !(best.is_finite() && best > 1e-18) {
            return None;
        }
        if piv != col {
            for k in 0..n {
                a.swap(col * n + k, piv * n + k);
                inv.swap(col * n + k, piv * n + k);
            }
        }
        let p = a[col * n + col];
        for k in 0..n {
            a[col * n + k] /= p;
            inv[col * n + k] /= p;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a[r * n + col];
            if f != 0.0 {
                for k in 0..n {
                    a[r * n + k] -= f * a[col * n + k];
                    inv[r * n + k] -= f * inv[col * n + k];
                }
            }
        }
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn grid(w: &Matrix, qmax: f32) -> Vec<f32> {
        (0..w.cols)
            .map(|k| {
                let m = (0..w.rows).fold(0.0f32, |m, j| m.max(w.get(j, k).abs()));
                m.max(1e-9) / qmax
            })
            .collect()
    }

    fn recon_err(x: &Matrix, w: &Matrix, codes: &[i8], scale: &[f32]) -> f32 {
        let deq = Matrix::from_fn(w.rows, w.cols, |j, k| {
            codes[j * w.cols + k] as f32 * scale[k]
        });
        let y = x.matmul(w);
        y.distance(&x.matmul(&deq))
    }

    #[test]
    fn diagonal_hessian_matches_naive_rounding_exactly() {
        // orthogonal calibration columns ⇒ H diagonal ⇒ no error
        // propagation ⇒ GPTQ must reduce to nearest rounding bit-for-bit
        let n = 8;
        let mut x = Matrix::zeros(n, n);
        for j in 0..n {
            x.set(j, j, 1.0 + j as f32);
        }
        let mut rng = SplitMix64::new(11);
        let w = Matrix::randn(n, 5, 1.0, &mut rng);
        let scale = grid(&w, 7.0);
        let gptq = round_weight(&w, &scale, &x, 7.0, DEFAULT_DAMPING).unwrap();
        assert_eq!(gptq, naive_codes(&w, &scale, 7.0));
    }

    #[test]
    fn zero_calibration_falls_back_to_naive() {
        let x = Matrix::zeros(4, 6);
        let mut rng = SplitMix64::new(5);
        let w = Matrix::randn(6, 3, 1.0, &mut rng);
        let scale = grid(&w, 127.0);
        let gptq = round_weight(&w, &scale, &x, 127.0, DEFAULT_DAMPING).unwrap();
        assert_eq!(gptq, naive_codes(&w, &scale, 127.0));
    }

    #[test]
    fn correlated_inputs_beat_naive_rounding() {
        // strongly correlated calibration columns: exactly the regime where
        // OBS error redistribution pays off — on a coarse 3-level grid the
        // gain is large and robust
        let (rows, n, out) = (96, 12, 6);
        let mut rng = SplitMix64::new(77);
        let base = Matrix::randn(rows, 1, 1.0, &mut rng);
        let noise = Matrix::randn(rows, n, 0.3, &mut rng);
        let x = Matrix::from_fn(rows, n, |i, j| 1.5 * base.get(i, 0) + noise.get(i, j));
        let w = Matrix::randn(n, out, 0.5, &mut rng);
        let scale = grid(&w, 3.0);
        let naive = naive_codes(&w, &scale, 3.0);
        let gptq = round_weight(&w, &scale, &x, 3.0, DEFAULT_DAMPING).unwrap();
        let e_naive = recon_err(&x, &w, &naive, &scale);
        let e_gptq = recon_err(&x, &w, &gptq, &scale);
        assert!(e_gptq <= e_naive * 1.001 + 1e-6, "gptq={e_gptq} naive={e_naive}");
        assert!(gptq.iter().all(|&c| (c as f32).abs() <= 3.0));
    }

    #[test]
    fn shape_mismatches_are_structured_errors() {
        let x = Matrix::zeros(4, 5);
        let w = Matrix::zeros(6, 3);
        assert!(round_weight(&w, &[1.0; 3], &x, 7.0, 0.01).is_err());
        let x = Matrix::zeros(4, 6);
        assert!(round_weight(&w, &[1.0; 2], &x, 7.0, 0.01).is_err());
        assert!(round_weight(&w, &[0.0; 3], &x, 7.0, 0.01).is_err());
    }
}
