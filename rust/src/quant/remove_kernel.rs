//! The "Remove Kernel" ablation operator (Figures 1, 6, 7, 9).
//!
//! Sets elements with |X_ij| < θ·t_i to zero WITHOUT quantizing anything
//! else. The paper uses this to show that zeroing the quantization kernel
//! alone reproduces nearly all of A8's accuracy loss — i.e. the kernel *is*
//! the loss mechanism. θ sweeps generate the threshold curves of §4.3.

use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct RemoveKernel {
    /// Zero-bound multiplier: elements with |x| < theta · t_i are dropped.
    /// theta = 0.5/qmax reproduces exactly the per-token kernel of that
    /// bit-width (eq. 4: B_ij = 0.5 · t_i / qmax).
    pub theta: f32,
}

impl RemoveKernel {
    pub fn new(theta: f32) -> Self {
        assert!(theta >= 0.0);
        RemoveKernel { theta }
    }

    /// θ matching the per-token kernel of a given grid bound.
    pub fn matching_per_token(qmax: f32) -> Self {
        RemoveKernel { theta: 0.5 / qmax }
    }

    pub fn apply(&self, x: &Matrix) -> Matrix {
        let t = x.row_abs_max();
        let mut out = x.clone();
        for i in 0..out.rows {
            let bound = self.theta * t[i];
            for v in out.row_mut(i) {
                if v.abs() < bound {
                    *v = 0.0;
                }
            }
        }
        out
    }

    /// Fraction of (non-zero) elements that would be removed.
    pub fn removed_fraction(&self, x: &Matrix) -> f32 {
        let t = x.row_abs_max();
        let mut removed = 0usize;
        for i in 0..x.rows {
            let bound = self.theta * t[i];
            removed += x.row(i).iter().filter(|v| v.abs() < bound && **v != 0.0).count();
        }
        removed as f32 / x.len().max(1) as f32
    }

    /// Binary-search the θ that removes (approximately) a target fraction
    /// of elements — the x-axis knob of Figures 6/7.
    pub fn for_target_fraction(x: &Matrix, target: f32) -> RemoveKernel {
        let (mut lo, mut hi) = (0.0f32, 1.0f32);
        for _ in 0..40 {
            let mid = 0.5 * (lo + hi);
            if (RemoveKernel { theta: mid }).removed_fraction(x) < target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        RemoveKernel { theta: 0.5 * (lo + hi) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{per_token::PerToken, ActQuantizer, Bits};
    use crate::tensor::SplitMix64;

    #[test]
    fn theta_zero_is_identity() {
        let mut rng = SplitMix64::new(1);
        let x = Matrix::randn(16, 16, 1.0, &mut rng);
        assert_eq!(RemoveKernel::new(0.0).apply(&x), x);
    }

    #[test]
    fn matches_per_token_kernel_exactly() {
        // Removing with θ = 0.5/qmax zeroes exactly the per-token kernel set.
        let mut rng = SplitMix64::new(2);
        let x = Matrix::randn(64, 64, 1.0, &mut rng);
        let rk = RemoveKernel::matching_per_token(127.0).apply(&x);
        let q = PerToken::new(Bits::Int8).fake_quant(&x);
        for ((&orig, &removed), &quant) in x.data.iter().zip(&rk.data).zip(&q.data) {
            if orig != 0.0 {
                assert_eq!(removed == 0.0, quant == 0.0, "element {orig}");
            }
        }
    }

    #[test]
    fn target_fraction_search() {
        let mut rng = SplitMix64::new(3);
        let x = Matrix::randn(128, 128, 1.0, &mut rng);
        for target in [0.05f32, 0.2, 0.5] {
            let rk = RemoveKernel::for_target_fraction(&x, target);
            let got = rk.removed_fraction(&x);
            assert!((got - target).abs() < 0.02, "target {target} got {got}");
        }
    }

    #[test]
    fn monotone_in_theta() {
        let mut rng = SplitMix64::new(4);
        let x = Matrix::randn(64, 64, 1.0, &mut rng);
        let mut prev = -1.0f32;
        for theta in [0.0, 0.001, 0.01, 0.05, 0.2] {
            let f = RemoveKernel::new(theta).removed_fraction(&x);
            assert!(f >= prev);
            prev = f;
        }
    }
}
