//! Real integer packing: INT8 and nibble-packed INT4 storage.
//!
//! The fake-quant protocol never materialises integers, but the memory
//! accounting in README/EXPERIMENTS (and the storage claims of §4.2) are
//! backed by actual packed buffers: a quantized matrix is (packed ints,
//! scale vectors), and `unpack` reproduces the dequantized fake-quant
//! values bit-exactly.

use super::{ActQuantizer, DeltaField};
use crate::tensor::Matrix;

/// Nibble-pack INT4 codes two per byte, low nibble first; a trailing odd
/// code leaves the high nibble zero. Codes must already be on the INT4
/// grid (−7..=7) — upper bits are truncated.
pub fn pack_nibbles(ints: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(ints.len().div_ceil(2));
    for pair in ints.chunks(2) {
        let lo = (pair[0] as u8) & 0x0F;
        let hi = if pair.len() > 1 { (pair[1] as u8) & 0x0F } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack `n` sign-extended INT4 codes from nibble-packed bytes (the
/// inverse of [`pack_nibbles`]).
pub fn unpack_nibbles(bytes: &[u8], n: usize) -> Vec<i8> {
    assert!(n <= bytes.len() * 2, "asked for {n} codes from {} bytes", bytes.len());
    let mut out = Vec::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(sign_extend4(b & 0x0F));
        out.push(sign_extend4(b >> 4));
    }
    out.truncate(n);
    out
}

/// A quantized tensor in storage form.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Integer codes: one i8 per element (INT8) or two per byte (INT4).
    pub codes: Vec<u8>,
    pub int4: bool,
    /// Factored scale field (the only FP metadata — O(T+I), not O(TI)).
    pub field: DeltaField,
}

impl PackedMatrix {
    /// Quantize + pack with any scheme exposing a factored delta field.
    pub fn pack(x: &Matrix, quant: &dyn ActQuantizer) -> PackedMatrix {
        let field = quant.delta_field(x);
        let qmax = quant.qmax();
        let int4 = qmax <= 7.0;
        let n = x.rows * x.cols;
        let mut ints = Vec::with_capacity(n);
        for i in 0..x.rows {
            for j in 0..x.cols {
                let d = field.delta(i, j);
                let q = (x.get(i, j) / d).round().clamp(-qmax, qmax) as i8;
                ints.push(q);
            }
        }
        let codes = if int4 {
            pack_nibbles(&ints)
        } else {
            ints.iter().map(|&v| v as u8).collect()
        };
        PackedMatrix { rows: x.rows, cols: x.cols, codes, int4, field }
    }

    /// Dequantize back to f32 (bit-exact with the scheme's fake_quant).
    pub fn unpack(&self) -> Matrix {
        let n = self.rows * self.cols;
        let ints = if self.int4 {
            unpack_nibbles(&self.codes, n)
        } else {
            self.codes.iter().map(|&b| b as i8).collect()
        };
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(i, j, ints[i * self.cols + j] as f32 * self.field.delta(i, j));
            }
        }
        out
    }

    /// Bytes of integer payload (the compression numerator).
    pub fn payload_bytes(&self) -> usize {
        self.codes.len()
    }

    /// Bytes of scale metadata.
    pub fn metadata_bytes(&self) -> usize {
        4 * match &self.field {
            DeltaField::PerRow(r) => r.len(),
            DeltaField::PerCol(c) => c.len(),
            DeltaField::Cross { row_pow, col_pow } => row_pow.len() + col_pow.len(),
        }
    }

    /// Compression ratio vs f32 storage.
    pub fn compression_ratio(&self) -> f32 {
        let orig = 4 * self.rows * self.cols;
        orig as f32 / (self.payload_bytes() + self.metadata_bytes()) as f32
    }
}

#[inline]
fn sign_extend4(nibble: u8) -> i8 {
    ((nibble << 4) as i8) >> 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{crossquant::CrossQuant, per_token::PerToken, Bits};
    use crate::tensor::SplitMix64;

    #[test]
    fn int8_roundtrip_matches_fake_quant() {
        let mut rng = SplitMix64::new(11);
        let x = Matrix::randn(33, 45, 1.0, &mut rng);
        let q = CrossQuant::new(0.15, Bits::Int8);
        let packed = PackedMatrix::pack(&x, &q);
        let unpacked = packed.unpack();
        let fq = q.fake_quant(&x);
        for (a, b) in unpacked.data.iter().zip(&fq.data) {
            assert!((a - b).abs() < 1e-6 * a.abs().max(1e-3), "{a} vs {b}");
        }
    }

    #[test]
    fn int4_roundtrip_matches_fake_quant() {
        let mut rng = SplitMix64::new(12);
        let x = Matrix::randn(17, 9, 1.0, &mut rng); // odd element count
        let q = PerToken::new(Bits::Int4);
        let packed = PackedMatrix::pack(&x, &q);
        assert!(packed.int4);
        assert_eq!(packed.payload_bytes(), (17 * 9usize).div_ceil(2));
        let unpacked = packed.unpack();
        let fq = q.fake_quant(&x);
        for (a, b) in unpacked.data.iter().zip(&fq.data) {
            assert!((a - b).abs() < 1e-6 * a.abs().max(1e-3), "{a} vs {b}");
        }
    }

    #[test]
    fn nibble_helpers_roundtrip() {
        // every INT4 code, odd length (forces a half-filled tail byte)
        let codes: Vec<i8> = (-7..=7).collect();
        let packed = pack_nibbles(&codes);
        assert_eq!(packed.len(), codes.len().div_ceil(2));
        assert_eq!(unpack_nibbles(&packed, codes.len()), codes);
        // empty is safe
        assert!(pack_nibbles(&[]).is_empty());
        assert!(unpack_nibbles(&[], 0).is_empty());
    }

    #[test]
    fn sign_extension() {
        assert_eq!(sign_extend4(0x0F), -1);
        assert_eq!(sign_extend4(0x07), 7);
        assert_eq!(sign_extend4(0x09), -7);
        assert_eq!(sign_extend4(0x00), 0);
    }

    #[test]
    fn compression_ratios() {
        let mut rng = SplitMix64::new(13);
        let x = Matrix::randn(256, 256, 1.0, &mut rng);
        let p8 = PackedMatrix::pack(&x, &PerToken::new(Bits::Int8));
        let p4 = PackedMatrix::pack(&x, &PerToken::new(Bits::Int4));
        assert!(p8.compression_ratio() > 3.9 && p8.compression_ratio() <= 4.0);
        assert!(p4.compression_ratio() > 7.5 && p4.compression_ratio() <= 8.0);
        // crossquant costs one extra vector of metadata, still ≈4×
        let pc = PackedMatrix::pack(&x, &CrossQuant::new(0.15, Bits::Int8));
        assert!(pc.compression_ratio() > 3.8);
    }
}
