//! CrossQuant — the paper's contribution, eq. (5).
//!
//! CQ(X_ij) = round(X_ij / Δ̃_ij),  Δ̃_ij = t_i^α · c_j^(1−α) / qmax
//!
//! The scale is stored factored (row_pow[i] = t_i^α / qmax, col_pow[j] =
//! c_j^(1−α)) so the memory overhead vs per-token is exactly one extra
//! length-I vector — the paper's storage claim — and the per-element cost
//! is one extra multiply (their "one extra division" claim; same O(TI)).
//!
//! α = 1 degenerates to per-token exactly; α = 0 to per-(column)-channel.
//! The paper's default is α = 0.15 everywhere (Appendix B.1), with weight
//! mode α_W grid-searched when CrossQuant is also applied to weights.

use super::{ActQuantizer, Bits, DeltaField, EPS};
use crate::tensor::Matrix;

pub const DEFAULT_ALPHA: f32 = 0.15;

#[derive(Clone, Copy, Debug)]
pub struct CrossQuant {
    pub alpha: f32,
    pub bits: Bits,
}

impl CrossQuant {
    pub fn new(alpha: f32, bits: Bits) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "alpha must be in [0,1]");
        CrossQuant { alpha, bits }
    }

    pub fn default_int8() -> Self {
        CrossQuant::new(DEFAULT_ALPHA, Bits::Int8)
    }

    /// CrossQuant applied to a *weight* matrix (Appendix B.1: used for
    /// OPT-66B W4A4 and LLaMA3-70B W8A8 where per-channel weight kernels
    /// hurt). Identical math; separate constructor for intent.
    pub fn weight_mode(alpha_w: f32, bits: Bits) -> Self {
        CrossQuant::new(alpha_w, bits)
    }
}

/// The per-row side of eq. (5): t_i^α / qmax from row abs-maxima, with the
/// shared EPS clamp. Every consumer of the row scale — fake-quant fields,
/// the integer qlinear paths, the native executor — goes through here.
pub fn row_pow_scales(t: &[f32], alpha: f32, qmax: f32) -> Vec<f32> {
    t.iter().map(|&ti| ti.max(EPS).powf(alpha) / qmax).collect()
}

/// The per-column side of eq. (5): c_j^(1−α) from column abs-maxima, with
/// the shared EPS clamp. The single home of the column factor — shared by
/// [`cross_delta_field`], the qlinear dynamic rescale, and static-scale
/// calibration (`activations::ColStats::col_pow`), so the clamping can
/// never drift between the fake-quant and integer paths again.
pub fn col_pow_scales(c: &[f32], alpha: f32) -> Vec<f32> {
    c.iter().map(|&cj| cj.max(EPS).powf(1.0 - alpha)).collect()
}

/// The factored CrossQuant scale field Δ̃_ij = t_i^α·c_j^(1−α)/qmax for
/// arbitrary runtime (α, qmax) — shared by [`CrossQuant::delta_field`]
/// and the coordinator's native executor (whose artifacts take α/qmax as
/// runtime scalars), so eq. (5) exists in exactly one place.
pub fn cross_delta_field(x: &Matrix, alpha: f32, qmax: f32) -> DeltaField {
    DeltaField::Cross {
        row_pow: row_pow_scales(&x.row_abs_max(), alpha, qmax),
        col_pow: col_pow_scales(&x.col_abs_max(), alpha),
    }
}

impl ActQuantizer for CrossQuant {
    fn name(&self) -> String {
        format!("crossquant[α={},{}]", self.alpha, self.bits)
    }

    fn delta_field(&self, x: &Matrix) -> DeltaField {
        super::debug_assert_finite(x, "CrossQuant");
        cross_delta_field(x, self.alpha, self.bits.qmax())
    }

    fn qmax(&self) -> f32 {
        self.bits.qmax()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::per_token::PerToken;
    use crate::tensor::SplitMix64;

    fn outlier_matrix(rows: usize, cols: usize, n_out: usize, scale: f32) -> Matrix {
        let mut rng = SplitMix64::new(17);
        let mut x = Matrix::randn(rows, cols, 1.0, &mut rng);
        for j in 0..n_out {
            for i in 0..rows {
                let v = x.get(i, j) * scale;
                x.set(i, j, v);
            }
        }
        x
    }

    #[test]
    fn alpha_one_equals_per_token() {
        let mut rng = SplitMix64::new(5);
        let x = Matrix::randn(40, 30, 1.0, &mut rng);
        let cq = CrossQuant::new(1.0, Bits::Int8).fake_quant(&x);
        let pt = PerToken::new(Bits::Int8).fake_quant(&x);
        for (a, b) in cq.data.iter().zip(&pt.data) {
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn smaller_zero_bound_when_col_max_below_row_max() {
        // Paper §4.2 Case I: c_j < t_i ⇒ B̃_ij < B_ij.
        let x = outlier_matrix(64, 64, 2, 50.0);
        let cq = CrossQuant::new(0.15, Bits::Int8);
        let pt = PerToken::new(Bits::Int8);
        let fc = cq.delta_field(&x);
        let fp = pt.delta_field(&x);
        let t = x.row_abs_max();
        let c = x.col_abs_max();
        for i in 0..x.rows {
            for j in 0..x.cols {
                if c[j] < t[i] {
                    assert!(fc.zero_bound(i, j) < fp.zero_bound(i, j));
                }
            }
        }
    }

    #[test]
    fn reduces_kernel_on_outlier_matrix() {
        let x = outlier_matrix(128, 128, 2, 50.0);
        let count_zeroed = |q: &Matrix| {
            x.data.iter().zip(&q.data).filter(|(&v, &qv)| v != 0.0 && qv == 0.0).count()
        };
        let k_pt = count_zeroed(&PerToken::new(Bits::Int8).fake_quant(&x));
        let k_cq = count_zeroed(&CrossQuant::new(0.15, Bits::Int8).fake_quant(&x));
        assert!(k_cq * 4 < k_pt, "pt={k_pt} cq={k_cq}");
    }

    #[test]
    fn preserves_values_better_than_per_token() {
        let x = outlier_matrix(128, 128, 2, 50.0);
        let e_pt = crate::quant::relative_error(&x, &PerToken::new(Bits::Int8).fake_quant(&x));
        let e_cq =
            crate::quant::relative_error(&x, &CrossQuant::new(0.15, Bits::Int8).fake_quant(&x));
        assert!(e_cq < e_pt, "cq={e_cq} pt={e_pt}");
    }

    #[test]
    #[should_panic]
    fn rejects_invalid_alpha() {
        CrossQuant::new(1.5, Bits::Int8);
    }
}
