//! True-integer quantized linear layers — the deployment path the paper
//! motivates (§3: "quantizing activations … accelerates inference").
//!
//! Everything else in this crate follows the paper's *fake-quant*
//! evaluation protocol; this module is the real thing: weights stored as
//! INT8/INT4 codes (nibble-packed for INT4 — see [`super::pack`]),
//! activations quantized to integer codes at run time, and the matmul
//! running through the packed-panel microkernel in [`super::gemm`].
//!
//! Three activation schemes:
//!
//! * **per-token** — the classic W8A8 GEMM: the scale t_i/qmax is constant
//!   along the contraction axis, so y_ij = (t_i/q)·s_j · Σ_k xq_ik·wq_kj
//!   is one int8×int8→i32 GEMM plus a rank-1 dequant.
//! * **CrossQuant, [`ScaleMode::Dynamic`]** — the scale t_i^α·c_k^(1−α)
//!   varies along the contraction axis, so it cannot be pulled out of an
//!   integer accumulation. The honest dynamic path folds c_k^(1−α) into
//!   the weight *per activation batch* (c changes with the batch): the
//!   matmul stays int8×int8→i32, but every batch pays an O(I·O)
//!   weight-rescale pass — the engineering cost the paper's complexity
//!   discussion (§4.2) abstracts away.
//! * **CrossQuant, [`ScaleMode::Static`]** — the deployment fix: estimate
//!   ĉ_k^(1−α) from *calibration* activations (ZeroQuant-V2/LRQ-style
//!   static scales), fold it into the weight codes **once at model
//!   build**, and serve with zero per-batch rescale. Deployed cost is
//!   identical to per-token W8A8 plus one multiply per activation element
//!   — exactly the paper's "one extra multiply" claim, made true.
//!
//! Both costs are quantified in `rust/benches/quant_hot_path.rs`
//! (`BENCH_qlinear_gemm.json`).

use anyhow::Result;

use super::gemm::{self, PackedInt8};
use super::{crossquant, pack, Bits, EPS};
use crate::tensor::{par, Matrix};

/// How the CrossQuant column factor c^(1−α) is sourced at inference.
#[derive(Clone, Debug)]
pub enum ScaleMode {
    /// Per-batch column maxima from the live activation: most faithful,
    /// but every batch pays the O(I·O) weight-rescale pass.
    Dynamic,
    /// Calibration-derived column factors ĉ^(1−α), one per input column
    /// (see `activations::ColStats::col_pow`), folded into the weight
    /// codes once at build: zero per-batch rescale. `alpha` is the α the
    /// factors were computed for — carried together so the activation
    /// side can never run a different α than the fold.
    Static { alpha: f32, col_pow: Vec<f32> },
}

/// The build-time product of [`ScaleMode::Static`]: weight panels with
/// ĉ^(1−α) pre-folded, plus the calibrated activation-side factors.
#[derive(Clone, Debug)]
struct StaticFold {
    alpha: f32,
    col_pow: Vec<f32>,
    panels: PackedInt8,
    scale: Vec<f32>,
}

/// A linear layer with per-output-channel integer weights.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub in_dim: usize,
    pub out_dim: usize,
    pub bits: Bits,
    /// Packed-panel compute representation of the codes (see `gemm`) —
    /// the single copy of the integer codes for byte-wide grids.
    panels: PackedInt8,
    /// Nibble-packed storage payload, present only for INT4 (the one
    /// width where the shipped bytes differ from one-byte-per-code).
    nibble_payload: Option<Vec<u8>>,
    /// Per-output-channel scale: w ≈ code · w_scale[j].
    w_scale: Vec<f32>,
    /// FP copy of the weight for the dynamic CrossQuant rescale path.
    w_fp: Matrix,
    /// Present iff `ScaleMode::Static` is installed.
    static_fold: Option<StaticFold>,
    /// LoRC rank-r correction (U: I×r, V: r×O) of the weight-quantization
    /// residual, added in fp after the int8 GEMM (see
    /// [`crate::quant::lorc`]). `None` for every non-LoRC scheme.
    lorc: Option<(Matrix, Matrix)>,
}

/// Integer activation codes + their factored scales.
pub struct QuantizedActivation {
    pub rows: usize,
    pub cols: usize,
    pub codes: Vec<i8>,
    /// Per-row dequant factor (t_i/q for per-token, t_i^α/q for CrossQuant).
    pub row_scale: Vec<f32>,
}

/// The integer paths materialise codes as i8; widths above 8 bits would
/// silently saturate at ±127, so they are rejected loudly (the fake-quant
/// protocol still supports them — it never stores integers).
fn i8_qmax(bits: Bits) -> f32 {
    let q = bits.qmax();
    assert!(q <= 127.0, "{bits}: the integer linear path stores i8 codes (max 8 bits)");
    q
}

impl QuantizedLinear {
    /// Quantize a weight matrix (I × O) per output channel.
    pub fn from_weight(w: &Matrix, bits: Bits) -> QuantizedLinear {
        let qmax = i8_qmax(bits);
        let w_scale: Vec<f32> = w.col_abs_max().iter().map(|&c| c.max(EPS) / qmax).collect();
        let mut codes = Vec::with_capacity(w.len());
        for i in 0..w.rows {
            for (j, &v) in w.row(i).iter().enumerate() {
                codes.push((v / w_scale[j]).round().clamp(-qmax, qmax) as i8);
            }
        }
        let panels = PackedInt8::from_row_major(&codes, w.rows, w.cols);
        let nibble_payload = match bits {
            Bits::Int4 => Some(pack::pack_nibbles(&codes)),
            _ => None,
        };
        QuantizedLinear {
            in_dim: w.rows,
            out_dim: w.cols,
            bits,
            panels,
            nibble_payload,
            w_scale,
            w_fp: w.clone(),
            static_fold: None,
            lorc: None,
        }
    }

    /// Rebuild a layer from persisted `.cqa` artifact parts: folded
    /// panels (possibly borrowed straight from a file mapping — see
    /// `PackedInt8::from_mapped`), folded per-output scales, and the
    /// activation-side column factors. The layer carries **no** FP weight
    /// and no dynamic panel grid: only
    /// [`QuantizedLinear::forward_crossquant_static`] is servable, which
    /// is exactly what the artifact deployment path runs.
    pub fn from_static_parts(
        bits: Bits,
        alpha: f32,
        col_pow: Vec<f32>,
        panels: PackedInt8,
        scale: Vec<f32>,
    ) -> Result<QuantizedLinear> {
        anyhow::ensure!(
            bits.qmax() <= 127.0,
            "{bits}: the integer linear path stores i8 codes (max 8 bits)"
        );
        anyhow::ensure!(
            alpha.is_finite() && (0.0..=1.0).contains(&alpha),
            "alpha {alpha} out of range (corrupt artifact?)"
        );
        let (in_dim, out_dim) = (panels.k, panels.n);
        anyhow::ensure!(
            col_pow.len() == in_dim,
            "col_pow holds {} factors, panels expect in_dim {in_dim}",
            col_pow.len()
        );
        anyhow::ensure!(
            scale.len() == out_dim,
            "scale holds {} factors, panels expect out_dim {out_dim}",
            scale.len()
        );
        anyhow::ensure!(
            col_pow.iter().chain(scale.iter()).all(|v| v.is_finite()),
            "non-finite scale factors (corrupt artifact?)"
        );
        Ok(QuantizedLinear {
            in_dim,
            out_dim,
            bits,
            panels: PackedInt8::from_raw(0, 0, Vec::new()),
            nibble_payload: None,
            w_scale: Vec::new(),
            w_fp: Matrix::zeros(0, 0),
            static_fold: Some(StaticFold { alpha, col_pow, panels, scale }),
            lorc: None,
        })
    }

    /// Install a LoRC correction pair (U: I×r, V: r×O); applied by
    /// [`QuantizedLinear::forward_crossquant_static`] after the int8 GEMM.
    pub(crate) fn set_lorc(&mut self, u: Matrix, v: Matrix) {
        assert_eq!(u.rows, self.in_dim, "LoRC U rows must match in_dim");
        assert_eq!(v.cols, self.out_dim, "LoRC V cols must match out_dim");
        assert_eq!(u.cols, v.rows, "LoRC U/V rank mismatch");
        self.lorc = Some((u, v));
    }

    /// The installed LoRC correction, if any (artifact serialization).
    pub(crate) fn lorc(&self) -> Option<&(Matrix, Matrix)> {
        self.lorc.as_ref()
    }

    /// The FP weight (I × O) — available only on builder-constructed
    /// layers, used by the registry's GPTQ/LoRC build passes.
    pub(crate) fn fp_weight(&self) -> &Matrix {
        assert!(self.has_fp(), "artifact-loaded layer: the FP weight was never shipped");
        &self.w_fp
    }

    /// Replace the static fold's weight codes in place (row-major I × O),
    /// keeping the fold's grid (`scale`) and activation factors — the hook
    /// GPTQ re-rounding rides: same panels format, same serving kernel,
    /// different integers.
    pub(crate) fn set_static_codes(&mut self, codes: &[i8]) {
        let fold = self
            .static_fold
            .as_mut()
            .expect("set_static_codes requires an installed static fold");
        assert_eq!(codes.len(), self.in_dim * self.out_dim, "code buffer shape mismatch");
        fold.panels = PackedInt8::from_row_major(codes, self.in_dim, self.out_dim);
    }

    /// The installed static fold, exported for artifact serialization:
    /// (α, activation-side column factors, folded panels, folded
    /// per-output scales).
    pub(crate) fn static_parts(&self) -> Option<(f32, &[f32], &PackedInt8, &[f32])> {
        self.static_fold
            .as_ref()
            .map(|f| (f.alpha, f.col_pow.as_slice(), &f.panels, f.scale.as_slice()))
    }

    /// False for artifact-loaded layers: the FP weight (and with it every
    /// dynamic/per-token path) was deliberately never shipped.
    fn has_fp(&self) -> bool {
        !self.w_fp.is_empty()
    }

    /// Integer payload bytes: the nibble-packed buffer actually stored
    /// for INT4, one byte per code otherwise (panel padding excluded —
    /// it is compute layout, not payload).
    pub fn payload_bytes(&self) -> usize {
        match self.bits {
            Bits::Int4 => (self.in_dim * self.out_dim).div_ceil(2),
            _ => self.in_dim * self.out_dim,
        }
    }

    /// Row-major codes decoded from storage (the pack/unpack round-trip
    /// surface; INT4 goes through `pack::unpack_nibbles`, byte-wide
    /// grids decode from the panel layout).
    pub fn stored_codes(&self) -> Vec<i8> {
        assert!(self.has_fp(), "artifact-loaded layer: base weight codes were never shipped");
        match &self.nibble_payload {
            Some(p) => pack::unpack_nibbles(p, self.in_dim * self.out_dim),
            None => self.panels.to_row_major(),
        }
    }

    /// Per-output-channel dequantization scales.
    pub fn w_scales(&self) -> &[f32] {
        &self.w_scale
    }

    /// Install a scale mode. `Static` folds the calibrated ĉ^(1−α) into
    /// the weight codes once (the build-time pass); `Dynamic` drops any
    /// fold and returns to per-batch rescaling.
    pub fn set_scale_mode(&mut self, mode: ScaleMode) {
        assert!(
            self.has_fp(),
            "artifact-loaded layer: the shipped static fold is the only scale mode"
        );
        match mode {
            ScaleMode::Dynamic => self.static_fold = None,
            ScaleMode::Static { alpha, col_pow } => {
                assert_eq!(col_pow.len(), self.in_dim, "static profile must match in_dim");
                // a NaN factor would zero whole weight rows through the
                // fold's saturating cast — fail loudly instead (the
                // crate-wide NaN policy); O(I) check on a cold path
                assert!(
                    col_pow.iter().all(|v| v.is_finite()),
                    "static profile contains non-finite factors (corrupt calibration)"
                );
                let (panels, scale) = self.fold_weight(&col_pow);
                self.static_fold = Some(StaticFold { alpha, col_pow, panels, scale });
            }
        }
    }

    /// The currently installed scale mode.
    pub fn scale_mode(&self) -> ScaleMode {
        match &self.static_fold {
            Some(f) => ScaleMode::Static { alpha: f.alpha, col_pow: f.col_pow.clone() },
            None => ScaleMode::Dynamic,
        }
    }

    /// Per-token quantize an activation to integer codes.
    pub fn quantize_per_token(x: &Matrix, bits: Bits) -> QuantizedActivation {
        let qmax = i8_qmax(bits);
        let t = x.row_abs_max();
        let row_scale: Vec<f32> = t.iter().map(|&ti| ti.max(EPS) / qmax).collect();
        let mut codes = Vec::with_capacity(x.len());
        for i in 0..x.rows {
            let inv = 1.0 / row_scale[i];
            for &v in x.row(i) {
                codes.push((v * inv).round().clamp(-qmax, qmax) as i8);
            }
        }
        QuantizedActivation { rows: x.rows, cols: x.cols, codes, row_scale }
    }

    /// CrossQuant-quantize an activation: per-element scale
    /// t_i^α·c_j^(1−α)/q, codes on the integer grid; returns the codes,
    /// the per-row factor t_i^α/q, and the per-column factor c_j^(1−α)
    /// the weight side must fold. Both factors come from the shared
    /// eq. (5) helpers in [`super::crossquant`].
    pub fn quantize_crossquant(
        x: &Matrix,
        alpha: f32,
        bits: Bits,
    ) -> (QuantizedActivation, Vec<f32>) {
        let qmax = i8_qmax(bits);
        let row_scale = crossquant::row_pow_scales(&x.row_abs_max(), alpha, qmax);
        let col_pow = crossquant::col_pow_scales(&x.col_abs_max(), alpha);
        let codes = Self::cross_codes(x, &row_scale, &col_pow, qmax);
        (QuantizedActivation { rows: x.rows, cols: x.cols, codes, row_scale }, col_pow)
    }

    /// Emit CrossQuant codes for given factored scales (shared by the
    /// dynamic and static activation paths — one code loop, not two).
    fn cross_codes(x: &Matrix, row_scale: &[f32], col_pow: &[f32], qmax: f32) -> Vec<i8> {
        let mut codes = Vec::with_capacity(x.len());
        for i in 0..x.rows {
            let rp = row_scale[i];
            for (j, &v) in x.row(i).iter().enumerate() {
                let d = rp * col_pow[j];
                codes.push((v / d).round().clamp(-qmax, qmax) as i8);
            }
        }
        codes
    }

    /// The W8A8 GEMM: int8×int8 → i32 accumulate, rank-1 dequant.
    pub fn forward_per_token(&self, x: &Matrix, act_bits: Bits) -> Matrix {
        assert!(
            self.has_fp(),
            "artifact-loaded layer: only forward_crossquant_static is servable"
        );
        let act = Self::quantize_per_token(x, act_bits);
        self.gemm(&act, &self.panels, &self.w_scale)
    }

    /// The dynamic CrossQuant integer path: requantize + repack the weight
    /// with the live batch's c^(1−α) folded in, then the packed GEMM.
    pub fn forward_crossquant(&self, x: &Matrix, alpha: f32, act_bits: Bits) -> Matrix {
        assert!(
            self.has_fp(),
            "artifact-loaded layer: only forward_crossquant_static is servable"
        );
        let (act, col_pow) = Self::quantize_crossquant(x, alpha, act_bits);
        let (folded, folded_scale) = self.fold_weight(&col_pow);
        self.gemm(&act, &folded, &folded_scale)
    }

    /// The static CrossQuant integer path: activation codes use the
    /// calibrated ĉ^(1−α) (row maxima stay per-token dynamic — an O(T·I)
    /// scan), weights are pre-folded — **no** per-batch weight pass.
    ///
    /// Panics if [`QuantizedLinear::set_scale_mode`] has not installed
    /// `ScaleMode::Static`.
    pub fn forward_crossquant_static(&self, x: &Matrix, act_bits: Bits) -> Matrix {
        let fold = self
            .static_fold
            .as_ref()
            .expect("forward_crossquant_static requires ScaleMode::Static");
        let qmax = i8_qmax(act_bits);
        let row_scale = crossquant::row_pow_scales(&x.row_abs_max(), fold.alpha, qmax);
        let codes = Self::cross_codes(x, &row_scale, &fold.col_pow, qmax);
        let act = QuantizedActivation { rows: x.rows, cols: x.cols, codes, row_scale };
        let mut y = self.gemm(&act, &fold.panels, &fold.scale);
        // LoRC: two skinny fp matmuls recover the rounding residual —
        // row-independent, so the batched engine step stays bit-identical
        // to sequential decode
        if let Some((u, v)) = &self.lorc {
            let corr = x.matmul(u).matmul(v);
            for (o, c) in y.data.iter_mut().zip(&corr.data) {
                *o += c;
            }
        }
        y
    }

    /// FP reference product (unquantized weight).
    pub fn forward_fp(&self, x: &Matrix) -> Matrix {
        assert!(self.has_fp(), "artifact-loaded layer: the FP weight was never shipped");
        x.matmul(&self.w_fp)
    }

    /// Fold c_k^(1−α) into the FP weight rows and requantize per output
    /// channel, packing straight into the panel layout — the per-batch
    /// O(I·O) pass of the dynamic path, and the one-time build pass of
    /// the static path. Two row-parallel sweeps: a per-output max
    /// reduction, then a fused quantize+pack.
    fn fold_weight(&self, col_pow: &[f32]) -> (PackedInt8, Vec<f32>) {
        let qmax = self.bits.qmax();
        let n = self.out_dim;
        let workers = par::workers_for(self.in_dim, self.w_fp.len());
        let partial_max = par::par_map_rows(self.in_dim, workers, |range| {
            let mut m = vec![0.0f32; n];
            for kk in range {
                let cp = col_pow[kk];
                for (mj, &v) in m.iter_mut().zip(self.w_fp.row(kk)) {
                    let a = (v * cp).abs();
                    if a > *mj {
                        *mj = a;
                    }
                }
            }
            m
        });
        let mut folded_scale = vec![0.0f32; n];
        for pm in &partial_max {
            for (s, &a) in folded_scale.iter_mut().zip(pm) {
                if a > *s {
                    *s = a;
                }
            }
        }
        for s in folded_scale.iter_mut() {
            *s = s.max(EPS) / qmax;
        }
        let pack_workers = par::workers_for(n.div_ceil(gemm::NR), self.w_fp.len());
        let folded = PackedInt8::pack_with(self.in_dim, n, pack_workers, |kk, j| {
            let v = self.w_fp.get(kk, j) * col_pow[kk] / folded_scale[j];
            v.round().clamp(-qmax, qmax) as i8
        });
        (folded, folded_scale)
    }

    /// Dispatch into the packed-panel GEMM (see [`super::gemm`]); the
    /// serial and parallel paths share the microkernel. Workers are sized
    /// by the full 2-D tile count (row groups × column panels), not row
    /// count — a small-M decode step against a wide weight still fans out
    /// across N-panels (`par::tile_grid`).
    fn gemm(&self, act: &QuantizedActivation, w: &PackedInt8, w_scale: &[f32]) -> Matrix {
        assert_eq!(act.cols, self.in_dim, "activation/weight shape mismatch");
        let cost = act.rows.saturating_mul(self.in_dim).saturating_mul(self.out_dim);
        let tiles = act.rows.div_ceil(gemm::MR).saturating_mul(w.n_panels());
        let workers = par::workers_for(tiles, cost);
        gemm::gemm_dequant(&act.codes, act.rows, w, &act.row_scale, w_scale, workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn pair(outlier: bool) -> (Matrix, Matrix) {
        let mut rng = SplitMix64::new(51);
        let mut x = Matrix::randn(96, 64, 1.0, &mut rng);
        if outlier {
            for i in 0..x.rows {
                let v = x.get(i, 3) * 50.0;
                x.set(i, 3, v);
            }
        }
        let w = Matrix::randn(64, 48, 0.1, &mut rng);
        (x, w)
    }

    #[test]
    fn per_token_int8_close_to_fp() {
        let (x, w) = pair(false);
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let y = lin.forward_per_token(&x, Bits::Int8);
        let fp = lin.forward_fp(&x);
        let rel = y.distance(&fp) / fp.frobenius();
        assert!(rel < 0.02, "rel {rel}");
    }

    #[test]
    fn crossquant_int8_beats_per_token_under_outliers() {
        let (x, w) = pair(true);
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let fp = lin.forward_fp(&x);
        let e_pt = lin.forward_per_token(&x, Bits::Int8).distance(&fp) / fp.frobenius();
        let e_cq = lin.forward_crossquant(&x, 0.15, Bits::Int8).distance(&fp) / fp.frobenius();
        assert!(e_cq < e_pt, "cq {e_cq} pt {e_pt}");
        assert!(e_cq < 0.05, "cq {e_cq}");
    }

    #[test]
    fn alpha_one_matches_per_token_path() {
        let (x, w) = pair(true);
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let a = lin.forward_crossquant(&x, 1.0, Bits::Int8);
        let b = lin.forward_per_token(&x, Bits::Int8);
        // α=1 ⇒ col_pow = 1 ⇒ folded weight == original weight grid
        let rel = a.distance(&b) / b.frobenius().max(1e-6);
        assert!(rel < 1e-3, "rel {rel}");
    }

    #[test]
    fn integer_path_matches_fake_quant_semantics() {
        // integer GEMM with per-token codes == fake-quant(x) @ fake-quant(w)
        let (x, w) = pair(false);
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let y_int = lin.forward_per_token(&x, Bits::Int8);
        use crate::quant::{per_channel::PerChannel, per_token::PerToken, ActQuantizer};
        let y_fake = PerToken::new(Bits::Int8)
            .fake_quant(&x)
            .matmul(&PerChannel::new(Bits::Int8).fake_quant(&w));
        let rel = y_int.distance(&y_fake) / y_fake.frobenius();
        assert!(rel < 1e-4, "integer vs fake-quant rel {rel}");
    }

    #[test]
    fn static_fold_with_batch_stats_matches_dynamic_exactly() {
        // ScaleMode::Static with the *live batch's* column stats produces
        // identical codes and an identical fold — outputs must be
        // bit-exact with the dynamic path.
        let (x, w) = pair(true);
        let mut lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let dynamic = lin.forward_crossquant(&x, 0.15, Bits::Int8);
        let cp = crossquant::col_pow_scales(&x.col_abs_max(), 0.15);
        lin.set_scale_mode(ScaleMode::Static { alpha: 0.15, col_pow: cp });
        assert!(matches!(lin.scale_mode(), ScaleMode::Static { .. }));
        let st = lin.forward_crossquant_static(&x, Bits::Int8);
        assert_eq!(st.data, dynamic.data);
        // and Dynamic mode clears the fold again
        lin.set_scale_mode(ScaleMode::Dynamic);
        assert!(matches!(lin.scale_mode(), ScaleMode::Dynamic));
    }

    #[test]
    fn static_fold_tolerates_shifted_calibration_stats() {
        // calibration stats from a *different* batch of the same
        // distribution: not bit-exact, but still close to FP
        let mut rng = SplitMix64::new(77);
        let x_calib = Matrix::randn(96, 64, 1.0, &mut rng);
        let (x, w) = pair(false);
        let mut lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let cp = crossquant::col_pow_scales(&x_calib.col_abs_max(), 0.15);
        lin.set_scale_mode(ScaleMode::Static { alpha: 0.15, col_pow: cp });
        let st = lin.forward_crossquant_static(&x, Bits::Int8);
        let fp = lin.forward_fp(&x);
        let rel = st.distance(&fp) / fp.frobenius();
        assert!(rel < 0.05, "static rel {rel}");
    }

    #[test]
    fn int4_payload_is_half() {
        let (_, w) = pair(false);
        let l8 = QuantizedLinear::from_weight(&w, Bits::Int8);
        let l4 = QuantizedLinear::from_weight(&w, Bits::Int4);
        assert_eq!(l8.payload_bytes(), 64 * 48);
        assert_eq!(l4.payload_bytes(), (64 * 48usize).div_ceil(2));
    }

    #[test]
    fn stored_codes_roundtrip_for_all_widths() {
        let (_, w) = pair(false);
        for bits in [Bits::Int8, Bits::Int4, Bits::Other(6)] {
            let lin = QuantizedLinear::from_weight(&w, bits);
            let qmax = bits.qmax();
            let decoded = lin.stored_codes();
            assert_eq!(decoded.len(), 64 * 48);
            // decoded payload must reproduce the quantization of w exactly
            let mut scale_ok = true;
            for i in 0..w.rows {
                for (j, &v) in w.row(i).iter().enumerate() {
                    let expect = (v / lin.w_scales()[j]).round().clamp(-qmax, qmax) as i8;
                    if decoded[i * w.cols + j] != expect {
                        scale_ok = false;
                    }
                }
            }
            assert!(scale_ok, "payload mismatch for {bits}");
        }
    }

    fn static_lin(x: &Matrix, w: &Matrix) -> QuantizedLinear {
        let mut lin = QuantizedLinear::from_weight(w, Bits::Int8);
        let cp = crossquant::col_pow_scales(&x.col_abs_max(), 0.15);
        lin.set_scale_mode(ScaleMode::Static { alpha: 0.15, col_pow: cp });
        lin
    }

    #[test]
    fn artifact_parts_roundtrip_is_bit_exact() {
        // export the static fold, rebuild a weight-free layer from the
        // parts, and demand bit-identical outputs — the layer-level core
        // of the .cqa round-trip guarantee
        let (x, w) = pair(true);
        let lin = static_lin(&x, &w);
        let want = lin.forward_crossquant_static(&x, Bits::Int8);
        let (alpha, col_pow, panels, scale) = lin.static_parts().expect("fold installed");
        let rebuilt = QuantizedLinear::from_static_parts(
            Bits::Int8,
            alpha,
            col_pow.to_vec(),
            panels.clone(),
            scale.to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt.forward_crossquant_static(&x, Bits::Int8).data, want.data);
        assert_eq!((rebuilt.in_dim, rebuilt.out_dim), (lin.in_dim, lin.out_dim));
    }

    #[test]
    fn from_static_parts_validates_inputs() {
        let (x, w) = pair(false);
        let lin = static_lin(&x, &w);
        let (alpha, col_pow, panels, scale) = lin.static_parts().unwrap();
        let bad_cp = col_pow[..col_pow.len() - 1].to_vec();
        assert!(QuantizedLinear::from_static_parts(
            Bits::Int8,
            alpha,
            bad_cp,
            panels.clone(),
            scale.to_vec()
        )
        .is_err());
        let mut nan_scale = scale.to_vec();
        nan_scale[0] = f32::NAN;
        assert!(QuantizedLinear::from_static_parts(
            Bits::Int8,
            alpha,
            col_pow.to_vec(),
            panels.clone(),
            nan_scale
        )
        .is_err());
        assert!(QuantizedLinear::from_static_parts(
            Bits::Int8,
            2.0,
            col_pow.to_vec(),
            panels.clone(),
            scale.to_vec()
        )
        .is_err());
    }

    #[test]
    fn lorc_correction_recovers_int4_weight_error() {
        // INT4 weights: rounding error dominates. A (near-)full-rank LoRC
        // pair built from the exact effective-weight residual must recover
        // almost all of it, leaving only the activation-quantization error.
        let (x, w) = pair(true);
        let mut lin = QuantizedLinear::from_weight(&w, Bits::Int4);
        let cp = crossquant::col_pow_scales(&x.col_abs_max(), 0.15);
        lin.set_scale_mode(ScaleMode::Static { alpha: 0.15, col_pow: cp });
        let fp = lin.forward_fp(&x);
        let base = lin.forward_crossquant_static(&x, Bits::Int8).distance(&fp);
        let e = {
            let (_, col_pow, panels, scale) = lin.static_parts().unwrap();
            let codes = panels.to_row_major();
            Matrix::from_fn(w.rows, w.cols, |j, k| {
                w.get(j, k) - codes[j * w.cols + k] as f32 * scale[k] / col_pow[j]
            })
        };
        let (u, v) = crate::quant::lorc::factor(&e, w.cols, 1);
        lin.set_lorc(u, v);
        let corr = lin.forward_crossquant_static(&x, Bits::Int8).distance(&fp);
        assert!(corr < base * 0.5, "corrected {corr} vs base {base}");
    }

    #[test]
    fn gptq_codes_ride_the_static_fold() {
        // replacing the fold's codes with GPTQ-rounded ones keeps the
        // serving kernel identical and must not hurt the output error
        let (x, w) = pair(true);
        let mut lin = static_lin(&x, &w);
        let fp = lin.forward_fp(&x);
        let base = lin.forward_crossquant_static(&x, Bits::Int8).distance(&fp);
        let codes = {
            let (_, col_pow, _, scale) = lin.static_parts().unwrap();
            let folded =
                Matrix::from_fn(w.rows, w.cols, |j, k| w.get(j, k) * col_pow[j]);
            let x_eff = Matrix::from_fn(x.rows, x.cols, |i, j| x.get(i, j) / col_pow[j]);
            crate::quant::gptq::round_weight(
                &folded,
                scale,
                &x_eff,
                Bits::Int8.qmax(),
                crate::quant::gptq::DEFAULT_DAMPING,
            )
            .unwrap()
        };
        lin.set_static_codes(&codes);
        let gptq = lin.forward_crossquant_static(&x, Bits::Int8).distance(&fp);
        assert!(gptq <= base * 1.05, "gptq {gptq} vs base {base}");
    }

    #[test]
    #[should_panic(expected = "artifact-loaded layer")]
    fn artifact_layer_rejects_dynamic_paths() {
        let (x, w) = pair(false);
        let lin = static_lin(&x, &w);
        let (alpha, col_pow, panels, scale) = lin.static_parts().unwrap();
        let rebuilt = QuantizedLinear::from_static_parts(
            Bits::Int8,
            alpha,
            col_pow.to_vec(),
            panels.clone(),
            scale.to_vec(),
        )
        .unwrap();
        let _ = rebuilt.forward_per_token(&x, Bits::Int8);
    }

    #[test]
    #[should_panic(expected = "non-finite factors")]
    fn rejects_non_finite_static_profile() {
        let (_, w) = pair(false);
        let mut lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let mut cp = vec![1.0f32; 64];
        cp[3] = f32::NAN;
        lin.set_scale_mode(ScaleMode::Static { alpha: 0.15, col_pow: cp });
    }

    #[test]
    #[should_panic(expected = "i8 codes")]
    fn rejects_widths_above_eight_bits() {
        // Bits::Other(12) is a legal fake-quant width, but the integer
        // path cannot represent its codes in i8 — must fail loudly, not
        // silently saturate
        let (_, w) = pair(false);
        let _ = QuantizedLinear::from_weight(&w, Bits::Other(12));
    }

    #[test]
    fn zero_activation_row_is_safe() {
        let (mut x, w) = pair(false);
        for v in x.row_mut(0) {
            *v = 0.0;
        }
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let y = lin.forward_per_token(&x, Bits::Int8);
        assert!(y.row(0).iter().all(|&v| v == 0.0));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }
}
