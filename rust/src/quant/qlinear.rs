//! True-integer quantized linear layers — the deployment path the paper
//! motivates (§3: "quantizing activations … accelerates inference").
//!
//! Everything else in this crate follows the paper's *fake-quant*
//! evaluation protocol; this module is the real thing: weights stored as
//! INT8/INT4 codes, activations quantized to integer codes at run time,
//! and the matmul accumulating in i32.
//!
//! Two activation schemes:
//!
//! * **per-token** — the classic W8A8 GEMM: the scale t_i/qmax is constant
//!   along the contraction axis, so y_ij = (t_i/q)·s_j · Σ_k xq_ik·wq_kj
//!   is one int8×int8→i32 GEMM plus a rank-1 dequant.
//! * **CrossQuant** — the scale t_i^α·c_k^(1−α) varies along the
//!   contraction axis, so it cannot be pulled out of an integer
//!   accumulation. Deployment folds c_k^(1−α) into the weight *rows and
//!   requantizes them to the integer grid per activation batch* (c changes
//!   with the batch). The matmul stays int8×int8→i32; the price is a
//!   per-batch O(I·O) weight-rescale pass — the honest engineering cost of
//!   the method that the paper's complexity discussion (§4.2) abstracts
//!   away, quantified in `rust/benches/quant_hot_path.rs`.

use super::{Bits, EPS};
use crate::tensor::{par, Matrix};

/// A linear layer with per-output-channel integer weights.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub in_dim: usize,
    pub out_dim: usize,
    pub bits: Bits,
    /// Row-major (in_dim × out_dim) integer codes.
    codes: Vec<i8>,
    /// Per-output-channel scale: w ≈ code · w_scale[j].
    w_scale: Vec<f32>,
    /// FP copy of the weight for the CrossQuant requantization path.
    w_fp: Matrix,
}

/// Integer activation codes + their factored scales.
pub struct QuantizedActivation {
    pub rows: usize,
    pub cols: usize,
    pub codes: Vec<i8>,
    /// Per-row dequant factor (t_i/q for per-token, t_i^α/q for CrossQuant).
    pub row_scale: Vec<f32>,
}

impl QuantizedLinear {
    /// Quantize a weight matrix (I × O) per output channel.
    pub fn from_weight(w: &Matrix, bits: Bits) -> QuantizedLinear {
        let qmax = bits.qmax();
        let w_scale: Vec<f32> = w.col_abs_max().iter().map(|&c| c.max(EPS) / qmax).collect();
        let mut codes = Vec::with_capacity(w.len());
        for i in 0..w.rows {
            for (j, &v) in w.row(i).iter().enumerate() {
                codes.push((v / w_scale[j]).round().clamp(-qmax, qmax) as i8);
            }
        }
        QuantizedLinear {
            in_dim: w.rows,
            out_dim: w.cols,
            bits,
            codes,
            w_scale,
            w_fp: w.clone(),
        }
    }

    /// Integer payload bytes (weights only).
    pub fn payload_bytes(&self) -> usize {
        match self.bits {
            Bits::Int4 => self.codes.len().div_ceil(2),
            _ => self.codes.len(),
        }
    }

    /// Per-token quantize an activation to integer codes.
    pub fn quantize_per_token(x: &Matrix, bits: Bits) -> QuantizedActivation {
        let qmax = bits.qmax();
        let t = x.row_abs_max();
        let row_scale: Vec<f32> = t.iter().map(|&ti| ti.max(EPS) / qmax).collect();
        let mut codes = Vec::with_capacity(x.len());
        for i in 0..x.rows {
            let inv = 1.0 / row_scale[i];
            for &v in x.row(i) {
                codes.push((v * inv).round().clamp(-qmax, qmax) as i8);
            }
        }
        QuantizedActivation { rows: x.rows, cols: x.cols, codes, row_scale }
    }

    /// CrossQuant-quantize an activation: per-element scale
    /// t_i^α·c_j^(1−α)/q, codes on the integer grid; returns the codes,
    /// the per-row factor t_i^α/q, and the per-column factor c_j^(1−α)
    /// the weight side must fold.
    pub fn quantize_crossquant(
        x: &Matrix,
        alpha: f32,
        bits: Bits,
    ) -> (QuantizedActivation, Vec<f32>) {
        let qmax = bits.qmax();
        let row_scale: Vec<f32> =
            x.row_abs_max().iter().map(|&t| t.max(EPS).powf(alpha) / qmax).collect();
        let col_pow: Vec<f32> =
            x.col_abs_max().iter().map(|&c| c.max(EPS).powf(1.0 - alpha)).collect();
        let mut codes = Vec::with_capacity(x.len());
        for i in 0..x.rows {
            let rp = row_scale[i];
            for (j, &v) in x.row(i).iter().enumerate() {
                let d = rp * col_pow[j];
                codes.push((v / d).round().clamp(-qmax, qmax) as i8);
            }
        }
        (QuantizedActivation { rows: x.rows, cols: x.cols, codes, row_scale }, col_pow)
    }

    /// The W8A8 GEMM: int8×int8 → i32 accumulate, rank-1 dequant.
    pub fn forward_per_token(&self, x: &Matrix, act_bits: Bits) -> Matrix {
        let act = Self::quantize_per_token(x, act_bits);
        self.gemm_i32(&act, &self.codes, &self.w_scale)
    }

    /// The CrossQuant integer path: requantize weight rows with the
    /// activation's c^(1−α) factor folded in (per batch), then the same
    /// int8 GEMM.
    pub fn forward_crossquant(&self, x: &Matrix, alpha: f32, act_bits: Bits) -> Matrix {
        let (act, col_pow) = Self::quantize_crossquant(x, alpha, act_bits);
        let qmax = self.bits.qmax();
        // Fold c_k^(1−α) into the FP weight rows and requantize per output
        // channel — the per-batch O(I·O) rescale pass. Both halves are
        // row-parallel over the weight (see tensor::par): workers reduce
        // their row blocks to per-output maxima (merged below), then emit
        // their blocks of folded integer codes.
        let n = self.out_dim;
        let workers = par::workers_for(self.in_dim, self.w_fp.len());
        let partial_max = par::par_map_rows(self.in_dim, workers, |range| {
            let mut m = vec![0.0f32; n];
            for k in range {
                let cp = col_pow[k];
                for (mj, &v) in m.iter_mut().zip(self.w_fp.row(k)) {
                    let a = (v * cp).abs();
                    if a > *mj {
                        *mj = a;
                    }
                }
            }
            m
        });
        let mut folded_scale = vec![0.0f32; n];
        for m in &partial_max {
            for (s, &a) in folded_scale.iter_mut().zip(m) {
                if a > *s {
                    *s = a;
                }
            }
        }
        for s in folded_scale.iter_mut() {
            *s = s.max(EPS) / qmax;
        }
        let mut folded_codes = vec![0i8; self.w_fp.len()];
        par::par_rows_mut(&mut folded_codes, n.max(1), workers, |k0, chunk| {
            for (local, dst) in chunk.chunks_mut(n.max(1)).enumerate() {
                let k = k0 + local;
                let cp = col_pow[k];
                for ((c, &v), &s) in dst.iter_mut().zip(self.w_fp.row(k)).zip(&folded_scale) {
                    *c = (v * cp / s).round().clamp(-qmax, qmax) as i8;
                }
            }
        });
        self.gemm_i32(&act, &folded_codes, &folded_scale)
    }

    /// FP reference product (unquantized weight).
    pub fn forward_fp(&self, x: &Matrix) -> Matrix {
        x.matmul(&self.w_fp)
    }

    /// int8 × int8 → i32 GEMM with row/col dequantization. Row-parallel:
    /// each worker owns a block of output rows and its own i32
    /// accumulator; integer sums make the result order-independent. The
    /// `a == 0` skip is exact for integer codes (unlike the FP matmul's
    /// removed shortcut) and pays off because quantized activations are
    /// zero exactly on the quantization kernel.
    fn gemm_i32(&self, act: &QuantizedActivation, w_codes: &[i8], w_scale: &[f32]) -> Matrix {
        assert_eq!(act.cols, self.in_dim, "activation/weight shape mismatch");
        let (m, k_dim, n) = (act.rows, self.in_dim, self.out_dim);
        let mut out = Matrix::zeros(m, n);
        if out.is_empty() {
            return out;
        }
        let cost = m.saturating_mul(k_dim).saturating_mul(n);
        par::par_rows_mut(&mut out.data, n, par::workers_for(m, cost), |row0, chunk| {
            let mut acc = vec![0i32; n];
            for (local_i, dst) in chunk.chunks_mut(n).enumerate() {
                let i = row0 + local_i;
                acc.iter_mut().for_each(|a| *a = 0);
                let a_row = &act.codes[i * k_dim..(i + 1) * k_dim];
                for (k, &a) in a_row.iter().enumerate() {
                    if a == 0 {
                        continue;
                    }
                    let a = a as i32;
                    let w_row = &w_codes[k * n..(k + 1) * n];
                    for (o, &w) in acc.iter_mut().zip(w_row) {
                        *o += a * w as i32;
                    }
                }
                let rs = act.row_scale[i];
                for ((d, &a), &ws) in dst.iter_mut().zip(&acc).zip(w_scale) {
                    *d = a as f32 * rs * ws;
                }
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn pair(outlier: bool) -> (Matrix, Matrix) {
        let mut rng = SplitMix64::new(51);
        let mut x = Matrix::randn(96, 64, 1.0, &mut rng);
        if outlier {
            for i in 0..x.rows {
                let v = x.get(i, 3) * 50.0;
                x.set(i, 3, v);
            }
        }
        let w = Matrix::randn(64, 48, 0.1, &mut rng);
        (x, w)
    }

    #[test]
    fn per_token_int8_close_to_fp() {
        let (x, w) = pair(false);
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let y = lin.forward_per_token(&x, Bits::Int8);
        let fp = lin.forward_fp(&x);
        let rel = y.distance(&fp) / fp.frobenius();
        assert!(rel < 0.02, "rel {rel}");
    }

    #[test]
    fn crossquant_int8_beats_per_token_under_outliers() {
        let (x, w) = pair(true);
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let fp = lin.forward_fp(&x);
        let e_pt = lin.forward_per_token(&x, Bits::Int8).distance(&fp) / fp.frobenius();
        let e_cq = lin.forward_crossquant(&x, 0.15, Bits::Int8).distance(&fp) / fp.frobenius();
        assert!(e_cq < e_pt, "cq {e_cq} pt {e_pt}");
        assert!(e_cq < 0.05, "cq {e_cq}");
    }

    #[test]
    fn alpha_one_matches_per_token_path() {
        let (x, w) = pair(true);
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let a = lin.forward_crossquant(&x, 1.0, Bits::Int8);
        let b = lin.forward_per_token(&x, Bits::Int8);
        // α=1 ⇒ col_pow = 1 ⇒ folded weight == original weight grid
        let rel = a.distance(&b) / b.frobenius().max(1e-6);
        assert!(rel < 1e-3, "rel {rel}");
    }

    #[test]
    fn integer_path_matches_fake_quant_semantics() {
        // integer GEMM with per-token codes == fake-quant(x) @ fake-quant(w)
        let (x, w) = pair(false);
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let y_int = lin.forward_per_token(&x, Bits::Int8);
        use crate::quant::{per_channel::PerChannel, per_token::PerToken, ActQuantizer};
        let y_fake = PerToken::new(Bits::Int8)
            .fake_quant(&x)
            .matmul(&PerChannel::new(Bits::Int8).fake_quant(&w));
        let rel = y_int.distance(&y_fake) / y_fake.frobenius();
        assert!(rel < 1e-4, "integer vs fake-quant rel {rel}");
    }

    #[test]
    fn int4_payload_is_half() {
        let (_, w) = pair(false);
        let l8 = QuantizedLinear::from_weight(&w, Bits::Int8);
        let l4 = QuantizedLinear::from_weight(&w, Bits::Int4);
        assert_eq!(l8.payload_bytes(), 64 * 48);
        assert_eq!(l4.payload_bytes(), (64 * 48usize).div_ceil(2));
    }

    #[test]
    fn zero_activation_row_is_safe() {
        let (mut x, w) = pair(false);
        for v in x.row_mut(0) {
            *v = 0.0;
        }
        let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
        let y = lin.forward_per_token(&x, Bits::Int8);
        assert!(y.row(0).iter().all(|&v| v == 0.0));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }
}
