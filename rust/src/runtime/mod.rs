//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them once on the CPU PJRT client, and
//! execute them from the request path. Python never runs here.

pub mod artifact;
pub mod client;
pub mod literal;

pub use artifact::ArtifactStore;
pub use client::Runtime;
