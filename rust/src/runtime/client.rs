//! PJRT client wrapper with a compiled-executable cache.
//!
//! Each HLO-text artifact is parsed (`HloModuleProto::from_text_file` —
//! the text parser reassigns the 64-bit instruction ids jax ≥0.5 emits,
//! which xla_extension 0.5.1 would otherwise reject) and compiled exactly
//! once; executions reuse the cached `PjRtLoadedExecutable`.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::artifact::ArtifactStore;
use crate::xla;

pub struct Runtime {
    pub store: ArtifactStore,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Compile + execute counters (exposed through coordinator metrics).
    pub compiles: usize,
    pub executions: usize,
}

impl Runtime {
    pub fn new(store: ArtifactStore) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { store, client, cache: HashMap::new(), compiles: 0, executions: 0 })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.store.hlo_path(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(name.to_string(), exe);
        self.compiles += 1;
        Ok(())
    }

    /// Execute an artifact. All our HLOs are lowered with
    /// `return_tuple=True`, so the single output buffer is a tuple literal;
    /// we decompose it into its elements.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.prepare(name)?;
        let exe = self.cache.get(name).expect("prepared above");
        let result = exe.execute::<xla::Literal>(inputs)?;
        let literal = result[0][0].to_literal_sync()?;
        self.executions += 1;
        Ok(literal.to_tuple()?)
    }

    /// Number of compiled executables resident.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}
