//! Artifact discovery: locate the artifacts directory, validate that the
//! HLO inventory in manifest.json matches the files on disk.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

use crate::model::weights::{Manifest, Weights};

/// The set of AOT artifacts this runtime understands.
pub const KNOWN_ARTIFACTS: &[&str] =
    &["lm_fp", "lm_aq", "lm_aq_jnp", "lm_rk", "lm_acts", "quant_ops", "qmatmul"];

#[derive(Clone, Debug)]
pub struct ArtifactStore {
    pub dir: PathBuf,
}

impl ArtifactStore {
    /// Locate artifacts: explicit path, `$CROSSQUANT_ARTIFACTS`, or
    /// `./artifacts` relative to the working directory.
    pub fn discover(explicit: Option<&Path>) -> Result<ArtifactStore> {
        let dir = if let Some(p) = explicit {
            p.to_path_buf()
        } else if let Ok(env) = std::env::var("CROSSQUANT_ARTIFACTS") {
            PathBuf::from(env)
        } else {
            PathBuf::from("artifacts")
        };
        ensure!(
            dir.join("manifest.json").exists(),
            "no manifest.json under {} — run `make artifacts` first",
            dir.display()
        );
        Ok(ArtifactStore { dir })
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn load_weights(&self) -> Result<Weights> {
        Weights::load(&self.dir)
    }

    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::parse(&std::fs::read_to_string(self.dir.join("manifest.json"))?)
    }

    /// Which known artifacts are present on disk?
    pub fn available(&self) -> Vec<&'static str> {
        KNOWN_ARTIFACTS.iter().copied().filter(|n| self.hlo_path(n).exists()).collect()
    }

    /// Fail unless every known artifact exists (used by the CLI preflight).
    pub fn validate(&self) -> Result<()> {
        for name in KNOWN_ARTIFACTS {
            ensure!(
                self.hlo_path(name).exists(),
                "missing artifact {} — run `make artifacts`",
                self.hlo_path(name).display()
            );
        }
        ensure!(self.dir.join("weights.bin").exists(), "missing weights.bin");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discover_missing_dir_errors() {
        let r = ArtifactStore::discover(Some(Path::new("/nonexistent/nowhere")));
        assert!(r.is_err());
    }

    #[test]
    fn hlo_path_shape() {
        let s = ArtifactStore { dir: PathBuf::from("/tmp/x") };
        assert_eq!(s.hlo_path("lm_fp"), PathBuf::from("/tmp/x/lm_fp.hlo.txt"));
    }
}
