//! Matrix / token / scalar ⇄ xla::Literal marshalling.

use anyhow::{ensure, Result};

use crate::tensor::Matrix;
use crate::xla;

/// (B, S) token batch → i32 literal. Pads short rows with `pad` up to S.
pub fn tokens_literal(batch: &[Vec<u32>], seq_len: usize, pad: u32) -> Result<xla::Literal> {
    ensure!(!batch.is_empty(), "empty token batch");
    let b = batch.len();
    let mut flat = Vec::with_capacity(b * seq_len);
    for row in batch {
        ensure!(row.len() <= seq_len, "sequence longer than artifact seq_len");
        flat.extend(row.iter().map(|&t| t as i32));
        flat.extend(std::iter::repeat(pad as i32).take(seq_len - row.len()));
    }
    Ok(xla::Literal::vec1(&flat).reshape(&[b as i64, seq_len as i64])?)
}

pub fn matrix_literal(m: &Matrix) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
}

pub fn vec_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn literal_to_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Interpret a literal of shape (rows, cols) as a Matrix.
pub fn literal_to_matrix(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let data = lit.to_vec::<f32>()?;
    ensure!(data.len() == rows * cols, "literal size {} != {rows}x{cols}", data.len());
    Ok(Matrix::from_vec(rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_literal_pads() {
        let lit = tokens_literal(&[vec![1, 2, 3], vec![4]], 4, 0).unwrap();
        let v = lit.to_vec::<i32>().unwrap();
        assert_eq!(v, vec![1, 2, 3, 0, 4, 0, 0, 0]);
    }

    #[test]
    fn tokens_literal_rejects_overflow() {
        assert!(tokens_literal(&[vec![1, 2, 3]], 2, 0).is_err());
    }

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let lit = matrix_literal(&m).unwrap();
        let back = literal_to_matrix(&lit, 2, 3).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = scalar_literal(0.15);
        assert!((literal_to_scalar(&lit).unwrap() - 0.15).abs() < 1e-7);
    }
}
