//! Model-family activation profiles.
//!
//! Calibration targets (paper Figure 4, measured on WikiText2):
//!   * OPT ≥ 6.7B: per-token kernel 40–55 %, CrossQuant ≈ 16 %
//!   * OPT 1.3B:   per-token kernel ≈ 16 % (pre-outlier-emergence)
//!   * OPT 2.3B:   transitional (≈ 30 %, tolerated well — paper §6)
//!   * LLaMA:      per-token ≈ 11 %, CrossQuant < 0.1 %
//!
//! Element model: bulk elements are sign·(|N(0,1)| + bulk_floor); with
//! probability `small_mass` an element instead has magnitude
//! U(small_lo, small_hi) (the near-zero spike of leptokurtic OPT
//! activations); the `outlier_channels` systematic columns are scaled by
//! `outlier_scale`. The knobs map onto the paper's regimes:
//!   * outlier_scale drives t_i and hence the *per-token* kernel;
//!   * the (small_lo, small_hi) band relative to the CrossQuant zero bound
//!     B̃ decides how much of the spike CrossQuant still loses (≈16 % for
//!     OPT, where the spike hugs zero; ~0 for LLaMA, whose bulk_floor
//!     keeps magnitudes above B̃).

use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Opt,
    Llama,
}

/// Streaming per-column activation statistics for static-scale CrossQuant
/// calibration: accumulates column abs-maxima across calibration batches —
/// the deployment-time stand-in for the live batch maxima the dynamic path
/// measures (ZeroQuant-V2/LRQ-style static scales).
///
/// One `ColStats` per quantization site; `QuantizedModel::calibrate_static`
/// drives a bank of them through the forward pass and folds the resulting
/// [`ColStats::col_pow`] profile into each `QuantizedLinear`.
#[derive(Clone, Debug, Default)]
pub struct ColStats {
    col_max: Vec<f32>,
    /// Number of calibration batches observed.
    pub batches: usize,
}

impl ColStats {
    pub fn new() -> ColStats {
        ColStats { col_max: Vec::new(), batches: 0 }
    }

    /// Rebuild statistics from persisted column maxima — the
    /// `quant::artifact` load path. The per-batch provenance is not
    /// shipped, so `batches` reports 1 (observed once, as one artifact).
    pub fn from_col_max(col_max: Vec<f32>) -> ColStats {
        ColStats { col_max, batches: 1 }
    }

    /// Fold one calibration activation batch into the statistics.
    /// NaN-propagating like `Matrix::col_abs_max`: a corrupt calibration
    /// batch surfaces in the profile instead of vanishing into a max.
    pub fn observe(&mut self, x: &Matrix) {
        let cm = x.col_abs_max();
        if self.col_max.is_empty() {
            self.col_max = cm;
        } else {
            assert_eq!(self.col_max.len(), cm.len(), "column count changed mid-calibration");
            for (m, &v) in self.col_max.iter_mut().zip(&cm) {
                if v > *m || v.is_nan() {
                    *m = v;
                }
            }
        }
        self.batches += 1;
    }

    /// Calibrated column abs-maxima ĉ (empty before any `observe`).
    pub fn col_max(&self) -> &[f32] {
        &self.col_max
    }

    /// The calibrated CrossQuant column factors ĉ^(1−α) — the profile
    /// payload of `quant::qlinear::ScaleMode::Static`, computed by the
    /// shared eq. (5) helper so calibration can never drift from the
    /// dynamic path's clamping.
    pub fn col_pow(&self, alpha: f32) -> Vec<f32> {
        crate::quant::crossquant::col_pow_scales(&self.col_max, alpha)
    }
}

impl std::fmt::Display for Family {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Family::Opt => write!(f, "OPT"),
            Family::Llama => write!(f, "LLaMA"),
        }
    }
}

/// Statistical profile of one model family member's activations.
#[derive(Clone, Debug)]
pub struct FamilyProfile {
    pub name: &'static str,
    pub family: Family,
    /// Nominal parameter count (billions) — the paper's x-axis label.
    pub params_b: f32,
    /// Number of systematic outlier channels.
    pub outlier_channels: usize,
    /// Magnitude multiplier of outlier channels relative to the bulk.
    pub outlier_scale: f32,
    /// Fraction of elements drawn from the near-zero spike.
    pub small_mass: f32,
    /// Magnitude band of the spike: |x| ~ U(small_lo, small_hi).
    pub small_lo: f32,
    pub small_hi: f32,
    /// Minimum magnitude of bulk elements (LLaMA's bulk stays away from 0).
    pub bulk_floor: f32,
}

impl FamilyProfile {
    #[allow(clippy::too_many_arguments)]
    pub const fn new(
        name: &'static str,
        family: Family,
        params_b: f32,
        outlier_channels: usize,
        outlier_scale: f32,
        small_mass: f32,
        small_lo: f32,
        small_hi: f32,
        bulk_floor: f32,
    ) -> Self {
        FamilyProfile {
            name,
            family,
            params_b,
            outlier_channels,
            outlier_scale,
            small_mass,
            small_lo,
            small_hi,
            bulk_floor,
        }
    }

    /// All OPT family members evaluated in the paper (Figs. 1/4/6, Tabs 3/5).
    /// Outliers emerge at 6.7B (Appendix A) — below that the row max is the
    /// ordinary Gaussian max, above it systematic 30–60× channels.
    pub fn opt_family() -> Vec<FamilyProfile> {
        vec![
            Self::new("opt-1.3b", Family::Opt, 1.3, 0, 1.0, 0.14, 0.0, 0.02, 0.0),
            Self::new("opt-2.3b", Family::Opt, 2.3, 1, 60.0, 0.14, 0.0, 0.02, 0.0),
            Self::new("opt-6.7b", Family::Opt, 6.7, 2, 82.0, 0.14, 0.0, 0.02, 0.0),
            Self::new("opt-13b", Family::Opt, 13.0, 2, 93.0, 0.14, 0.0, 0.02, 0.0),
            Self::new("opt-30b", Family::Opt, 30.0, 3, 110.0, 0.15, 0.0, 0.02, 0.0),
            Self::new("opt-66b", Family::Opt, 66.0, 3, 127.0, 0.16, 0.0, 0.02, 0.0),
        ]
    }

    /// All LLaMA family members evaluated in the paper (Tabs 2/4, Fig 7).
    pub fn llama_family() -> Vec<FamilyProfile> {
        vec![
            Self::new("llama2-7b", Family::Llama, 7.0, 1, 15.0, 0.20, 0.02, 0.10, 0.05),
            Self::new("llama2-13b", Family::Llama, 13.0, 1, 15.5, 0.20, 0.02, 0.10, 0.05),
            Self::new("llama1-30b", Family::Llama, 30.0, 2, 16.0, 0.21, 0.02, 0.10, 0.05),
            Self::new("llama3-8b", Family::Llama, 8.0, 1, 15.2, 0.20, 0.02, 0.10, 0.05),
            Self::new("llama3-70b", Family::Llama, 70.0, 2, 16.5, 0.21, 0.02, 0.10, 0.05),
        ]
    }

    pub fn all() -> Vec<FamilyProfile> {
        let mut v = Self::opt_family();
        v.extend(Self::llama_family());
        v
    }

    pub fn by_name(name: &str) -> Option<FamilyProfile> {
        Self::all().into_iter().find(|p| p.name == name)
    }

    /// Has this member crossed the outlier-emergence scale? (≥6.7B for
    /// OPT, Appendix A: multiple systematic rogue channels.)
    pub fn has_systematic_outliers(&self) -> bool {
        self.outlier_channels >= 2 && self.outlier_scale >= 20.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(FamilyProfile::by_name("opt-13b").unwrap().params_b, 13.0);
        assert!(FamilyProfile::by_name("gpt-5").is_none());
    }

    #[test]
    fn emergence_boundary() {
        assert!(!FamilyProfile::by_name("opt-1.3b").unwrap().has_systematic_outliers());
        assert!(!FamilyProfile::by_name("opt-2.3b").unwrap().has_systematic_outliers());
        assert!(FamilyProfile::by_name("opt-6.7b").unwrap().has_systematic_outliers());
        assert!(FamilyProfile::by_name("opt-66b").unwrap().has_systematic_outliers());
    }

    #[test]
    fn col_stats_accumulate_maxima_across_batches() {
        let a = Matrix::from_vec(2, 3, vec![1.0, -5.0, 2.0, -3.0, 4.0, 0.0]);
        let b = Matrix::from_vec(2, 3, vec![-7.0, 1.0, 0.5, 2.0, -1.0, 6.0]);
        let mut stats = ColStats::new();
        stats.observe(&a);
        stats.observe(&b);
        assert_eq!(stats.batches, 2);
        assert_eq!(stats.col_max(), &[7.0, 5.0, 6.0]);
        // α=1 ⇒ c^0 = 1 for every column (the per-token degeneration)
        for p in stats.col_pow(1.0) {
            assert!((p - 1.0).abs() < 1e-6);
        }
        // α=0 ⇒ the factors are the maxima themselves
        let p0 = stats.col_pow(0.0);
        assert!((p0[0] - 7.0).abs() < 1e-5 && (p0[2] - 6.0).abs() < 1e-5);
    }

    #[test]
    fn families_disjoint_and_complete() {
        let all = FamilyProfile::all();
        assert_eq!(all.len(), 11);
        assert_eq!(all.iter().filter(|p| p.family == Family::Opt).count(), 6);
        assert_eq!(all.iter().filter(|p| p.family == Family::Llama).count(), 5);
    }
}
