//! Synthetic activation matrices drawn from a [`FamilyProfile`].
//!
//! Element distribution: with probability `small_mass` a near-zero spike
//! N(0, small_std²), otherwise the bulk N(0, 1); then the first
//! `outlier_channels` columns (a fixed, systematic set — outlier channels
//! in real LLMs are stable across tokens, Kovaleva et al. 2021) are scaled
//! by `outlier_scale`.

use super::profile::FamilyProfile;
use crate::tensor::{Matrix, SplitMix64};

pub struct ActivationGen {
    pub profile: FamilyProfile,
    rng: SplitMix64,
}

impl ActivationGen {
    pub fn new(profile: FamilyProfile, seed: u64) -> Self {
        ActivationGen { profile, rng: SplitMix64::new(seed) }
    }

    /// One (tokens × channels) activation matrix.
    pub fn matrix(&mut self, tokens: usize, channels: usize) -> Matrix {
        let p = &self.profile;
        let mut x = Matrix::zeros(tokens, channels);
        for i in 0..tokens {
            for j in 0..channels {
                let v = if self.rng.uniform() < p.small_mass as f64 {
                    // near-zero spike: |x| ~ U(small_lo, small_hi)
                    let mag =
                        p.small_lo + (p.small_hi - p.small_lo) * self.rng.uniform() as f32;
                    if self.rng.uniform() < 0.5 {
                        mag
                    } else {
                        -mag
                    }
                } else {
                    // bulk: sign·(|N(0,1)| + bulk_floor)
                    let n = self.rng.normal() as f32;
                    n + p.bulk_floor * n.signum()
                };
                x.set(i, j, v);
            }
        }
        // systematic outlier channels, spread across the channel range
        for k in 0..p.outlier_channels.min(channels) {
            let j = k * channels / p.outlier_channels.max(1);
            for i in 0..tokens {
                let v = x.get(i, j);
                // keep outlier channels away from the near-zero spike so
                // their magnitude is consistently large, as observed in
                // real models (they are "always-on" rogue dimensions)
                let base = if v.abs() < 0.1 { 0.5 + v } else { v };
                x.set(i, j, base * p.outlier_scale);
            }
        }
        x
    }

    /// A batch of matrices (e.g. one per layer) for averaged statistics.
    pub fn batch(&mut self, n: usize, tokens: usize, channels: usize) -> Vec<Matrix> {
        (0..n).map(|_| self.matrix(tokens, channels)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::kernel_fraction;
    use crate::quant::{crossquant::CrossQuant, per_token::PerToken, ActQuantizer, Bits};

    fn gen(name: &str) -> Matrix {
        ActivationGen::new(FamilyProfile::by_name(name).unwrap(), 7).matrix(512, 256)
    }

    #[test]
    fn opt_66b_reproduces_large_per_token_kernel() {
        let x = gen("opt-66b");
        let k = kernel_fraction(&x, &PerToken::new(Bits::Int8).delta_field(&x));
        assert!(k > 0.35, "per-token kernel {k}");
        let kc = kernel_fraction(&x, &CrossQuant::new(0.15, Bits::Int8).delta_field(&x));
        assert!(kc < 0.25 && kc < k / 2.0, "crossquant kernel {kc}");
    }

    #[test]
    fn llama_reproduces_small_kernels() {
        let x = gen("llama2-7b");
        let k = kernel_fraction(&x, &PerToken::new(Bits::Int8).delta_field(&x));
        assert!(k > 0.01 && k < 0.3, "per-token kernel {k}");
        let kc = kernel_fraction(&x, &CrossQuant::new(0.15, Bits::Int8).delta_field(&x));
        assert!(kc < 0.01, "crossquant kernel {kc}");
    }

    #[test]
    fn regime_ordering_across_families() {
        // paper Figure 4: OPT(≥6.7B) per-token ≫ OPT(1.3B) ≈ LLaMA levels
        let k = |name: &str| {
            let x = gen(name);
            kernel_fraction(&x, &PerToken::new(Bits::Int8).delta_field(&x))
        };
        let k_small_opt = k("opt-1.3b");
        let k_big_opt = k("opt-66b");
        let k_llama = k("llama2-13b");
        assert!(k_big_opt > 2.0 * k_small_opt, "{k_big_opt} vs {k_small_opt}");
        assert!(k_big_opt > 2.0 * k_llama, "{k_big_opt} vs {k_llama}");
    }

    #[test]
    fn deterministic_given_seed() {
        let p = FamilyProfile::by_name("opt-13b").unwrap();
        let a = ActivationGen::new(p.clone(), 3).matrix(16, 16);
        let b = ActivationGen::new(p, 3).matrix(16, 16);
        assert_eq!(a, b);
    }
}
