//! Synthetic-activation substrate: model-family profiles + generators.
//!
//! The paper's model-size axis (OPT-1.3B…66B, LLaMA-7B…70B) matters to the
//! quantization analysis only through the activation statistics each model
//! exhibits — most importantly the emergence of systematic outlier channels
//! in models ≥ 6.7B (Dettmers et al., 2022; paper Appendix A). We encode
//! each family member as a [`FamilyProfile`] whose parameters are
//! calibrated to land in the paper's reported kernel regimes, and generate
//! activations from it (or inject it into the trained LM's LayerNorm gains
//! — see `model::quantized`).

pub mod profile;
pub mod synth;

pub use profile::{ColStats, Family, FamilyProfile};
pub use synth::ActivationGen;
