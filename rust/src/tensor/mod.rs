//! Minimal dense-tensor substrate: a row-major f32 matrix plus the handful
//! of operations the quantization library and the native forward pass need.
//!
//! Deliberately not a general tensor library — every op here exists because
//! a quantizer, the analysis engine, or `model::forward` uses it on a hot
//! path, and each is written to be straightforwardly auto-vectorizable.

pub mod par;
pub mod rng;

pub use rng::SplitMix64;

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Per-row absolute maximum: the paper's `t` vector (len = rows).
    ///
    /// NaN-propagating: a NaN anywhere in a row yields a NaN maximum, so a
    /// corrupt activation matrix surfaces in the scale field instead of
    /// producing a plausible-looking delta (`f32::max` would silently
    /// discard the NaN and the kernel-fraction numbers would be quietly
    /// wrong). `quant::debug_assert_finite` turns that NaN into a panic in
    /// debug builds at every `delta_field` entry.
    pub fn row_abs_max(&self) -> Vec<f32> {
        (0..self.rows).map(|i| abs_max_nan_propagating(0.0, self.row(i))).collect()
    }

    /// Per-column absolute maximum: the paper's `c` vector (len = cols).
    /// Row-parallel (see [`par`]) and NaN-propagating like
    /// [`Matrix::row_abs_max`].
    pub fn col_abs_max(&self) -> Vec<f32> {
        self.col_abs_max_threads(par::workers_for(self.rows, self.len()))
    }

    /// [`Matrix::col_abs_max`] with an explicit worker count (1 = the
    /// serial reference the parallel path is property-tested against).
    pub fn col_abs_max_threads(&self, workers: usize) -> Vec<f32> {
        let partials = par::par_map_rows(self.rows, workers, |range| {
            let mut c = vec![0.0f32; self.cols];
            for i in range {
                for (cv, &v) in c.iter_mut().zip(self.row(i)) {
                    let a = v.abs();
                    if a >= *cv || a.is_nan() {
                        *cv = a;
                    }
                }
            }
            c
        });
        let mut partials = partials.into_iter();
        let mut c = partials.next().unwrap_or_else(|| vec![0.0f32; self.cols]);
        for p in partials {
            for (cv, &a) in c.iter_mut().zip(&p) {
                if a >= *cv || a.is_nan() {
                    *cv = a;
                }
            }
        }
        c
    }

    /// Dense matmul: self (m×k) · rhs (k×n) → (m×n).
    ///
    /// Row-parallel, cache-blocked ikj kernel: each worker owns a
    /// contiguous block of output rows; within a row, contributions
    /// accumulate in strictly ascending k (walked in L1-sized k-blocks so
    /// the touched `rhs` rows stay resident), which keeps the result
    /// bit-identical for every worker count, including the serial
    /// reference `matmul_threads(rhs, 1)`. The inner loop is branchless
    /// over contiguous rows of `rhs`, which LLVM vectorizes — no
    /// data-dependent `a == 0.0` skip: that branch defeated
    /// autovectorization, made timings depend on activation sparsity, and
    /// silently dropped -0.0/NaN propagation.
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        let cost = self.rows.saturating_mul(self.cols).saturating_mul(rhs.cols);
        self.matmul_threads(rhs, par::workers_for(self.rows, cost))
    }

    /// [`Matrix::matmul`] with an explicit worker count.
    pub fn matmul_threads(&self, rhs: &Matrix, workers: usize) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let (k, n) = (self.cols, rhs.cols);
        let mut out = Matrix::zeros(self.rows, n);
        if out.is_empty() {
            return out;
        }
        // 256 k-steps touch 256 rhs rows; with the output row that stays
        // within L2 for the shapes this crate runs (n ≤ ~4096).
        const KB: usize = 256;
        par::par_rows_mut(&mut out.data, n, workers, |row0, chunk| {
            for (local_i, o_row) in chunk.chunks_mut(n).enumerate() {
                let a_row = self.row(row0 + local_i);
                let mut p0 = 0usize;
                while p0 < k {
                    let p1 = (p0 + KB).min(k);
                    for (off, &a) in a_row[p0..p1].iter().enumerate() {
                        let p = p0 + off;
                        let b_row = &rhs.data[p * n..(p + 1) * n];
                        for (o, &b) in o_row.iter_mut().zip(b_row) {
                            *o += a * b;
                        }
                    }
                    p0 = p1;
                }
            }
        });
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Frobenius norm of (self − other), for error metrics.
    pub fn distance(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Gaussian-filled matrix (Box–Muller over SplitMix64) — the substrate
    /// for synthetic activations and property tests.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut SplitMix64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal() as f32 * std);
        }
        Matrix { rows, cols, data }
    }
}

/// `fold` for the absolute maximum that lets NaN win instead of being
/// discarded (`f32::max(NaN, x)` returns `x`). If the accumulator is
/// already NaN, every later comparison is false and it stays NaN.
#[inline]
fn abs_max_nan_propagating(init: f32, row: &[f32]) -> f32 {
    row.iter().fold(init, |m, &v| {
        let a = v.abs();
        if a >= m || a.is_nan() {
            a
        } else {
            m
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let eye = Matrix::from_fn(3, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![1., 1., 1., 1.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3., 3., 7., 7.]);
    }

    #[test]
    fn row_col_abs_max() {
        let m = Matrix::from_vec(2, 3, vec![1., -5., 2., -3., 4., 0.]);
        assert_eq!(m.row_abs_max(), vec![5., 4.]);
        assert_eq!(m.col_abs_max(), vec![3., 5., 2.]);
    }

    #[test]
    fn matmul_propagates_nan_through_zero_lhs() {
        // The seed's `a == 0.0` inner-loop skip silently dropped NaN
        // propagation: a zero activation against a NaN weight must yield
        // NaN, exactly as IEEE multiply-add does.
        let a = Matrix::from_vec(1, 2, vec![0.0, 1.0]);
        let b = Matrix::from_vec(2, 2, vec![f32::NAN, 2.0, 3.0, 4.0]);
        let c = a.matmul(&b);
        assert!(c.get(0, 0).is_nan(), "0·NaN must propagate, got {}", c.get(0, 0));
        assert_eq!(c.get(0, 1), 4.0);
    }

    #[test]
    fn matmul_threads_bit_exact_with_serial() {
        let mut rng = SplitMix64::new(77);
        let a = Matrix::randn(37, 53, 1.0, &mut rng);
        let b = Matrix::randn(53, 29, 0.1, &mut rng);
        let serial = a.matmul_threads(&b, 1);
        for workers in [2, 4, 64] {
            assert_eq!(a.matmul_threads(&b, workers).data, serial.data);
        }
    }

    #[test]
    fn matmul_degenerate_shapes() {
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(5, 3);
        assert_eq!(a.matmul(&b), Matrix::zeros(0, 3));
        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 3);
        assert_eq!(a.matmul(&b), Matrix::zeros(4, 3));
        let a = Matrix::zeros(4, 5);
        let b = Matrix::zeros(5, 0);
        assert_eq!(a.matmul(&b), Matrix::zeros(4, 0));
    }

    #[test]
    fn abs_max_propagates_nan() {
        let m = Matrix::from_vec(2, 2, vec![1.0, f32::NAN, -3.0, 2.0]);
        let t = m.row_abs_max();
        assert!(t[0].is_nan(), "row NaN must survive the fold");
        assert_eq!(t[1], 3.0);
        let c = m.col_abs_max();
        assert_eq!(c[0], 3.0);
        assert!(c[1].is_nan(), "column NaN must survive the fold");
    }

    #[test]
    fn col_abs_max_threads_matches_serial() {
        let mut rng = SplitMix64::new(12);
        let m = Matrix::randn(61, 33, 1.0, &mut rng);
        let serial = m.col_abs_max_threads(1);
        for workers in [2, 7, 100] {
            assert_eq!(m.col_abs_max_threads(workers), serial);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = SplitMix64::new(1);
        let m = Matrix::randn(7, 5, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn randn_moments() {
        let mut rng = SplitMix64::new(42);
        let m = Matrix::randn(200, 200, 1.0, &mut rng);
        let mean = m.data.iter().sum::<f32>() / m.len() as f32;
        let var = m.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / m.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
