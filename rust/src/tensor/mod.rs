//! Minimal dense-tensor substrate: a row-major f32 matrix plus the handful
//! of operations the quantization library and the native forward pass need.
//!
//! Deliberately not a general tensor library — every op here exists because
//! a quantizer, the analysis engine, or `model::forward` uses it on a hot
//! path, and each is written to be straightforwardly auto-vectorizable.

pub mod rng;

pub use rng::SplitMix64;

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Per-row absolute maximum: the paper's `t` vector (len = rows).
    pub fn row_abs_max(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect()
    }

    /// Per-column absolute maximum: the paper's `c` vector (len = cols).
    pub fn col_abs_max(&self) -> Vec<f32> {
        let mut c = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (cv, &v) in c.iter_mut().zip(row) {
                let a = v.abs();
                if a > *cv {
                    *cv = a;
                }
            }
        }
        c
    }

    /// Dense matmul: self (m×k) · rhs (k×n) → (m×n).
    ///
    /// Simple ikj loop order with the inner loop over contiguous rows of
    /// `rhs`, which LLVM vectorizes; good enough for the tiny-model native
    /// path (the PJRT path carries the large shapes).
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, rhs.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let o_row = out.row_mut(i);
            for (p, &a) in a_row.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let b_row = &rhs.data[p * n..(p + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Frobenius norm of (self − other), for error metrics.
    pub fn distance(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Gaussian-filled matrix (Box–Muller over SplitMix64) — the substrate
    /// for synthetic activations and property tests.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut SplitMix64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal() as f32 * std);
        }
        Matrix { rows, cols, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let eye = Matrix::from_fn(3, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![1., 1., 1., 1.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3., 3., 7., 7.]);
    }

    #[test]
    fn row_col_abs_max() {
        let m = Matrix::from_vec(2, 3, vec![1., -5., 2., -3., 4., 0.]);
        assert_eq!(m.row_abs_max(), vec![5., 4.]);
        assert_eq!(m.col_abs_max(), vec![3., 5., 2.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = SplitMix64::new(1);
        let m = Matrix::randn(7, 5, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn randn_moments() {
        let mut rng = SplitMix64::new(42);
        let m = Matrix::randn(200, 200, 1.0, &mut rng);
        let mean = m.data.iter().sum::<f32>() / m.len() as f32;
        let var = m.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / m.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
