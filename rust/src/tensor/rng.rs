//! SplitMix64 PRNG — deterministic, dependency-free randomness for
//! synthetic activations, the corpus generator and property tests.

/// SplitMix64 (Steele et al.): tiny, fast, and passes BigCrush when used
/// as a 64-bit stream. Deterministic across platforms, which the
/// reproduce-a-table CLI relies on.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
    /// Cached second Box–Muller output.
    spare: Option<f64>,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box–Muller (caches the spare value).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Derive an independent stream (for per-worker rngs).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = SplitMix64::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.03);
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = SplitMix64::new(9);
        let mut b = a.fork();
        let va: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn below_bounds() {
        let mut r = SplitMix64::new(11);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
