//! Zero-dependency row-parallelism primitive (the offline build has no
//! rayon — see Cargo.toml).
//!
//! Every quantization hot path in this crate walks a row-major matrix row
//! by row, so one primitive covers all of them: split a buffer into
//! contiguous whole-row chunks and run one worker per chunk under
//! [`std::thread::scope`]. The worker count comes from
//! [`std::thread::available_parallelism`], can be overridden with the
//! `CROSSQUANT_THREADS` environment variable, and collapses to a serial
//! in-place call for small jobs (scoped-thread spawns cost ~10µs each, so
//! tiny matrices must not pay for them).
//!
//! Chunk boundaries depend only on `(rows, workers)`, and every consumer
//! keeps its per-row arithmetic identical between the serial and parallel
//! paths, so results are bit-exact for any worker count — pinned by
//! `rust/tests/parallel.rs`.

use std::sync::OnceLock;

/// Minimum element-operations a worker must receive before an extra
/// thread pays for its spawn.
pub const MIN_COST_PER_THREAD: usize = 32 * 1024;

fn parse_threads(val: &str) -> Option<usize> {
    val.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}

/// The configured thread ceiling: `CROSSQUANT_THREADS` if set and valid,
/// otherwise the machine's available parallelism (cached process-wide).
pub fn max_threads() -> usize {
    static CONF: OnceLock<usize> = OnceLock::new();
    *CONF.get_or_init(|| {
        std::env::var("CROSSQUANT_THREADS")
            .ok()
            .and_then(|v| parse_threads(&v))
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

/// Worker count for a job of `cost` total element-operations spread over
/// `rows` rows: 1 (serial) unless every worker gets a meaningful share,
/// and never more workers than rows.
pub fn workers_for(rows: usize, cost: usize) -> usize {
    let w = (cost / MIN_COST_PER_THREAD).min(max_threads()).min(rows);
    if w == 0 {
        1
    } else {
        w
    }
}

/// Split a 2-D iteration space of `row_units × col_units` independent
/// work units into a `(row_chunks, col_chunks)` tile grid for `workers`
/// workers. Rows are preferred (a row chunk streams each column unit
/// once; a column split re-reads its row inputs), so the column dimension
/// is only split when there are fewer row units than workers — the
/// shape where pure row-chunking leaves workers idle (an M=4 decode
/// step against thousands of output panels). Every returned grid
/// satisfies `row_chunks ≤ max(row_units, 1)` and
/// `col_chunks ≤ max(col_units, 1)`.
pub fn tile_grid(row_units: usize, col_units: usize, workers: usize) -> (usize, usize) {
    let row_chunks = row_units.min(workers).max(1);
    let col_chunks = if row_chunks >= workers {
        1
    } else {
        (workers / row_chunks).min(col_units).max(1)
    };
    (row_chunks, col_chunks)
}

/// Split `data` into contiguous whole-row chunks (`cols` elements per
/// row), run `f(first_row, chunk)` on `workers` scoped threads, and
/// return the per-chunk results in row order. `workers <= 1` (or an empty
/// buffer) runs one inline call — the serial reference path.
pub fn par_rows_map_mut<T, R, F>(data: &mut [T], cols: usize, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut [T]) -> R + Sync,
{
    if cols == 0 || data.is_empty() || workers <= 1 {
        return vec![f(0, data)];
    }
    let rows = data.len() / cols;
    debug_assert_eq!(rows * cols, data.len(), "buffer must hold whole rows");
    let workers = workers.min(rows);
    let per = rows.div_ceil(workers);
    // Chunk 0 runs on the calling thread (like par_map_rows below), so a
    // job of W workers costs W−1 spawns and the caller's core works too.
    let (first, mut rest) = data.split_at_mut(per * cols);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        let mut row0 = per;
        while row0 < rows {
            let take = per.min(rows - row0);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take * cols);
            rest = tail;
            let f = &f;
            let start = row0;
            handles.push(scope.spawn(move || f(start, chunk)));
            row0 += take;
        }
        let mut out = Vec::with_capacity(workers);
        out.push(f(0, first));
        for h in handles {
            out.push(h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)));
        }
        out
    })
}

/// [`par_rows_map_mut`] without per-chunk results — the common shape for
/// "fill this output buffer row-parallel".
pub fn par_rows_mut<T, F>(data: &mut [T], cols: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_rows_map_mut(data, cols, workers, f);
}

/// Map disjoint row ranges to per-chunk values on scoped threads (no
/// shared output buffer), returned in row order — the reduction-side
/// primitive (`kernel_fraction`, `col_abs_max`, the qlinear rescale max).
pub fn par_map_rows<R, F>(rows: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(std::ops::Range<usize>) -> R + Sync,
{
    if rows == 0 {
        return Vec::new();
    }
    let workers = workers.min(rows);
    if workers <= 1 {
        return vec![f(0..rows)];
    }
    let per = rows.div_ceil(workers);
    let n_chunks = rows.div_ceil(per);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (1..n_chunks)
            .map(|c| {
                let f = &f;
                scope.spawn(move || f(c * per..((c + 1) * per).min(rows)))
            })
            .collect();
        let mut out = Vec::with_capacity(n_chunks);
        out.push(f(0..per.min(rows)));
        for h in handles {
            out.push(h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)));
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_threads_accepts_positive_integers() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 16 "), Some(16));
        assert_eq!(parse_threads("0"), None);
        assert_eq!(parse_threads("-2"), None);
        assert_eq!(parse_threads("lots"), None);
    }

    #[test]
    fn workers_never_exceed_rows_and_tiny_jobs_stay_serial() {
        assert_eq!(workers_for(3, usize::MAX), 3.min(max_threads()));
        assert_eq!(workers_for(1000, 100), 1); // below MIN_COST_PER_THREAD
        assert_eq!(workers_for(0, usize::MAX), 1);
    }

    #[test]
    fn par_rows_mut_fills_every_row_once() {
        let (rows, cols) = (23, 7);
        for workers in [1, 2, 5, 16, 64] {
            let mut data = vec![0u32; rows * cols];
            par_rows_mut(&mut data, cols, workers, |row0, chunk| {
                for (local, row) in chunk.chunks_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + local) as u32 + 1;
                    }
                }
            });
            for i in 0..rows {
                assert!(data[i * cols..(i + 1) * cols].iter().all(|&v| v == i as u32 + 1));
            }
        }
    }

    #[test]
    fn par_rows_map_mut_returns_chunks_in_row_order() {
        let mut data = vec![0u8; 10 * 3];
        let starts = par_rows_map_mut(&mut data, 3, 4, |row0, _chunk| row0);
        let mut sorted = starts.clone();
        sorted.sort_unstable();
        assert_eq!(starts, sorted);
        assert_eq!(starts[0], 0);
    }

    #[test]
    fn par_map_rows_covers_range_in_order() {
        for workers in [1, 3, 7, 50] {
            let ranges = par_map_rows(11, workers, |r| r);
            let mut expect = 0usize;
            for r in &ranges {
                assert_eq!(r.start, expect);
                assert!(r.end > r.start);
                expect = r.end;
            }
            assert_eq!(expect, 11);
        }
    }

    #[test]
    fn tile_grid_prefers_rows_and_splits_columns_when_rows_run_out() {
        // plenty of rows: pure row split, no column tiling
        assert_eq!(tile_grid(128, 256, 8), (8, 1));
        // one row group, many panels: all parallelism moves to columns
        assert_eq!(tile_grid(1, 256, 8), (1, 8));
        // rows absorb some workers, columns the rest
        assert_eq!(tile_grid(2, 256, 8), (2, 4));
        // never more chunks than units
        assert_eq!(tile_grid(1, 2, 16), (1, 2));
        assert_eq!(tile_grid(3, 1, 16), (3, 1));
        // degenerate inputs stay a valid 1×1 grid
        assert_eq!(tile_grid(0, 0, 4), (1, 1));
        assert_eq!(tile_grid(5, 5, 0), (1, 1));
    }

    #[test]
    fn empty_inputs_are_safe() {
        let mut empty: Vec<f32> = Vec::new();
        par_rows_mut(&mut empty, 4, 8, |_, chunk| assert!(chunk.is_empty()));
        assert!(par_map_rows(0, 8, |r| r).is_empty());
        let results = par_rows_map_mut(&mut empty, 0, 8, |_, _| 42usize);
        assert_eq!(results, vec![42]);
    }
}
