//! Deterministic word-level tokenizer over the synthetic vocabulary.
//!
//! Maps token ids to pronounceable synthetic words (and back), used by the
//! examples and the CLI to render corpora and task prompts human-readably.
//! The mapping is a bijection: encode(decode(id)) == id.

/// Syllable-based id ⇄ word bijection.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub vocab: usize,
}

const ONSETS: [&str; 8] = ["b", "d", "k", "l", "m", "n", "s", "t"];
const NUCLEI: [&str; 4] = ["a", "e", "i", "o"];

impl Tokenizer {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab <= 8 * 4 * 8 * 4, "vocab too large for two syllables");
        Tokenizer { vocab }
    }

    /// id → word, two CV syllables: (onset·nucleus)², base-32 positional.
    pub fn decode(&self, id: u32) -> String {
        let id = id as usize % self.vocab;
        let s1 = id / 32;
        let s2 = id % 32;
        format!(
            "{}{}{}{}",
            ONSETS[s1 / 4],
            NUCLEI[s1 % 4],
            ONSETS[s2 / 4],
            NUCLEI[s2 % 4]
        )
    }

    /// word → id; None if not a valid vocabulary word.
    pub fn encode(&self, word: &str) -> Option<u32> {
        let ch: Vec<char> = word.chars().collect();
        if ch.len() != 4 {
            return None;
        }
        let onset = |c: char| ONSETS.iter().position(|&o| o == c.to_string());
        let nucleus = |c: char| NUCLEI.iter().position(|&n| n == c.to_string());
        let (o1, n1, o2, n2) = (onset(ch[0])?, nucleus(ch[1])?, onset(ch[2])?, nucleus(ch[3])?);
        let id = (o1 * 4 + n1) * 32 + o2 * 4 + n2;
        (id < self.vocab).then_some(id as u32)
    }

    pub fn decode_seq(&self, ids: &[u32]) -> String {
        ids.iter().map(|&i| self.decode(i)).collect::<Vec<_>>().join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_whole_vocab() {
        let tok = Tokenizer::new(512);
        for id in 0..512u32 {
            let w = tok.decode(id);
            assert_eq!(tok.encode(&w), Some(id), "word {w}");
        }
    }

    #[test]
    fn rejects_garbage() {
        let tok = Tokenizer::new(512);
        assert_eq!(tok.encode("xyz"), None);
        assert_eq!(tok.encode("qaqa"), None);
        assert_eq!(tok.encode(""), None);
    }

    #[test]
    fn words_distinct() {
        let tok = Tokenizer::new(512);
        let mut seen = std::collections::HashSet::new();
        for id in 0..512u32 {
            assert!(seen.insert(tok.decode(id)));
        }
    }

    #[test]
    fn decode_seq_joins() {
        let tok = Tokenizer::new(512);
        let s = tok.decode_seq(&[0, 1]);
        assert_eq!(s.split(' ').count(), 2);
    }
}
