//! Synthetic corpus substrate (WikiText2/C4 stand-ins).
//!
//! The paper's language-modeling evaluations compare quantization schemes
//! on the *same* model and corpus; any stationary corpus the model was
//! trained on exposes the deltas. We use a Zipfian first-order Markov
//! chain over token ids — the identical process (exponent, mixing map)
//! that python/compile/common.py used for training, so rust-side eval
//! batches are in-distribution.

pub mod synth;
pub mod tokenizer;

pub use synth::{CorpusGen, CorpusKind};
pub use tokenizer::Tokenizer;
