//! Zipfian Markov-chain token stream — mirror of python/compile/common.py.
//!
//! next = (prev·31 + rank·7 + 13) mod V, rank ~ Zipf(s = 1.4).
//!
//! Two named corpora stand in for the paper's two LM datasets: `Wiki2`
//! (the training distribution, seed-disjoint draw) and `C4` (a shifted
//! mixing map — mildly out-of-distribution, so perplexities are higher,
//! matching the Wiki2-vs-C4 gap in Table 2).

use crate::tensor::SplitMix64;

pub const ZIPF_S: f64 = 1.4;
pub const MIX_A: usize = 31;
pub const MIX_B: usize = 7;
pub const MIX_C: usize = 13;

/// Which evaluation corpus to draw (paper Table 2 columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    /// The training distribution (WikiText2 stand-in).
    Wiki2,
    /// 6 % of transitions use a shifted mixing constant — mildly
    /// out-of-distribution, so perplexities run ~1.3–1.5× higher than
    /// Wiki2, matching the Wiki2-vs-C4 gap of Table 2.
    C4,
}

impl CorpusKind {
    /// Probability that a transition uses the shifted map.
    fn shift_prob(self) -> f64 {
        match self {
            CorpusKind::Wiki2 => 0.0,
            CorpusKind::C4 => 0.06,
        }
    }
}

pub struct CorpusGen {
    vocab: usize,
    cdf: Vec<f64>,
    rng: SplitMix64,
    prev: usize,
    shift_prob: f64,
}

impl CorpusGen {
    pub fn new(vocab: usize, seed: u64) -> Self {
        Self::with_kind(vocab, seed, CorpusKind::Wiki2)
    }

    pub fn with_kind(vocab: usize, seed: u64, kind: CorpusKind) -> Self {
        let mut weights: Vec<f64> = (1..=vocab).map(|r| 1.0 / (r as f64).powf(ZIPF_S)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in weights.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        CorpusGen {
            vocab,
            cdf: weights,
            rng: SplitMix64::new(seed),
            prev: 0,
            shift_prob: kind.shift_prob(),
        }
    }

    pub fn next_token(&mut self) -> u32 {
        let u = self.rng.uniform();
        let rank = match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i,
        }
        .min(self.vocab - 1);
        let mix_c = if self.shift_prob > 0.0 && self.rng.uniform() < self.shift_prob {
            MIX_C + 4
        } else {
            MIX_C
        };
        let tok = (self.prev * MIX_A + rank * MIX_B + mix_c) % self.vocab;
        self.prev = tok;
        tok as u32
    }

    /// A (batch × seq) block of token ids.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<Vec<u32>> {
        (0..batch).map(|_| (0..seq).map(|_| self.next_token()).collect()).collect()
    }

    pub fn sequence(&mut self, len: usize) -> Vec<u32> {
        (0..len).map(|_| self.next_token()).collect()
    }

    /// The modal next token after `prev` (rank 0) — ground truth used by
    /// the synthetic zero-shot tasks.
    pub fn modal_next(&self, prev: u32) -> u32 {
        ((prev as usize * MIX_A + MIX_C) % self.vocab) as u32
    }

    /// Override the Markov state (used by the task generators to branch a
    /// continuation from an arbitrary predecessor token).
    pub fn set_prev(&mut self, prev: u32) {
        self.prev = prev as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = CorpusGen::new(512, 9).sequence(200);
        let b = CorpusGen::new(512, 9).sequence(200);
        assert_eq!(a, b);
    }

    #[test]
    fn tokens_in_range() {
        let s = CorpusGen::new(512, 1).sequence(5000);
        assert!(s.iter().all(|&t| t < 512));
    }

    #[test]
    fn zipf_head_heavy() {
        // rank-0 transitions should dominate: the modal next token should
        // appear after its predecessor far more often than chance.
        let mut g = CorpusGen::new(512, 2);
        let s = g.sequence(20_000);
        let mut modal_hits = 0usize;
        for w in s.windows(2) {
            if w[1] == g.modal_next(w[0]) {
                modal_hits += 1;
            }
        }
        let frac = modal_hits as f64 / (s.len() - 1) as f64;
        assert!(frac > 0.25, "modal fraction {frac}");
    }

    #[test]
    fn corpora_differ_but_share_marginals() {
        let a = CorpusGen::with_kind(512, 3, CorpusKind::Wiki2).sequence(100);
        let b = CorpusGen::with_kind(512, 3, CorpusKind::C4).sequence(100);
        assert_ne!(a, b);
    }

    #[test]
    fn seeds_decorrelate() {
        let a = CorpusGen::new(512, 4).sequence(100);
        let b = CorpusGen::new(512, 5).sequence(100);
        assert_ne!(a, b);
    }
}
