//! # crossquant
//!
//! A production-grade reproduction of **CrossQuant** (Liu, Ma, Zhang, Wang,
//! 2024): *A Post-Training Quantization Method with Smaller Quantization
//! Kernel for Precise Large Language Model Compression* — built as a
//! three-layer Rust + JAX + Pallas system.
//!
//! * **L1 (Pallas, build time)** — quantization hot-spot kernels,
//!   `python/compile/kernels/`, validated against a pure-jnp oracle.
//! * **L2 (JAX, build time)** — a GPT-style LM with in-graph activation
//!   fake-quantization, AOT-lowered to HLO text artifacts.
//! * **L3 (this crate, run time)** — the quantization library with every
//!   baseline, the kernel-analysis engine, synthetic substrates, a PJRT
//!   runtime that executes the AOT artifacts, an async eval coordinator,
//!   and the benchmark harness regenerating every table/figure of the
//!   paper.
//!
//! Quick taste (native path, no artifacts needed; `no_run` keeps rustdoc
//! from re-timing the sweep — the same assertions run for real in
//! rust/tests/property.rs):
//!
//! ```no_run
//! use crossquant::quant::{ActQuantizer, Bits, crossquant::CrossQuant, per_token::PerToken};
//! use crossquant::analysis::kernel_fraction;
//! use crossquant::activations::{ActivationGen, FamilyProfile};
//!
//! let profile = FamilyProfile::by_name("opt-66b").unwrap();
//! let x = ActivationGen::new(profile, 0).matrix(256, 256);
//! let pt = PerToken::new(Bits::Int8);
//! let cq = CrossQuant::new(0.15, Bits::Int8);
//! let k_pt = kernel_fraction(&x, &pt.delta_field(&x));
//! let k_cq = kernel_fraction(&x, &cq.delta_field(&x));
//! assert!(k_cq < k_pt); // the paper's central claim
//! ```

pub mod activations;
pub mod analysis;
pub mod coordinator;
pub mod corpus;
pub mod eval;
pub mod exp;
pub mod loadgen;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod xla;
