//! `repro` — the CrossQuant reproduction CLI (hand-rolled argument parsing;
//! the offline build has no clap — see Cargo.toml).
//!
//! Subcommands:
//!   info                 artifact + manifest inventory
//!   quantize             calibrate + write a deployable .cqa artifact
//!   inspect              print a .cqa artifact's header/sections/ratios
//!   analyze              kernel analysis across profiles (Figure-4 style)
//!   eval                 ppl + zero-shot eval of one method×setting cell
//!   serve-eval           the PJRT/coordinator path: batched eval requests
//!   serve                TCP server (optionally booted from a .cqa artifact)
//!   route                fault-tolerant tier: supervised worker fleet with
//!                        health checks, deadlines, retry/failover
//!   top                  live metrics summary of a serve/route endpoint
//!   loadtest             open-loop Poisson load generator + SLO crosscheck
//!   reproduce <id>       regenerate a paper table/figure (fig1 … tab5, all)
//!
//! Global flags: --artifacts <dir> --synthetic --eval-sequences N
//!               --task-instances N --seed N

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Result};

use crossquant::activations::{Family, FamilyProfile};
use crossquant::coordinator::scheduler::CoordinatorConfig;
use crossquant::coordinator::{ActScheme, EvalCoordinator};
use crossquant::corpus::{CorpusGen, CorpusKind};
use crossquant::eval::harness::Table;
use crossquant::exp::{
    self,
    common::{prepare, run_ppl, run_tasks, ExpOpts, Method, Setting},
};
use crossquant::model::quantized::quantize_to_artifact;
use crossquant::model::weights::{fp_weight_bytes, synthetic_weights, Weights};
use crossquant::model::ModelConfig;
use crossquant::quant::artifact::{Artifact, SectionKind};
use crossquant::quant::registry::{self, SchemeId, StaticSpec};
use crossquant::quant::Bits;
use crossquant::runtime::{ArtifactStore, Runtime};
use crossquant::util::Json;

const USAGE: &str = "usage: repro [GLOBAL FLAGS] <command> [ARGS]

commands:
  info                         artifact + manifest inventory
  quantize [--scheme S] [--alpha A] [--rank R] [--bits 4|8]
           [--calib-sequences N] [--out PATH]
                               run the registry pipeline (quantize →
                               calibrate → fold) for one static scheme
                               (crossquant-static, smoothquant, awq, gptq,
                               lorc) and write a deployable .cqa artifact
                               (default scheme: crossquant-static, out:
                               model.cqa; --rank applies to lorc)
  inspect <artifact.cqa>       print a .cqa artifact's header, sections,
                               checksums and compression ratio
  analyze                      kernel proportions across all profiles
  eval [--profile P] [--method M] [--setting S] [--alpha A] [--tasks]
  serve-eval [--requests N] [--alpha A]
  serve [--addr HOST:PORT]     TCP line-protocol eval + generation server
        [--artifact PATH]      boot from a .cqa artifact: no weights.bin, no
                               calibration; crossquant-static served zero-copy
        [--max-active-seqs N]  continuous-batching width (default 32)
        [--kv-pool-mb MB]      KV-cache arena byte budget (default: unbounded
                               up to max-active-seqs slots)
        [--admission-queue N]  waiting sequences before rejection (default 256)
        [--max-connections N]  concurrent client cap (default 256)
        [--idle-timeout-s S]   idle-connection read timeout (default 300,
                               0 disables)
        [--kernel-telemetry]   sample the quantization-kernel fraction and
                               row/column absmax per activation site on live
                               dynamic-scheme forwards ({\"cmd\": \"metrics\"}
                               gauges; off by default)
        [--kernel-threshold F] warn when a site's kernel fraction crosses F
                               (default 0.19 — the paper's OPT bound;
                               LLaMA-family sites should sit near 0.01)
        [--prefill-per-tick N] prefill admissions per engine tick (default 4)
                               — the prefill/decode fairness knob: bounds how
                               many queued prompts one tick may admit so long
                               prefills cannot starve decode progress
        [--slo-ttft-ms MS] [--slo-intertoken-ms MS] [--slo-error-rate F]
        [--slo-burn F]         SLO targets for error-budget burn-rate
                               monitoring (defaults 500 / 200 / 0.01 / 10.0);
                               {\"cmd\": \"slo\"} reports per-window burn,
                               and a sustained burn over the threshold sheds
                               priority-0 requests at admission
        [--worker]             fleet-worker mode: bind --addr (use port 0),
                               print CROSSQUANT_WORKER_READY addr=… on stdout,
                               honour a CROSSQUANT_FAULT injection plan
  route [--addr HOST:PORT]     fault-tolerant serving tier (default port 8472):
        [--num-workers N]      supervise N `serve --worker` processes (default
        [--artifact PATH]      2) over one artifact, heartbeat + restart with
        [--synthetic]          exponential backoff and a crash-loop breaker,
        [--deadline-ms MS]     route requests to the least-loaded healthy
        [--retries N]          worker with per-request deadlines (default
                               30000 ms, override per request via
                               \"deadline_ms\") and transparent retry of
                               idempotent requests (default 3 failovers);
                               {\"cmd\": \"metrics\"} aggregates the fleet
        [--heartbeat-ms MS] [--breaker-crashes N] [--ready-timeout-s S]
                               supervision knobs (defaults 250 / 5 / 30)
        [--kernel-telemetry] [--kernel-threshold F] [--prefill-per-tick N]
        [--slo-ttft-ms MS] [--slo-intertoken-ms MS] [--slo-error-rate F]
        [--slo-burn F]         forwarded to every worker; requests carry an
                               optional \"priority\" (0-3 or batch/low/
                               normal/high, default normal) — overloaded
                               tiers shed lowest-priority-first
  top [--addr HOST:PORT]       live metrics summary of a serve or route
      [--interval-ms N]        endpoint (default 127.0.0.1:8472, refresh
      [--once]                 every 1000 ms; --once prints one snapshot),
                               including the SLO burn-rate panel
  loadtest [--addr HOST:PORT]  open-loop load generator against a serve or
      [--duration-s S]         route endpoint (default 127.0.0.1:8472):
      [--rate RPS]             N clients offer a seeded-RNG Poisson request
      [--clients N]            mix (default 20 req/s over 8 clients, 10 s),
      [--preset default|overload]
      [--scenario FILE]        measure client-side TTFT / inter-token /
      [--out PATH]             total-latency histograms + per-priority
      [--p99-tolerance F]      shed/error counts, cross-check client p99
      [--no-reset]             against the server histograms (tolerance
                               default 0.5), and write BENCH_loadtest.json
                               (--no-reset skips the pre-run metrics_reset)
  bench-trend [--out PATH]     measure every served scheme (GOP/s, decode
                               tok/s, NLL) and append the rows to the
                               checked-in trend file
                               (default out: BENCH_TREND.json)
  reproduce <fig1|fig4|fig5|fig6|fig7|fig8|fig9|tab1|tab2|tab3|tab4|tab5|
             appendixA|weight-kernel|correlation|schemes|all> [--json PATH]

global flags:
  --artifacts DIR    artifacts directory (default ./artifacts)
  --synthetic        use random weights instead of trained artifacts
  --eval-sequences N perplexity eval size (default 12)
  --task-instances N instances per zero-shot task (default 40)
  --seed N           base RNG seed
";

/// Tiny argv scanner: flags may appear anywhere; first bare word is the
/// command, later bare words are positional arguments.
struct Args {
    flags: std::collections::HashMap<String, String>,
    bools: std::collections::HashSet<String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut bools = std::collections::HashSet::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if bool_flags.contains(&name) {
                    bools.insert(name.to_string());
                } else {
                    i += 1;
                    let v = argv.get(i).ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    flags.insert(name.to_string(), v.clone());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { flags, bools, positional })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: cannot parse '{v}'")),
        }
    }

    fn flag(&self, name: &str) -> bool {
        self.bools.contains(name)
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(
        &argv,
        &["synthetic", "tasks", "help", "worker", "kernel-telemetry", "once", "no-reset"],
    )?;
    if args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    let opts = ExpOpts {
        eval_sequences: args.num("eval-sequences", 12)?,
        task_instances: args.num("task-instances", 40)?,
        calib_sequences: 2,
        seed: args.num("seed", 0xC0FFEE_u64)?,
    };

    match cmd {
        "info" => info(&args),
        "quantize" => quantize(&args, &opts),
        "inspect" => {
            let path = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("inspect needs an artifact path (e.g. model.cqa)"))?;
            inspect(path)
        }
        "analyze" => analyze(&args, &opts),
        "eval" => eval_cell(
            &args,
            &opts,
            &args.get_or("profile", "llama2-7b"),
            &args.get_or("method", "crossquant"),
            &args.get_or("setting", "w8a8"),
            args.num("alpha", 0.15f32)?,
            args.flag("tasks"),
        ),
        "serve-eval" => serve_eval(&args, args.num("requests", 32usize)?, args.num("alpha", 0.15f32)?),
        "serve" => serve(&args, &args.get_or("addr", "127.0.0.1:8471")),
        "route" => route(&args, &args.get_or("addr", "127.0.0.1:8472")),
        "top" => top(&args, &args.get_or("addr", "127.0.0.1:8472")),
        "loadtest" => loadtest(&args, &args.get_or("addr", "127.0.0.1:8472")),
        "bench-trend" => bench_trend(&args),
        "reproduce" => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("reproduce needs an artefact id (fig1..tab5, all)"))?;
            reproduce(&args, &opts, id, args.get("json").map(Path::new))
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn artifacts_dir(args: &Args) -> Option<PathBuf> {
    args.get("artifacts").map(PathBuf::from)
}

fn load_weights(args: &Args, seed: u64) -> Result<Weights> {
    if args.flag("synthetic") {
        return Ok(synthetic_weights(ModelConfig::default_build(), seed));
    }
    let store = ArtifactStore::discover(artifacts_dir(args).as_deref())?;
    store.load_weights()
}

fn info(args: &Args) -> Result<()> {
    let store = ArtifactStore::discover(artifacts_dir(args).as_deref())?;
    let manifest = store.manifest()?;
    println!("artifacts dir : {}", store.dir.display());
    println!("model config  : {:?}", manifest.config);
    println!("total params  : {}", manifest.total_params);
    if let Some(t) = &manifest.train {
        println!("trained       : {} steps, final ppl {:.2}", t.steps, t.final_ppl);
    }
    println!("hlo artifacts : {:?}", store.available());
    let runtime = Runtime::new(store)?;
    println!("pjrt platform : {}", runtime.platform());
    Ok(())
}

/// "W8"/"W4"-style weight-grid label (Bits's Display is the activation
/// flavour).
fn weight_label(bits: Bits) -> String {
    match bits {
        Bits::Int4 => "W4".into(),
        Bits::Int8 => "W8".into(),
        Bits::Other(n) => format!("W{n}"),
    }
}

/// The deployment pipeline's first half: load FP weights (trained store
/// or --synthetic), run the registry's static pipeline (quantize →
/// calibrate → fold) for the requested scheme, and write the `.cqa`
/// artifact `repro serve --artifact` boots from.
fn quantize(args: &Args, opts: &ExpOpts) -> Result<()> {
    let scheme: SchemeId = args.get_or("scheme", "crossquant-static").parse()?;
    let alpha = args.num("alpha", 0.15f32)?;
    let rank = args.num("rank", crossquant::exp::registry_sweep::DEFAULT_RANK)?;
    let bits = match args.num("bits", 8u8)? {
        4 => Bits::Int4,
        8 => Bits::Int8,
        other => bail!("--bits must be 4 or 8 for the integer deployment path, got {other}"),
    };
    let n_calib = args.num("calib-sequences", 8usize)?;
    let out = PathBuf::from(args.get_or("out", "model.cqa"));
    let weights = load_weights(args, opts.seed)?;
    let cfg = weights.config;
    let mut gen = CorpusGen::new(cfg.vocab, opts.seed ^ 0x5CA1E);
    let calib: Vec<Vec<u32>> = (0..n_calib).map(|_| gen.sequence(cfg.seq_len)).collect();
    let spec = StaticSpec::new(scheme, alpha, if scheme == SchemeId::Lorc { rank } else { 0 });
    let t0 = std::time::Instant::now();
    let report = quantize_to_artifact(&weights, bits, Bits::Int8, &spec, &calib, &out)?;
    println!(
        "wrote {} ({} sections, {} bytes) in {:.2?}",
        out.display(),
        report.sections,
        report.artifact_bytes,
        t0.elapsed()
    );
    println!(
        "  scheme {}, {} weights, α = {}, calibrated on {} sequences",
        scheme.name(),
        weight_label(report.weight_bits),
        report.alpha,
        report.calib_sequences
    );
    println!(
        "  fp32 checkpoint {} bytes → {:.2}x compression",
        report.fp_bytes,
        report.compression_ratio()
    );
    println!("  inspect it: repro inspect {}", out.display());
    println!("  serve it:   repro serve --artifact {}", out.display());
    Ok(())
}

/// Print a `.cqa` artifact's header, per-section shapes/bytes/checksums,
/// and the compression ratio against the FP32 checkpoint it replaces.
fn inspect(path: &str) -> Result<()> {
    let art = Artifact::open(Path::new(path))?;
    println!("artifact        : {path}");
    println!(
        "format          : .cqa v{}  ({} sections, {} bytes, mmap: {})",
        art.version,
        art.sections().len(),
        art.file_bytes(),
        art.is_mapped()
    );
    let c = art.config;
    println!(
        "model           : vocab {}  d_model {}  layers {}  heads {}  d_ff {}  n_ctx {}",
        c.vocab, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.seq_len
    );
    let scheme = SchemeId::from_artifact_code(art.scheme)
        .map(|s| s.name().to_string())
        .unwrap_or_else(|_| format!("unknown (code {})", art.scheme));
    println!(
        "quantization    : scheme {scheme}, {} weights, {} activations, α = {}",
        weight_label(art.weight_bits),
        art.act_bits,
        art.alpha
    );
    println!();
    println!("{:<22} {:>10} {:>12} {:>10}  crc32", "section", "kind", "shape", "bytes");
    let (mut panel_bytes, mut f32_bytes) = (0usize, 0usize);
    for s in art.sections() {
        match s.kind {
            SectionKind::F32 => f32_bytes += s.len,
            SectionKind::PanelsI8 | SectionKind::PanelsI4 => panel_bytes += s.len,
        }
        println!(
            "{:<22} {:>10} {:>12} {:>10}  {:08x}",
            s.name,
            s.kind.label(),
            format!("{}x{}", s.rows, s.cols),
            s.len,
            s.crc
        );
    }
    let fp = fp_weight_bytes(&art.config);
    println!();
    println!("integer panels  : {panel_bytes} bytes");
    println!("fp32 sections   : {f32_bytes} bytes (embeddings, LN affines, scales, stats)");
    println!("fp32 checkpoint : {fp} bytes");
    let ratio = fp as f64 / art.file_bytes() as f64;
    println!("compression     : {ratio:.2}x vs the fp32 checkpoint");
    Ok(())
}

fn analyze(args: &Args, opts: &ExpOpts) -> Result<()> {
    let base = load_weights(args, opts.seed)?;
    for family in [Family::Opt, Family::Llama] {
        exp::fig4::run(&base, family, opts)?.print();
    }
    Ok(())
}

fn parse_method(m: &str, alpha: f32) -> Result<Method> {
    // one name table for the whole crate: the registry parses, and this
    // maps the offline-eval subset onto the tables' Method rows
    let id: SchemeId = m.parse()?;
    Ok(match id {
        SchemeId::Fp => Method::Fp16,
        SchemeId::PerToken => Method::PerToken,
        SchemeId::SmoothQuant => Method::SmoothQuant,
        SchemeId::CrossQuant => Method::CrossQuant { alpha },
        SchemeId::Awq => Method::Awq,
        SchemeId::CrossQuantAwq => Method::CrossQuantAwq { alpha },
        SchemeId::OmniQuant => Method::OmniQuant,
        other => bail!(
            "scheme '{}' is not an offline eval method; see `repro reproduce schemes` for \
             the registry sweep over the served schemes",
            other.name()
        ),
    })
}

fn parse_setting(s: &str) -> Result<Setting> {
    Ok(match s {
        "w8a8" => Setting::w8a8(),
        "w4a8-g128" => Setting::w4a8_g128(),
        "w4a4" => Setting::w4a4(),
        "fp" => Setting::fp(),
        _ => bail!("unknown setting {s}"),
    })
}

#[allow(clippy::too_many_arguments)]
fn eval_cell(
    args: &Args,
    opts: &ExpOpts,
    profile: &str,
    method: &str,
    setting: &str,
    alpha: f32,
    tasks: bool,
) -> Result<()> {
    let base = load_weights(args, opts.seed)?;
    let p =
        FamilyProfile::by_name(profile).ok_or_else(|| anyhow!("unknown profile {profile}"))?;
    let method = parse_method(method, alpha)?;
    let setting = if method == Method::Fp16 { Setting::fp() } else { parse_setting(setting)? };

    let mut prep = prepare(&base, &p, method, setting, opts)?;
    let wiki = run_ppl(&mut prep, CorpusKind::Wiki2, opts)?;
    let mut prep2 = prepare(&base, &p, method, setting, opts)?;
    let c4 = run_ppl(&mut prep2, CorpusKind::C4, opts)?;
    println!(
        "{} {} on {profile}: Wiki2 ppl {:.3}  C4 ppl {:.3}  ({} tokens)",
        method.label(),
        setting.label(),
        wiki.perplexity,
        c4.perplexity,
        wiki.tokens
    );
    if tasks {
        let mut prep3 = prepare(&base, &p, method, setting, opts)?;
        let (rows, avg) = run_tasks(&mut prep3, opts)?;
        for (name, r) in rows {
            println!("  {name:12} {:6.2}%  ({}/{})", r.accuracy * 100.0, r.correct, r.total);
        }
        println!("  {:12} {:6.2}%", "average", avg * 100.0);
    }
    Ok(())
}

fn serve_eval(args: &Args, requests: usize, alpha: f32) -> Result<()> {
    let store = ArtifactStore::discover(artifacts_dir(args).as_deref())?;
    store.validate()?;
    let weights = store.load_weights()?;
    let cfg = weights.config;
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("w16".to_string(), weights.flat.clone())],
        CoordinatorConfig::default(),
    );
    let mut gen = CorpusGen::new(cfg.vocab, 0xEEE);
    let seqs: Vec<Vec<u32>> = (0..requests).map(|_| gen.sequence(cfg.seq_len)).collect();

    let t0 = std::time::Instant::now();
    let (fp_nll, _) = coordinator.evaluate_stream(seqs.clone(), ActScheme::Fp, "w16")?;
    let (cq_nll, kfrac) = coordinator.evaluate_stream(
        seqs.clone(),
        ActScheme::CrossQuant { alpha, qmax: 127.0 },
        "w16",
    )?;
    let (pt_nll, pt_kfrac) = coordinator.evaluate_stream(
        seqs,
        ActScheme::CrossQuant { alpha: 1.0, qmax: 127.0 },
        "w16",
    )?;
    let dt = t0.elapsed();

    println!("PJRT coordinator eval over {requests} sequences ({dt:?}):");
    println!("  FP          ppl {:.3}", fp_nll.exp());
    println!("  CrossQuant  ppl {:.3}  (kernel {:.2}%)", cq_nll.exp(), kfrac * 100.0);
    println!("  Per-token   ppl {:.3}  (kernel {:.2}%)", pt_nll.exp(), pt_kfrac * 100.0);
    println!("  metrics: {}", coordinator.metrics.summary());
    Ok(())
}

/// The standard weight variants clients can pick a precision from.
fn weight_variants(weights: &Weights) -> Result<Vec<(String, Vec<f32>)>> {
    let mut sets = vec![("w16".to_string(), weights.flat.clone())];
    for (name, scheme) in [
        ("w8", crossquant::model::quantized::WeightScheme::PerChannel(Bits::Int8)),
        ("w4g128", crossquant::model::quantized::WeightScheme::GroupWise(Bits::Int4, 128)),
    ] {
        let mut w = weights.clone();
        crossquant::model::quantized::quantize_weights(&mut w, scheme)?;
        sets.push((name.to_string(), w.flat));
    }
    Ok(sets)
}

fn serve(args: &Args, addr: &str) -> Result<()> {
    use crossquant::coordinator::server::DEFAULT_IDLE_TIMEOUT_SECS;
    use crossquant::coordinator::{EngineConfig, EvalServer};
    use crossquant::util::FaultInjector;
    // --worker: spawned by `repro route` — no banner, machine-readable
    // ready line on stdout, deterministic fault plan from the environment
    let worker = args.flag("worker");
    // three boot modes:
    //  * --artifact P: boot from the .cqa alone — config comes from its
    //    header, weights.bin is never read, no calibration runs; the
    //    "w16" set serves crossquant-static straight off the mapping
    //  * --synthetic: random weights, full scheme surface, no disk state
    //  * default: the trained artifacts store
    let dir = artifacts_dir(args).unwrap_or_else(|| PathBuf::from("artifacts"));
    // the last tuple element is the α the printed request examples use —
    // an artifact serves only its own α, so the examples interpolate it
    let (store, cfg, sets, mounts, example_alpha, example_scheme) = if let Some(apath) =
        args.get("artifact")
    {
        let apath = PathBuf::from(apath);
        // this open feeds the engine config + banner; the executor thread
        // re-opens and retains its own mapping at mount (a second
        // full-file validation at startup — accepted so the config
        // surface stays a plain path and mount errors stay request-visible
        // through the executor's MountState)
        let art = Artifact::open(&apath)?;
        let scheme = SchemeId::from_artifact_code(art.scheme)?;
        if !worker {
            println!(
                "mounted artifact {} (scheme {}, α = {}, {} weights, {} sections, {} bytes)",
                apath.display(),
                scheme.name(),
                art.alpha,
                weight_label(art.weight_bits),
                art.sections().len(),
                art.file_bytes()
            );
        }
        let mounts = vec![("w16".to_string(), apath)];
        (ArtifactStore { dir }, art.config, Vec::new(), mounts, art.alpha, scheme.name())
    } else if args.flag("synthetic") {
        // random weights with no artifacts on disk: the native executor
        // handles every scheme, so the full protocol is demoable anywhere
        let weights = synthetic_weights(ModelConfig::default_build(), args.num("seed", 0u64)?);
        let cfg = weights.config;
        (ArtifactStore { dir }, cfg, weight_variants(&weights)?, Vec::new(), 0.15, "crossquant-static")
    } else {
        let store = ArtifactStore::discover(artifacts_dir(args).as_deref())?;
        store.validate()?;
        let weights = store.load_weights()?;
        let cfg = weights.config;
        let sets = weight_variants(&weights)?;
        (store, cfg, sets, Vec::new(), 0.15, "crossquant-static")
    };

    let defaults = EngineConfig::default();
    let max_active = args.num("max-active-seqs", defaults.max_active_seqs)?;
    let engine = EngineConfig {
        max_active_seqs: max_active,
        kv_pool_bytes: match args.get("kv-pool-mb") {
            None => defaults.kv_pool_bytes,
            Some(_) => Some(args.num::<usize>("kv-pool-mb", 0)? * 1024 * 1024),
        },
        max_waiting: args.num("admission-queue", defaults.max_waiting)?,
        max_prefills_per_tick: args.num("prefill-per-tick", defaults.max_prefills_per_tick)?,
    };
    let max_connections = args.num("max-connections", 256usize)?;
    let idle_secs = args.num("idle-timeout-s", DEFAULT_IDLE_TIMEOUT_SECS)?;
    let idle_timeout =
        if idle_secs == 0 { None } else { Some(std::time::Duration::from_secs(idle_secs)) };
    // absent env → inactive injector; a malformed plan is a hard startup
    // error (a silently ignored fault plan would fake test passes)
    let fault = std::sync::Arc::new(FaultInjector::from_env()?);
    let artifact_only = !mounts.is_empty();
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        sets,
        CoordinatorConfig { engine, artifacts: mounts, ..Default::default() },
    );
    let kernel_telemetry = args.flag("kernel-telemetry");
    let kernel_threshold =
        args.num("kernel-threshold", crossquant::obs::DEFAULT_KERNEL_THRESHOLD)?;
    // stride 8: sample every 8th dynamic-scheme forward per site — cheap
    // enough to leave on, dense enough to catch a drifting site fast
    coordinator.metrics.kernel.configure(kernel_telemetry, kernel_threshold, 8);
    let slo_defaults = crossquant::obs::SloSpec::default();
    let slo_spec = crossquant::obs::SloSpec {
        ttft_p99_us: args.num("slo-ttft-ms", slo_defaults.ttft_p99_us / 1000)? * 1000,
        inter_token_p99_us: args.num("slo-intertoken-ms", slo_defaults.inter_token_p99_us / 1000)?
            * 1000,
        error_rate: args.num("slo-error-rate", slo_defaults.error_rate)?,
        burn_threshold: args.num("slo-burn", slo_defaults.burn_threshold)?,
    };
    coordinator.metrics.slo.configure(slo_spec);
    let listener = std::net::TcpListener::bind(addr)?;
    if worker {
        // the supervisor parses this exact line for the dispatch address
        use std::io::Write as _;
        let local = listener.local_addr()?;
        println!("{}{local}", crossquant::coordinator::fleet::READY_PREFIX);
        std::io::stdout().flush()?;
        if fault.is_active() {
            crossquant::obs::log::info("serve", "fault injection active", &[]);
        }
    } else {
        println!("serving quantized-LM evaluation + generation on {addr}");
        if artifact_only {
            println!(
                "  artifact-only: \"w16\" serves scheme \"{example_scheme}\" (mmap, zero-copy)"
            );
        } else {
            println!("  weight sets: w16, w8, w4g128 — protocol: one JSON per line");
        }
        println!(
            "  continuous batching: {max_active} max active seqs, {max_connections} max connections"
        );
        println!(
            "  score:    echo '{{\"tokens\": [1,2,3,4,5], \"scheme\": \"{example_scheme}\", \
             \"alpha\": {example_alpha}}}' | nc {addr}"
        );
        println!(
            "  generate: echo '{{\"tokens\": [1,2,3,4,5], \"scheme\": \"{example_scheme}\", \
             \"alpha\": {example_alpha}, \"max_new_tokens\": 8}}' | nc {addr}"
        );
        println!(
            "  stream:   add \"stream\": true for one {{\"token\": ...}} line per decoded token"
        );
        println!(
            "  observe:  add \"trace\": \"my-request\" to any request, then \
             '{{\"cmd\": \"trace\", \"id\": \"my-request\"}}' for its spans; \
             {{\"cmd\": \"metrics\"}} (+ \"format\": \"prometheus\") for telemetry"
        );
        println!(
            "  slo:      ttft p99 <= {}ms, inter-token p99 <= {}ms, errors <= {:.2}% \
             (shed priority 0 at burn >= {}x) — {{\"cmd\": \"slo\"}} for the burn report",
            slo_spec.ttft_p99_us / 1000,
            slo_spec.inter_token_p99_us / 1000,
            slo_spec.error_rate * 100.0,
            slo_spec.burn_threshold
        );
        if kernel_telemetry {
            println!(
                "  kernel telemetry on: per-site quantization-kernel gauges, warn at {kernel_threshold}"
            );
        }
    }
    EvalServer::new(coordinator)
        .with_max_connections(max_connections)
        .with_idle_timeout(idle_timeout)
        .with_fault_injector(fault)
        .serve(listener)
}

/// Process-wide shutdown flag flipped by SIGTERM/SIGINT. Signal handlers
/// may only do async-signal-safe work; storing to an atomic qualifies.
static SHUTDOWN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: i32) {
    SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Install SIGTERM/SIGINT handlers without a libc dependency (the same
/// pattern as the raw mmap bindings in util/mmap.rs).
fn install_shutdown_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_shutdown_signal);
        signal(SIGTERM, on_shutdown_signal);
    }
}

/// The fault-tolerant serving tier: a supervised fleet of `serve
/// --worker` processes behind a deadline-enforcing, retrying router.
/// SIGTERM drains in-flight requests before the fleet is torn down.
fn route(args: &Args, addr: &str) -> Result<()> {
    use crossquant::coordinator::{Fleet, FleetConfig, FleetMetrics, Router, RouterConfig};
    use std::sync::atomic::Ordering;
    use std::sync::Arc;
    use std::time::Duration;

    let num_workers = args.num("num-workers", 2usize)?;
    let exe = std::env::current_exe()?;
    // workers bind an ephemeral port and report it via their ready line
    let mut worker_args: Vec<String> =
        ["serve", "--worker", "--addr", "127.0.0.1:0"].iter().map(|s| s.to_string()).collect();
    if args.flag("synthetic") {
        worker_args.push("--synthetic".to_string());
    }
    if args.flag("kernel-telemetry") {
        worker_args.push("--kernel-telemetry".to_string());
    }
    for flag in [
        "artifact",
        "artifacts",
        "seed",
        "max-active-seqs",
        "kv-pool-mb",
        "admission-queue",
        "max-connections",
        "idle-timeout-s",
        "kernel-threshold",
        "prefill-per-tick",
        "slo-ttft-ms",
        "slo-intertoken-ms",
        "slo-error-rate",
        "slo-burn",
    ] {
        if let Some(v) = args.get(flag) {
            worker_args.push(format!("--{flag}"));
            worker_args.push(v.to_string());
        }
    }
    let defaults = FleetConfig::default();
    let ready_timeout = Duration::from_secs(args.num("ready-timeout-s", 30u64)?);
    let fleet_cfg = FleetConfig {
        num_workers,
        worker_cmd: exe,
        worker_args,
        heartbeat_interval: Duration::from_millis(args.num("heartbeat-ms", 250u64)?),
        breaker_crashes: args.num("breaker-crashes", defaults.breaker_crashes)?,
        ready_timeout,
        ..defaults
    };
    let fleet = Arc::new(Fleet::start(fleet_cfg, Arc::new(FleetMetrics::new()))?);
    fleet.wait_ready(ready_timeout)?;
    let router_cfg = RouterConfig {
        default_deadline: Duration::from_millis(args.num("deadline-ms", 30_000u64)?),
        max_retries: args.num("retries", 3usize)?,
        ..Default::default()
    };
    let default_deadline = router_cfg.default_deadline;
    let max_retries = router_cfg.max_retries;
    let router = Router::new(fleet.clone(), router_cfg);

    install_shutdown_handlers();
    let watcher = router.clone();
    std::thread::spawn(move || {
        while !SHUTDOWN.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(50));
        }
        watcher.request_shutdown();
    });

    let listener = std::net::TcpListener::bind(addr)?;
    println!("routing across {num_workers} workers on {addr}");
    for w in fleet.status() {
        let a = w.addr.map_or("<down>".to_string(), |a| a.to_string());
        println!("  worker {}: {a} (pid {})", w.index, w.pid.unwrap_or(0));
    }
    println!(
        "  deadlines: {} ms default (per-request \"deadline_ms\"), {} failover retries",
        default_deadline.as_millis(),
        max_retries
    );
    println!("  metrics:  echo '{{\"cmd\": \"metrics\"}}' | nc {addr}");
    println!("  tracing:  every request gets a trace id (echoed in its response); \
              '{{\"cmd\": \"trace\", \"id\": ID}}' merges spans across the fleet");
    router.serve(listener)?;
    crossquant::obs::log::info("route", "shutdown: draining in-flight requests", &[]);
    if !router.drain(Duration::from_secs(10)) {
        crossquant::obs::log::warn(
            "route",
            "drain timed out",
            &[("in_flight", router.in_flight().to_string())],
        );
    }
    fleet.shutdown();
    Ok(())
}

/// Poll an endpoint's `{"cmd": "metrics"}` and render a live one-screen
/// summary — latency quantiles, engine occupancy, per-site
/// quantization-kernel gauges. Understands both response shapes: a
/// worker (`serve`) reports counters/engine/latency/kernel, a router
/// (`route`) reports router/fleet/workers/aggregate.
fn top(args: &Args, addr: &str) -> Result<()> {
    use std::io::Write as _;
    let interval = std::time::Duration::from_millis(args.num("interval-ms", 1000u64)?);
    let once = args.flag("once");
    loop {
        let out = match fetch_metrics(addr) {
            // the slo fetch is best-effort: an old worker without the
            // command still renders everything else
            Ok(resp) => {
                let slo = fetch_cmd(addr, "slo").ok();
                render_top(&resp, slo.as_ref(), addr)
            }
            Err(e) => format!("repro top — {addr}\n  (metrics fetch failed: {e})\n"),
        };
        if once {
            print!("{out}");
            return Ok(());
        }
        // ANSI home + clear keeps the refresh flicker-free
        print!("\x1b[H\x1b[2J{out}");
        std::io::stdout().flush()?;
        std::thread::sleep(interval);
    }
}

fn fetch_metrics(addr: &str) -> Result<Json> {
    fetch_cmd(addr, "metrics")
}

fn fetch_cmd(addr: &str, cmd: &str) -> Result<Json> {
    use std::io::{BufRead, BufReader, Write as _};
    let stream = std::net::TcpStream::connect(addr)?;
    let timeout = Some(std::time::Duration::from_secs(2));
    stream.set_read_timeout(timeout)?;
    stream.set_write_timeout(timeout)?;
    let mut writer = stream.try_clone()?;
    writer.write_all(Json::obj(vec![("cmd", Json::str(cmd))]).render().as_bytes())?;
    writer.write_all(b"\n")?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    Json::parse(&line)
}

/// Format a microsecond value human-readably.
fn fmt_us(us: f64) -> String {
    if us < 1_000.0 {
        format!("{us:.0}us")
    } else if us < 1_000_000.0 {
        format!("{:.1}ms", us / 1_000.0)
    } else {
        format!("{:.2}s", us / 1_000_000.0)
    }
}

fn render_top(resp: &Json, slo: Option<&Json>, addr: &str) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "repro top — {addr}");
    let num = |o: &Json, k: &str| o.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);

    if let Some(router) = resp.get("router") {
        let _ = writeln!(
            out,
            "router    requests {:.0}  ok {:.0}  retried {:.0}  deadline {:.0}  shed {:.0}",
            num(router, "requests"),
            num(router, "succeeded"),
            num(router, "retried"),
            num(router, "deadline_exceeded"),
            num(router, "shed"),
        );
    }
    if let Some(fleet) = resp.get("fleet") {
        let _ = writeln!(
            out,
            "fleet     crashes {:.0}  restarts {:.0}  wedged {:.0}  breaker_trips {:.0}",
            num(fleet, "worker_crashes"),
            num(fleet, "worker_restarts"),
            num(fleet, "worker_wedged"),
            num(fleet, "breaker_trips"),
        );
    }
    if let Some(Json::Arr(workers)) = resp.get("workers") {
        for w in workers {
            let healthy = w.get("healthy") == Some(&Json::Bool(true));
            let _ = writeln!(
                out,
                "  worker {:.0} {}  {}  in_flight {:.0}  restarts {:.0}",
                num(w, "index"),
                if healthy { "up  " } else { "DOWN" },
                w.get("addr").and_then(|a| a.as_str()).unwrap_or("<none>"),
                num(w, "in_flight"),
                num(w, "restarts"),
            );
        }
    }
    // flat counters: a worker's own, or the fleet-summed aggregate
    for key in ["counters", "aggregate"] {
        if let Some(Json::Obj(fields)) = resp.get(key) {
            let _ = write!(out, "{key:<9}");
            for (i, (k, v)) in fields.iter().enumerate() {
                if let Some(n) = v.as_f64() {
                    if i > 0 && i % 5 == 0 {
                        let _ = write!(out, "\n         ");
                    }
                    let _ = write!(out, " {k} {n:.0}");
                }
            }
            let _ = writeln!(out);
        }
    }
    if let Some(engine) = resp.get("engine") {
        let _ = writeln!(
            out,
            "engine    active {:.0}  queue {:.0}  occupancy {:.2}  decode {:.1} tok/s",
            num(engine, "active_seqs"),
            num(engine, "queue_depth"),
            num(engine, "batch_occupancy"),
            num(engine, "decode_tok_s"),
        );
    }
    if let Some(latency) = resp.get("latency") {
        let _ = writeln!(out, "latency             n      p50      p95      p99   w10s(n/p50/p99)");
        for name in ["request", "ttft", "inter_token", "queue_wait", "batch_forward"] {
            let Some(track) = latency.get(name) else { continue };
            let total = track.get("total").unwrap_or(&Json::Null);
            let w10 = track.get("w10s").unwrap_or(&Json::Null);
            let _ = writeln!(
                out,
                "  {name:<14} {:6.0} {:>8} {:>8} {:>8}   {:.0}/{}/{}",
                num(total, "count"),
                fmt_us(num(total, "p50_us")),
                fmt_us(num(total, "p95_us")),
                fmt_us(num(total, "p99_us")),
                num(w10, "count"),
                fmt_us(num(w10, "p50_us")),
                fmt_us(num(w10, "p99_us")),
            );
        }
    }
    // SLO panel: a worker answers {"slo": report}, a router fans out and
    // answers {"workers": [{index, slo}], "shedding"}
    if let Some(slo) = slo {
        if let Some(report) = slo.get("slo") {
            render_slo_report(&mut out, report, None);
        } else if let Some(Json::Arr(rows)) = slo.get("workers") {
            for row in rows {
                if let Some(report) = row.get("slo") {
                    render_slo_report(&mut out, report, Some(num(row, "index") as usize));
                }
            }
        }
    }
    if let Some(kernel) = resp.get("kernel") {
        if let Some(Json::Arr(sites)) = kernel.get("sites") {
            if !sites.is_empty() {
                let _ = writeln!(
                    out,
                    "kernel    threshold {:.2}  ({} sites sampled)",
                    num(kernel, "threshold"),
                    sites.len()
                );
            }
            for s in sites {
                let over = s.get("over_threshold") == Some(&Json::Bool(true));
                let _ = writeln!(
                    out,
                    "  site {:>3}  kernel {:6.3}%  row {:8.3}  col {:8.3}  n {:.0}{}",
                    num(s, "site"),
                    num(s, "kernel_fraction") * 100.0,
                    num(s, "row_absmax_mean"),
                    num(s, "col_absmax_mean"),
                    num(s, "samples"),
                    if over { "  OVER-THRESHOLD" } else { "" },
                );
            }
        }
    }
    out
}

/// One SLO burn-rate block: the spec line, then one line per window with
/// its fast/slow burn and alert state.
fn render_slo_report(out: &mut String, report: &Json, worker: Option<usize>) {
    use std::fmt::Write as _;
    let num = |o: &Json, k: &str| o.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let spec = report.get("spec").cloned().unwrap_or(Json::Null);
    let label = worker.map_or_else(|| "slo".to_string(), |i| format!("slo w{i}"));
    let shedding = report.get("shedding") == Some(&Json::Bool(true));
    let _ = writeln!(
        out,
        "{label:<9} ttft p99<={}  itl p99<={}  err<={:.2}%  alert at burn>={:.0}x{}",
        fmt_us(num(&spec, "ttft_p99_us")),
        fmt_us(num(&spec, "inter_token_p99_us")),
        num(&spec, "error_rate") * 100.0,
        num(&spec, "burn_threshold"),
        if shedding { "  SHEDDING" } else { "" },
    );
    if let Some(Json::Arr(windows)) = report.get("windows") {
        for w in windows {
            let alerting = w.get("alerting") == Some(&Json::Bool(true));
            let _ = writeln!(
                out,
                "  w{:<3.0}s  burn {:7.2}  (ttft {:.2}  itl {:.2}  err {:.2})  n {:.0}{}",
                num(w, "window_s"),
                num(w, "max_burn"),
                num(w, "ttft_burn"),
                num(w, "inter_token_burn"),
                num(w, "error_burn"),
                num(w, "requests"),
                if alerting { "  ALERT" } else { "" },
            );
        }
    }
}

/// Open-loop load test against a live `serve`/`route` endpoint: offer a
/// seeded Poisson request mix, then write the offered-vs-achieved
/// throughput, client-side latency histograms, per-priority shed matrix,
/// and the client-vs-server p99 crosscheck to BENCH_loadtest.json.
fn loadtest(args: &Args, addr: &str) -> Result<()> {
    use crossquant::loadgen::{self, LoadtestConfig, Scenario};

    let scenario = match args.get("scenario") {
        Some(path) => Scenario::from_file(Path::new(path))?,
        None => Scenario::preset(&args.get_or("preset", "default"))?,
    };
    let cfg = LoadtestConfig {
        addr: addr.to_string(),
        duration_s: args.num("duration-s", 10.0f64)?,
        rate: args.num("rate", 20.0f64)?,
        clients: args.num("clients", 8usize)?,
        seed: args.num("seed", 1u64)?,
        scenario,
        p99_tolerance: args.num("p99-tolerance", 0.5f64)?,
        reset: !args.flag("no-reset"),
    };
    ensure!(cfg.duration_s > 0.0, "--duration-s must be > 0");
    ensure!(cfg.rate > 0.0, "--rate must be > 0");
    println!(
        "offering {:.1} req/s across {} clients to {} for {:.0}s (seed {})",
        cfg.rate, cfg.clients, cfg.addr, cfg.duration_s, cfg.seed
    );
    let report = loadgen::run(&cfg)?;
    let out = PathBuf::from(args.get_or("out", "BENCH_loadtest.json"));
    std::fs::write(&out, report.render_pretty())?;

    let num = |o: &Json, k: &str| o.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let client = report.get("client").cloned().unwrap_or(Json::Null);
    let ttft = client.get("ttft").cloned().unwrap_or(Json::Null);
    println!(
        "offered {:.1} rps, achieved {:.1} rps  (sent {:.0}, ok {:.0}, shed {:.0}, errors {:.0})",
        num(&report, "offered_rps"),
        num(&report, "achieved_rps"),
        num(&client, "sent"),
        num(&client, "ok"),
        num(&client, "shed"),
        num(&client, "errors"),
    );
    println!(
        "client ttft  p50 {}  p95 {}  p99 {}  ({:.0} streamed samples)",
        fmt_us(num(&ttft, "p50_us")),
        fmt_us(num(&ttft, "p95_us")),
        fmt_us(num(&ttft, "p99_us")),
        num(&ttft, "count"),
    );
    if let Some(check) = report.get("crosscheck") {
        match check.get("within_tolerance") {
            Some(Json::Bool(ok)) => println!(
                "crosscheck  client p99 {} vs server p99 {}  rel_err {:.3}  -> {}",
                fmt_us(num(check, "ttft_p99_client_us")),
                fmt_us(num(check, "ttft_p99_server_us")),
                num(check, "rel_err"),
                if *ok { "AGREE" } else { "DISAGREE" },
            ),
            _ => println!("crosscheck  skipped (no streamed samples on one side)"),
        }
    }
    println!("wrote {}", out.display());
    Ok(())
}

/// Measure every served scheme on a small fixed synthetic model —
/// scoring throughput (GOP/s over the checkpoint's linear work),
/// KV-cached greedy decode rate (tok/s), and mean NLL — and append the
/// rows to the checked-in trend file, so the CI history shows when a
/// scheme's speed or quality moves.
fn bench_trend(args: &Args) -> Result<()> {
    use crossquant::exp::registry_sweep::{served_schemes, DEFAULT_RANK};
    use crossquant::model::{ActSite, IdentitySite, NativeModel, QuantSite};
    use crossquant::quant::crossquant::CrossQuant;

    let out = PathBuf::from(args.get_or("out", "BENCH_TREND.json"));
    let cfg = ModelConfig {
        vocab: 128,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        seq_len: 48,
        eval_batch: 2,
    };
    let alpha = 0.15f32;
    let weights = synthetic_weights(cfg, 0xBE7C);
    let native = NativeModel::new(weights.clone());
    let mut gen = CorpusGen::new(cfg.vocab, 0x5CA1E);
    let calib: Vec<Vec<u32>> = (0..4).map(|_| gen.sequence(cfg.seq_len)).collect();
    let probe: Vec<u32> = (0..cfg.seq_len).map(|i| ((i * 7) % cfg.vocab) as u32).collect();
    let prompt = &probe[..8];
    let new_tokens = 24usize;
    // per-token linear work ≈ one multiply-add through every weight
    let ops_per_token = 2.0 * weights.flat.len() as f64;

    let mut rows: Vec<Json> = match std::fs::read_to_string(&out) {
        Ok(s) => match Json::parse(&s)? {
            Json::Arr(v) => v,
            _ => bail!("{} is not a JSON array of trend rows", out.display()),
        },
        Err(_) => Vec::new(),
    };
    let run_id = rows.len();
    // which GEMM microkernel served this run — trend rows are only
    // comparable within one ISA (scalar vs avx2 is the point of the row)
    let isa = crossquant::quant::gemm::dispatch::active().name();

    let measure_native = |site: &mut dyn ActSite| -> Result<(f64, f64, f64)> {
        let t0 = std::time::Instant::now();
        let nll_v = native.forward_nll(&probe, site)?;
        let gops = ops_per_token * probe.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e9;
        let nll = nll_v.iter().map(|&v| v as f64).sum::<f64>() / nll_v.len().max(1) as f64;
        let t1 = std::time::Instant::now();
        let toks = native.generate_greedy(prompt, new_tokens, site)?;
        let tok_s = toks.len() as f64 / t1.elapsed().as_secs_f64().max(1e-9);
        Ok((nll, gops, tok_s))
    };

    println!("{:<20} {:>10} {:>14} {:>10}", "scheme", "GOP/s", "decode tok/s", "NLL");
    for id in served_schemes() {
        let (nll, gops, tok_s) = match id {
            SchemeId::Fp => measure_native(&mut IdentitySite)?,
            SchemeId::PerToken | SchemeId::CrossQuant => {
                let eff = registry::effective_alpha(id, alpha);
                measure_native(&mut QuantSite::new(CrossQuant::new(eff, Bits::Int8)))?
            }
            _ => {
                let rank = if id == SchemeId::Lorc { DEFAULT_RANK } else { 0 };
                let spec = StaticSpec::new(id, alpha, rank);
                let qm =
                    registry::build_static_model(&weights, Bits::Int8, Bits::Int8, &spec, &calib)?;
                let t0 = std::time::Instant::now();
                let nll_v = qm.forward_nll(&probe)?;
                let gops = ops_per_token * probe.len() as f64
                    / t0.elapsed().as_secs_f64().max(1e-9)
                    / 1e9;
                let nll = nll_v.iter().map(|&v| v as f64).sum::<f64>() / nll_v.len().max(1) as f64;
                let t1 = std::time::Instant::now();
                let toks = qm.generate_greedy(prompt, new_tokens)?;
                let tok_s = toks.len() as f64 / t1.elapsed().as_secs_f64().max(1e-9);
                (nll, gops, tok_s)
            }
        };
        println!("{:<20} {gops:>10.2} {tok_s:>14.1} {nll:>10.3}", id.name());
        rows.push(Json::obj(vec![
            ("run", Json::num(run_id as f64)),
            ("scheme", Json::str(id.name())),
            ("isa", Json::str(isa)),
            ("gops", Json::num(gops)),
            ("decode_tok_s", Json::num(tok_s)),
            ("nll", Json::num(nll)),
            // rows this binary measured are stamped; the two hand-seeded
            // offline-estimate rows in the checked-in file carry false
            ("measured", Json::Bool(true)),
        ]));
    }
    // a trend run that appends nothing is a broken registry or a broken
    // measure loop — fail here rather than let CI commit a no-op "run"
    ensure!(
        rows.len() > run_id,
        "bench-trend appended no rows (served_schemes() is empty?) — refusing to write {}",
        out.display()
    );
    std::fs::write(&out, Json::Arr(rows).render_pretty())?;
    println!(
        "appended {} rows (run {run_id}, isa {isa}) to {}",
        rows.len() - run_id,
        out.display()
    );
    Ok(())
}

fn reproduce(args: &Args, opts: &ExpOpts, id: &str, json: Option<&Path>) -> Result<()> {
    let base = load_weights(args, opts.seed)?;
    let mut tables: Vec<Table> = Vec::new();
    let ids: Vec<&str> = if id == "all" {
        vec![
            "fig1", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "tab1", "tab2", "tab3",
            "tab4", "tab5", "appendixA", "weight-kernel", "correlation", "schemes",
        ]
    } else {
        vec![id]
    };
    for id in ids {
        let before = tables.len();
        match id {
            "fig1" => tables.push(exp::fig1::run(&base, Bits::Int8, opts)?),
            "fig9" => tables.push(exp::fig1::run(&base, Bits::Int4, opts)?),
            "fig4" => {
                tables.push(exp::fig4::run(&base, Family::Opt, opts)?);
                tables.push(exp::fig4::run(&base, Family::Llama, opts)?);
            }
            "fig5" => {
                for family in [Family::Opt, Family::Llama] {
                    tables.push(exp::fig5::run(&base, family, Setting::w8a8(), opts)?);
                    tables.push(exp::fig5::run(&base, family, Setting::w4a8_g128(), opts)?);
                }
            }
            "fig6" | "fig7" => {
                let family = if id == "fig6" { Family::Opt } else { Family::Llama };
                let r = exp::fig67::run(&base, family, opts)?;
                for (name, th) in &r.thresholds {
                    match th {
                        Some(t) => println!("  threshold[{name}] ≈ {:.1}% (5% ppl tol)", t * 100.0),
                        None => println!("  threshold[{name}]: none within sweep"),
                    }
                }
                tables.push(r.table);
            }
            "fig8" => tables.push(exp::fig8::run(&base, opts)?),
            "tab1" => tables.push(exp::tab1::run(&base, opts)?),
            "tab2" => tables.push(exp::tab2::run(&base, opts)?),
            "tab3" => tables.extend(exp::tab3::run(&base, &["opt-30b", "opt-66b"], false, opts)?),
            "tab4" => tables.push(exp::tab4::run(&base, opts)?),
            "appendixA" | "appa" => tables.push(exp::appendix_a::run(&base, opts)?),
            "correlation" => tables.push(exp::correlation::run(&base, opts)?),
            "schemes" => tables.push(exp::registry_sweep::run(&base, opts)?),
            "weight-kernel" | "appb" => tables.push(exp::weight_kernel::run(&base, opts)?),
            "tab5" => tables.extend(exp::tab3::run(
                &base,
                &["opt-1.3b", "opt-2.3b", "opt-6.7b", "opt-13b"],
                true,
                opts,
            )?),
            other => bail!("unknown artefact id {other}"),
        }
        for t in &tables[before..] {
            t.print();
            println!();
        }
    }
    if let Some(path) = json {
        let all = Json::arr(tables.iter().map(|t| t.to_json()).collect());
        std::fs::write(path, all.render_pretty())?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
