//! The L3 coordinator: an async evaluation-serving layer over the PJRT
//! runtime — request routing, scheme-keyed dynamic batching, a dedicated
//! executor thread owning the (non-Send) PJRT client, backpressure, and
//! metrics. This is the paper-system's "serving" shell: quantized-LM
//! evaluation requests go in, per-token NLLs come out, Python nowhere on
//! the path. Generation requests are served by the continuous-batching
//! [`engine`] (pooled KV slots, step-granular admission, per-token
//! streaming) instead of one serial decode loop per request.

pub mod batcher;
pub mod engine;
pub mod fleet;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;

pub use engine::{EngineConfig, GenEvent, KvPool};
pub use fleet::{Fleet, FleetConfig, WorkerStatus};
pub use metrics::{FleetMetrics, Metrics};
pub use router::{Router, RouterConfig};
pub use scheduler::{EvalCoordinator, EvalRequest, EvalResponse, RequestKind};
pub use server::EvalServer;

use crate::quant::registry::{SchemeId, StaticSpec};
use crate::util::Json;

/// Parse a `"priority"` wire field — shared by the worker server and the
/// router so the two can never disagree about what a class name means.
/// Accepts a plain number (clamped to the highest class) or a named
/// class; returns `None` for anything else so callers can reject the
/// request with a structured error instead of silently defaulting.
pub fn parse_priority(v: &Json) -> Option<u8> {
    match v {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => {
            Some((*n as u64).min(metrics::NUM_PRIORITIES as u64 - 1) as u8)
        }
        Json::Str(s) => match s.as_str() {
            "batch" | "best-effort" => Some(0),
            "low" => Some(1),
            "normal" => Some(2),
            "high" | "interactive" => Some(3),
            other => match other.parse::<u64>() {
                Ok(n) => Some(n.min(metrics::NUM_PRIORITIES as u64 - 1) as u8),
                Err(_) => None,
            },
        },
        _ => None,
    }
}

/// Activation-quantization scheme of a request — maps onto one AOT
/// artifact plus its runtime scalar inputs. The static variants (from
/// [`ActScheme::CrossQuantStatic`] down) are all served by the native
/// executor's `QuantizedModel`, built through the scheme registry's one
/// pipeline ([`crate::quant::registry::build_static_model`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActScheme {
    /// FP forward (`lm_fp`).
    Fp,
    /// CrossQuant with runtime α / qmax (`lm_aq`); α = 1.0 is per-token.
    CrossQuant { alpha: f32, qmax: f32 },
    /// Same graph, pure-jnp (XLA-fused) quantization path (`lm_aq_jnp`).
    CrossQuantFused { alpha: f32, qmax: f32 },
    /// Calibrated static-scale CrossQuant on the true-integer path
    /// (`lm_aq_static`): weights pre-folded with calibration-derived
    /// ĉ^(1−α), zero per-batch rescale. Served by the native executor's
    /// `QuantizedModel`; no PJRT artifact exists for it yet.
    CrossQuantStatic { alpha: f32, qmax: f32 },
    /// Remove-kernel ablation with zero-bound multiplier θ (`lm_rk`).
    RemoveKernel { theta: f32 },
    /// SmoothQuant: scale migration folded into the weights, per-token
    /// static fold (`lm_sq`).
    SmoothQuant { alpha: f32, qmax: f32 },
    /// AWQ: activation-aware weight scales folded in, served static
    /// (`lm_awq_s`).
    Awq { alpha: f32, qmax: f32 },
    /// GPTQ error-minimising weight rounding on the static fold
    /// (`lm_gptq`).
    Gptq { alpha: f32, qmax: f32 },
    /// Static fold plus rank-r LoRC residual correction (`lm_lorc`).
    Lorc { alpha: f32, rank: usize, qmax: f32 },
}

impl ActScheme {
    pub fn artifact(&self) -> &'static str {
        match self {
            ActScheme::Fp => "lm_fp",
            ActScheme::CrossQuant { .. } => "lm_aq",
            ActScheme::CrossQuantFused { .. } => "lm_aq_jnp",
            ActScheme::CrossQuantStatic { .. } => "lm_aq_static",
            ActScheme::RemoveKernel { .. } => "lm_rk",
            ActScheme::SmoothQuant { .. } => "lm_sq",
            ActScheme::Awq { .. } => "lm_awq_s",
            ActScheme::Gptq { .. } => "lm_gptq",
            ActScheme::Lorc { .. } => "lm_lorc",
        }
    }

    /// Extra scalar literals after (tokens, weights).
    pub fn scalars(&self) -> Vec<f32> {
        match *self {
            ActScheme::Fp => vec![],
            ActScheme::CrossQuant { alpha, qmax }
            | ActScheme::CrossQuantFused { alpha, qmax }
            | ActScheme::CrossQuantStatic { alpha, qmax }
            | ActScheme::SmoothQuant { alpha, qmax }
            | ActScheme::Awq { alpha, qmax }
            | ActScheme::Gptq { alpha, qmax } => vec![alpha, qmax],
            ActScheme::Lorc { alpha, rank, qmax } => vec![alpha, rank as f32, qmax],
            ActScheme::RemoveKernel { theta } => vec![theta],
        }
    }

    /// The registry build spec when this scheme is served by the
    /// calibrated integer model, plus its requested activation grid —
    /// `None` for the FP/dynamic schemes. This is the single dispatch
    /// point that used to be a scattered `CrossQuantStatic` match arm in
    /// the scheduler, engine and server.
    pub fn static_spec(&self) -> Option<(StaticSpec, f32)> {
        match *self {
            ActScheme::CrossQuantStatic { alpha, qmax } => {
                Some((StaticSpec::new(SchemeId::CrossQuantStatic, alpha, 0), qmax))
            }
            ActScheme::SmoothQuant { alpha, qmax } => {
                Some((StaticSpec::new(SchemeId::SmoothQuant, alpha, 0), qmax))
            }
            ActScheme::Awq { alpha, qmax } => {
                Some((StaticSpec::new(SchemeId::Awq, alpha, 0), qmax))
            }
            ActScheme::Gptq { alpha, qmax } => {
                Some((StaticSpec::new(SchemeId::Gptq, alpha, 0), qmax))
            }
            ActScheme::Lorc { alpha, rank, qmax } => {
                Some((StaticSpec::new(SchemeId::Lorc, alpha, rank), qmax))
            }
            _ => None,
        }
    }

    /// Batching key: requests with identical keys share an execution.
    /// Scoring key — generation requests go through `EvalRequest::key`,
    /// which flips [`SchemeKey::generate`] so decode work never shares a
    /// batch with fixed-shape scoring executions.
    pub fn key(&self, weight_set: &str) -> SchemeKey {
        let quant = |f: f32| (f * 1e6).round() as i64;
        let (a, b) = match *self {
            ActScheme::Fp => (0, 0),
            ActScheme::CrossQuant { alpha, qmax }
            | ActScheme::CrossQuantFused { alpha, qmax }
            | ActScheme::CrossQuantStatic { alpha, qmax }
            | ActScheme::SmoothQuant { alpha, qmax }
            | ActScheme::Awq { alpha, qmax }
            | ActScheme::Gptq { alpha, qmax } => (quant(alpha), quant(qmax)),
            ActScheme::Lorc { alpha, rank, qmax } => {
                // fold the rank in so different ranks never share a model
                (quant(alpha), quant(qmax) ^ ((rank as i64) << 40))
            }
            ActScheme::RemoveKernel { theta } => (quant(theta), 0),
        };
        SchemeKey {
            artifact: self.artifact(),
            s0: a,
            s1: b,
            weight_set: weight_set.to_string(),
            generate: false,
        }
    }
}

/// Hashable batching key (floats quantized to micro-units).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SchemeKey {
    pub artifact: &'static str,
    pub s0: i64,
    pub s1: i64,
    pub weight_set: String,
    /// Generation requests batch separately from scoring requests under
    /// the same scheme (their execution shapes differ).
    pub generate: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_mapping() {
        assert_eq!(ActScheme::Fp.artifact(), "lm_fp");
        assert_eq!(ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 }.artifact(), "lm_aq");
        assert_eq!(
            ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 }.artifact(),
            "lm_aq_static"
        );
        assert_eq!(ActScheme::RemoveKernel { theta: 0.01 }.artifact(), "lm_rk");
    }

    #[test]
    fn static_and_dynamic_schemes_never_share_a_batch() {
        let d = ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 };
        let s = ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 };
        assert_ne!(d.key("w8"), s.key("w8"));
        assert_eq!(s.key("w8"), s.key("w8"));
        assert_eq!(s.scalars(), vec![0.15, 127.0]);
    }

    #[test]
    fn keys_equal_iff_same_scheme_and_weights() {
        let a = ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 };
        assert_eq!(a.key("w8"), a.key("w8"));
        assert_ne!(a.key("w8"), a.key("w4"));
        let b = ActScheme::CrossQuant { alpha: 0.45, qmax: 127.0 };
        assert_ne!(a.key("w8"), b.key("w8"));
    }

    #[test]
    fn generation_never_shares_a_batch_with_scoring() {
        let s = ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 };
        let score = EvalRequest::score(vec![1, 2, 3], s, "w8");
        let generate = EvalRequest::generate(vec![1, 2, 3], s, "w8", 4);
        assert_ne!(score.key(), generate.key());
        assert_eq!(score.key(), s.key("w8"));
        // generation requests with different budgets still share a batch
        let other = EvalRequest::generate(vec![9], s, "w8", 7);
        assert_eq!(generate.key(), other.key());
    }

    #[test]
    fn scalar_lists() {
        assert!(ActScheme::Fp.scalars().is_empty());
        assert_eq!(ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 }.scalars(), vec![0.15, 127.0]);
        assert_eq!(ActScheme::RemoveKernel { theta: 0.01 }.scalars(), vec![0.01]);
    }

    #[test]
    fn static_specs_cover_exactly_the_registry_static_schemes() {
        assert!(ActScheme::Fp.static_spec().is_none());
        assert!(ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 }.static_spec().is_none());
        let (spec, qmax) =
            ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 }.static_spec().unwrap();
        assert_eq!(spec.id, SchemeId::CrossQuantStatic);
        assert_eq!(qmax, 127.0);
        let (spec, _) =
            ActScheme::Lorc { alpha: 0.15, rank: 8, qmax: 127.0 }.static_spec().unwrap();
        assert_eq!((spec.id, spec.rank), (SchemeId::Lorc, 8));
        for s in [
            ActScheme::SmoothQuant { alpha: 0.15, qmax: 127.0 },
            ActScheme::Awq { alpha: 0.15, qmax: 127.0 },
            ActScheme::Gptq { alpha: 0.15, qmax: 127.0 },
        ] {
            assert!(s.static_spec().unwrap().0.id.is_static(), "{s:?}");
        }
    }

    #[test]
    fn priority_parses_numbers_and_names_and_clamps() {
        assert_eq!(parse_priority(&Json::num(0.0)), Some(0));
        assert_eq!(parse_priority(&Json::num(3.0)), Some(3));
        assert_eq!(parse_priority(&Json::num(9.0)), Some(3)); // clamped
        assert_eq!(parse_priority(&Json::str("batch")), Some(0));
        assert_eq!(parse_priority(&Json::str("low")), Some(1));
        assert_eq!(parse_priority(&Json::str("normal")), Some(2));
        assert_eq!(parse_priority(&Json::str("high")), Some(3));
        assert_eq!(parse_priority(&Json::str("interactive")), Some(3));
        assert_eq!(parse_priority(&Json::str("2")), Some(2));
        assert_eq!(parse_priority(&Json::str("urgent")), None);
        assert_eq!(parse_priority(&Json::num(1.5)), None);
        assert_eq!(parse_priority(&Json::num(-1.0)), None);
        assert_eq!(parse_priority(&Json::Null), None);
    }

    #[test]
    fn lorc_ranks_never_share_a_batch() {
        let a = ActScheme::Lorc { alpha: 0.15, rank: 4, qmax: 127.0 };
        let b = ActScheme::Lorc { alpha: 0.15, rank: 8, qmax: 127.0 };
        assert_ne!(a.key("w16"), b.key("w16"));
        assert_eq!(a.key("w16"), a.key("w16"));
    }
}
