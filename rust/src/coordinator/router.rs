//! The fleet router: a line-protocol TCP front-end that dispatches each
//! client request to the least-loaded healthy worker, enforces
//! per-request deadlines, and transparently retries idempotent requests
//! on a surviving worker when one fails mid-request.
//!
//! Protocol: the worker protocol (see [`super::server`]), verbatim —
//! the router forwards the client's raw line and relays the worker's
//! response line(s), so anything a worker serves the router serves. Two
//! additions:
//!
//! * `"deadline_ms"` on any data request bounds its total time in the
//!   tier (dispatch + all retries); exceeding it returns
//!   `{"ok": false, "error": "deadline exceeded…", "retryable": true}`.
//! * `{"cmd": "metrics"}` aggregates across the fleet: per-worker
//!   status, summed worker counters, and the router's own counters.
//!   With `"format": "prometheus"` it returns one exposition body: the
//!   router's samples plus every healthy worker's body re-labeled with
//!   `worker="<index>"`.
//! * Every data request is assigned a trace id before relay: a client
//!   `"trace"` field is honored, otherwise the router generates one and
//!   injects it, so worker-side spans always correlate. The id is
//!   echoed in the final response and a dispatch span (aux = worker
//!   index) is recorded router-side.
//! * `{"cmd": "trace", "id": …}` merges the router's dispatch spans for
//!   that id with every healthy worker's spans (`"format": "chrome"`
//!   returns merged Chrome `trace_event` JSON instead).
//! * A `"priority"` field on any data request is normalized (0–3 or
//!   "batch"/"low"/"normal"/"high") and injected into the relayed frame,
//!   so worker-side lowest-priority-first shedding sees the same class
//!   the router accounted under; router-side sheds count into
//!   `shed_p<N>` alongside the total.
//! * `{"cmd": "slo"}` fans out per-worker burn-rate reports;
//!   `{"cmd": "metrics_reset"}` zeroes the router's counters and every
//!   healthy worker's (load harnesses call it before a run).
//!
//! Retry safety: score and generate are deterministic (greedy decode,
//! pinned by rust/tests/engine.rs), so re-running a request on another
//! worker returns bit-identical results — failover is invisible to the
//! client. A streamed generation is only retried when *zero* token
//! lines have been relayed; after that the stream fails explicitly
//! rather than replaying tokens.
//!
//! When no healthy worker exists the router sheds load with a
//! structured retryable error instead of hanging; a `shutdown` request
//! stops the accept loop and [`Router::drain`] waits for in-flight
//! requests before the process exits.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::fleet::{Fleet, Worker};
use super::metrics::FleetMetrics;
use crate::obs::prom::{relabel, PromWriter};
use crate::obs::trace::chrome_trace_json;
use crate::obs::{self, Span, SpanKind};
use crate::util::Json;

#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Deadline applied when a request carries no `"deadline_ms"`.
    pub default_deadline: Duration,
    /// Failover attempts after the first (so `3` means up to 4 workers
    /// see the request).
    pub max_retries: usize,
    /// Poll interval while waiting for a healthy worker (fleet
    /// restarting) under an unexpired deadline.
    pub retry_poll: Duration,
    /// Idle read timeout for client connections.
    pub idle_timeout: Option<Duration>,
    /// Per-worker timeout when fanning out metrics aggregation.
    pub metrics_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            default_deadline: Duration::from_secs(30),
            max_retries: 3,
            retry_poll: Duration::from_millis(25),
            idle_timeout: Some(Duration::from_secs(300)),
            metrics_timeout: Duration::from_millis(500),
        }
    }
}

#[derive(Clone)]
pub struct Router {
    fleet: Arc<Fleet>,
    cfg: RouterConfig,
    metrics: Arc<FleetMetrics>,
    in_flight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
}

/// Outcome of one attempt against one worker.
enum Attempt {
    /// A complete response was relayed to the client.
    Served { ok: bool },
    /// The worker failed mid-request (connect refused, connection died,
    /// torn frame, or a retryable worker error) — safe to try elsewhere.
    WorkerFailed(String),
    /// The per-request deadline expired during this attempt.
    TimedOut,
    /// The *client* connection died — abandon the request.
    ClientGone,
}

/// Panic-safe in-flight counter guard (drain correctness).
struct InFlightGuard(Arc<AtomicUsize>);

impl InFlightGuard {
    fn new(counter: Arc<AtomicUsize>) -> InFlightGuard {
        counter.fetch_add(1, Ordering::SeqCst);
        InFlightGuard(counter)
    }
}

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Router {
    pub fn new(fleet: Arc<Fleet>, cfg: RouterConfig) -> Router {
        let metrics = fleet.metrics().clone();
        Router {
            fleet,
            cfg,
            metrics,
            in_flight: Arc::new(AtomicUsize::new(0)),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Stop accepting new connections; `serve` returns at its next poll.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Data requests currently being dispatched (drain accounting).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Wait for in-flight requests to finish (bounded by `timeout`).
    /// Returns true when the tier drained cleanly.
    pub fn drain(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while self.in_flight() > 0 {
            if Instant::now() > deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        true
    }

    /// Accept loop: one thread per client connection. Polls the shutdown
    /// flag between accepts, so `request_shutdown` (e.g. from a SIGTERM
    /// handler) ends the loop instead of blocking in `accept` forever.
    pub fn serve(&self, listener: TcpListener) -> Result<()> {
        listener.set_nonblocking(true)?;
        while !self.shutdown.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let router = self.clone();
                    std::thread::spawn(move || {
                        let _ = router.handle_connection(stream);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    fn handle_connection(&self, stream: TcpStream) -> Result<()> {
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(self.cfg.idle_timeout)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) => return Ok(()),
                Ok(_) => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    let _ = write_line(
                        &mut writer,
                        &error_json("idle timeout: closing connection", true),
                    );
                    return Ok(());
                }
                // e.g. invalid UTF-8 from the fuzzer: close, never panic
                Err(_) => return Ok(()),
            }
            if line.trim().is_empty() {
                continue;
            }
            let parsed = match Json::parse(&line) {
                Ok(j) => j,
                Err(e) => {
                    self.metrics.malformed.fetch_add(1, Ordering::SeqCst);
                    let err = error_json(&format!("malformed request: {e}"), false);
                    write_line(&mut writer, &err)?;
                    continue;
                }
            };
            if let Some(cmd) = parsed.get("cmd").and_then(|c| c.as_str()) {
                let resp = match cmd {
                    "ping" => {
                        Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])
                    }
                    "metrics" => {
                        if parsed.get("format").and_then(|f| f.as_str()) == Some("prometheus") {
                            self.fleet_prometheus()
                        } else {
                            self.aggregate_metrics()
                        }
                    }
                    "slo" => self.fleet_slo(),
                    "metrics_reset" => self.fleet_reset(),
                    "trace" => self.fleet_trace(&parsed),
                    other => error_json(&format!("unknown cmd '{other}'"), false),
                };
                write_line(&mut writer, &resp)?;
                continue;
            }
            if !matches!(parsed, Json::Obj(_)) {
                self.metrics.malformed.fetch_add(1, Ordering::SeqCst);
                write_line(
                    &mut writer,
                    &error_json("malformed request: expected a JSON object", false),
                )?;
                continue;
            }
            if self.dispatch(&line, &parsed, &mut writer).is_err() {
                return Ok(()); // client connection is gone
            }
        }
    }

    /// Route one data request: deadline, least-loaded pick, failover.
    /// `Err` means the *client* connection died; every other outcome is
    /// written to the client as a structured line.
    fn dispatch(&self, raw_line: &str, req: &Json, writer: &mut TcpStream) -> Result<()> {
        let _guard = InFlightGuard::new(self.in_flight.clone());
        self.metrics.requests.fetch_add(1, Ordering::SeqCst);
        let deadline = match req.get("deadline_ms") {
            None => self.cfg.default_deadline,
            Some(ms) => match ms.as_f64() {
                Some(v) if v.is_finite() && v >= 1.0 => Duration::from_millis(v as u64),
                _ => {
                    self.metrics.malformed.fetch_add(1, Ordering::SeqCst);
                    let msg = "malformed request: 'deadline_ms' must be a positive integer";
                    write_line(writer, &error_json(msg, false))?;
                    return Ok(());
                }
            },
        };
        let deadline = Instant::now() + deadline;
        let streaming = req.get("stream") == Some(&Json::Bool(true));
        // Normalize the scheduling class up front: a malformed field is
        // a deterministic request error, and the canonical numeric form
        // is what gets relayed, so router and worker shed accounting can
        // never disagree about a request's class.
        let priority = match req.get("priority") {
            Some(v) => match super::parse_priority(v) {
                Some(p) => p,
                None => {
                    self.metrics.malformed.fetch_add(1, Ordering::SeqCst);
                    let msg =
                        "malformed request: 'priority' must be 0-3 or batch/low/normal/high";
                    write_line(writer, &error_json(msg, false))?;
                    return Ok(());
                }
            },
            None => super::metrics::PRIORITY_DEFAULT,
        };
        // Assign (or honor) the trace id and inject it into the relayed
        // frame so worker-side spans correlate with the router's.
        let trace = req
            .get("trace")
            .and_then(obs::parse_trace_field)
            .unwrap_or_else(obs::next_trace_id);
        let line = match req {
            Json::Obj(fields) => {
                let mut fields = fields.clone();
                fields.insert("trace".to_string(), Json::str(obs::trace_id_string(trace)));
                fields.insert("priority".to_string(), Json::num(priority as f64));
                format!("{}\n", Json::Obj(fields).render())
            }
            _ => format!("{}\n", raw_line.trim_end()),
        };
        let t0 = Instant::now();

        let mut tried: Vec<usize> = Vec::new();
        let mut attempts = 0usize;
        let mut last_err = String::from("no healthy worker available");
        loop {
            if Instant::now() >= deadline {
                self.metrics.deadline_exceeded.fetch_add(1, Ordering::SeqCst);
                obs::log::warn(
                    "router",
                    "deadline exceeded",
                    &[
                        ("trace", obs::trace_id_string(trace)),
                        ("last_err", last_err.clone()),
                    ],
                );
                write_line(
                    writer,
                    &error_json(&format!("deadline exceeded (last failure: {last_err})"), true),
                )?;
                return Ok(());
            }
            let Some(worker) = self.pick_worker(&tried) else {
                if self.fleet.workers().iter().all(|w| w.breaker_open()) {
                    // nothing will ever come back without intervention
                    self.metrics.shed.fetch_add(1, Ordering::SeqCst);
                    self.metrics.mark_shed(priority);
                    obs::log::warn(
                        "router",
                        "request shed: all circuit breakers open",
                        &[("trace", obs::trace_id_string(trace))],
                    );
                    write_line(
                        writer,
                        &error_json("no healthy workers: all circuit breakers open", true),
                    )?;
                    return Ok(());
                }
                // every worker is down or already tried: let the
                // supervisor restart one, within the deadline
                tried.clear();
                std::thread::sleep(self.cfg.retry_poll);
                continue;
            };
            if attempts > self.cfg.max_retries {
                self.metrics.shed.fetch_add(1, Ordering::SeqCst);
                self.metrics.mark_shed(priority);
                obs::log::warn(
                    "router",
                    "request shed: retry budget exhausted",
                    &[
                        ("trace", obs::trace_id_string(trace)),
                        ("attempts", attempts.to_string()),
                        ("last_err", last_err.clone()),
                    ],
                );
                write_line(
                    writer,
                    &error_json(
                        &format!("request failed after {attempts} attempts: {last_err}"),
                        true,
                    ),
                )?;
                return Ok(());
            }
            attempts += 1;
            if attempts > 1 {
                self.metrics.retried.fetch_add(1, Ordering::SeqCst);
            }
            worker.begin_request();
            let outcome = attempt_worker(&worker, &line, deadline, streaming, writer);
            worker.end_request();
            match outcome {
                Attempt::Served { ok } => {
                    if ok {
                        self.metrics.succeeded.fetch_add(1, Ordering::SeqCst);
                    }
                    let dur_us = t0.elapsed().as_micros() as u64;
                    self.metrics.spans.record(Span {
                        trace,
                        kind: SpanKind::Dispatch,
                        start_us: obs::now_us().saturating_sub(dur_us),
                        dur_us,
                        aux: worker.index() as u64,
                    });
                    return Ok(());
                }
                Attempt::WorkerFailed(err) => {
                    tried.push(worker.index());
                    last_err = err;
                }
                Attempt::TimedOut => {
                    self.metrics.deadline_exceeded.fetch_add(1, Ordering::SeqCst);
                    write_line(
                        writer,
                        &error_json("deadline exceeded waiting for worker response", true),
                    )?;
                    return Ok(());
                }
                Attempt::ClientGone => return Err(anyhow::anyhow!("client disconnected")),
            }
        }
    }

    /// Least-loaded healthy worker not yet tried for this request.
    fn pick_worker(&self, tried: &[usize]) -> Option<Arc<Worker>> {
        self.fleet
            .workers()
            .iter()
            .filter(|w| w.is_healthy() && w.addr().is_some() && !tried.contains(&w.index()))
            .min_by_key(|w| (w.in_flight(), w.index()))
            .cloned()
    }

    /// Fleet-wide `{"cmd": "metrics"}`: per-worker status, worker
    /// counters summed across the fleet, and the router's own counters.
    fn aggregate_metrics(&self) -> Json {
        let mut aggregate: Vec<(String, f64)> = Vec::new();
        let mut worker_rows = Vec::new();
        for w in self.fleet.workers() {
            let status = w.status();
            let counters = status
                .addr
                .filter(|_| status.healthy)
                .and_then(|addr| fetch_worker_metrics(addr, self.cfg.metrics_timeout));
            let fleet_counters = counters.as_ref().and_then(|c| c.get("counters")).cloned();
            if let Some(Json::Obj(fields)) = fleet_counters {
                for (k, v) in fields {
                    if let Some(n) = v.as_f64() {
                        match aggregate.iter_mut().find(|(name, _)| *name == k) {
                            Some((_, total)) => *total += n,
                            None => aggregate.push((k, n)),
                        }
                    }
                }
            }
            worker_rows.push(Json::obj(vec![
                ("index", Json::num(status.index as f64)),
                ("healthy", Json::Bool(status.healthy)),
                ("addr", status.addr.map_or(Json::Null, |a| Json::str(a.to_string()))),
                ("in_flight", Json::num(status.in_flight as f64)),
                ("restarts", Json::num(status.restarts as f64)),
                ("breaker_open", Json::Bool(status.breaker_open)),
            ]));
        }
        // Both tiers decide `shed` outcomes (the router on breaker/retry
        // exhaustion, workers on queue-full/burn-rate admission), so the
        // router's counts fold into the same keys the worker sum uses —
        // the aggregate is total sheds across the tier, per class.
        let mut router_only: Vec<(String, u64)> = vec![
            (
                "deadline_exceeded".to_string(),
                self.metrics.deadline_exceeded.load(Ordering::Relaxed),
            ),
            ("shed".to_string(), self.metrics.shed.load(Ordering::Relaxed)),
        ];
        for (p, c) in self.metrics.shed_by_priority.iter().enumerate() {
            router_only.push((format!("shed_p{p}"), c.load(Ordering::Relaxed)));
        }
        for (k, v) in router_only {
            match aggregate.iter_mut().find(|(name, _)| *name == k) {
                Some((_, total)) => *total += v as f64,
                None => aggregate.push((k, v as f64)),
            }
        }
        let aggregate_obj =
            Json::Obj(aggregate.into_iter().map(|(k, v)| (k, Json::num(v))).collect());
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("router", self.metrics.router_json()),
            ("fleet", self.metrics.fleet_json()),
            ("workers", Json::arr(worker_rows)),
            ("aggregate", aggregate_obj),
        ])
    }

    /// Fleet-wide Prometheus exposition: the router's own samples
    /// followed by each healthy worker's body, re-labeled with
    /// `worker="<index>"` so per-worker series stay distinguishable.
    fn fleet_prometheus(&self) -> Json {
        let mut w = PromWriter::new();
        self.metrics.prom_into(&mut w);
        let mut body = w.finish();
        let req = Json::obj(vec![
            ("cmd", Json::str("metrics")),
            ("format", Json::str("prometheus")),
        ]);
        for worker in self.fleet.workers() {
            let status = worker.status();
            let Some(addr) = status.addr.filter(|_| status.healthy) else {
                continue;
            };
            let Some(resp) = fetch_worker_line(addr, &req, self.cfg.metrics_timeout) else {
                continue;
            };
            if let Some(worker_body) = resp.get("body").and_then(|b| b.as_str()) {
                body.push_str(&relabel(worker_body, "worker", &status.index.to_string()));
            }
        }
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("content_type", Json::str("text/plain; version=0.0.4")),
            ("body", Json::str(body)),
        ])
    }

    /// Fleet-wide `{"cmd": "slo"}`: each healthy worker's burn-rate
    /// report, plus a fleet-level `shedding` bit (any worker shedding).
    fn fleet_slo(&self) -> Json {
        let req = Json::obj(vec![("cmd", Json::str("slo"))]);
        let mut rows = Vec::new();
        let mut any_shedding = false;
        for worker in self.fleet.workers() {
            let status = worker.status();
            let Some(addr) = status.addr.filter(|_| status.healthy) else {
                continue;
            };
            let Some(resp) = fetch_worker_line(addr, &req, self.cfg.metrics_timeout) else {
                continue;
            };
            if let Some(slo) = resp.get("slo") {
                any_shedding |= slo.get("shedding") == Some(&Json::Bool(true));
                rows.push(Json::obj(vec![
                    ("index", Json::num(status.index as f64)),
                    ("slo", slo.clone()),
                ]));
            }
        }
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("workers", Json::arr(rows)),
            ("shedding", Json::Bool(any_shedding)),
        ])
    }

    /// Fleet-wide `{"cmd": "metrics_reset"}`: zero the router's own
    /// counters and fan the reset out to every healthy worker.
    fn fleet_reset(&self) -> Json {
        self.metrics.reset();
        let req = Json::obj(vec![("cmd", Json::str("metrics_reset"))]);
        let mut workers_reset = 0usize;
        for worker in self.fleet.workers() {
            let status = worker.status();
            let Some(addr) = status.addr.filter(|_| status.healthy) else {
                continue;
            };
            if let Some(resp) = fetch_worker_line(addr, &req, self.cfg.metrics_timeout) {
                if resp.get("ok") == Some(&Json::Bool(true)) {
                    workers_reset += 1;
                }
            }
        }
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("reset", Json::Bool(true)),
            ("workers_reset", Json::num(workers_reset as f64)),
        ])
    }

    /// Fleet-wide `{"cmd": "trace"}`: the router's spans for the id
    /// merged with every healthy worker's (`id` absent or 0 dumps
    /// everything). `"format": "chrome"` merges Chrome trace events.
    fn fleet_trace(&self, req: &Json) -> Json {
        let id = req.get("id").and_then(obs::parse_trace_field).unwrap_or(0);
        let chrome = req.get("format").and_then(|f| f.as_str()) == Some("chrome");
        let own = self.metrics.spans.for_trace(id);
        let mut worker_fields = vec![
            ("cmd", Json::str("trace")),
            ("format", Json::str(if chrome { "chrome" } else { "spans" })),
        ];
        if id != 0 {
            // an explicit hex 0 would parse back as `0 | 1`; omitting
            // the field is the protocol's "dump everything"
            worker_fields.push(("id", Json::str(obs::trace_id_string(id))));
        }
        let worker_req = Json::obj(worker_fields);
        let mut rows: Vec<Json> = if chrome {
            match chrome_trace_json(&own).get("traceEvents") {
                Some(Json::Arr(events)) => events.clone(),
                _ => Vec::new(),
            }
        } else {
            own.iter().map(|s| s.json()).collect()
        };
        let key = if chrome { "traceEvents" } else { "spans" };
        for worker in self.fleet.workers() {
            let status = worker.status();
            let Some(addr) = status.addr.filter(|_| status.healthy) else {
                continue;
            };
            let Some(resp) = fetch_worker_line(addr, &worker_req, self.cfg.metrics_timeout) else {
                continue;
            };
            if let Some(Json::Arr(worker_rows)) = resp.get(key) {
                rows.extend(worker_rows.iter().cloned());
            }
        }
        if chrome {
            Json::obj(vec![("ok", Json::Bool(true)), ("traceEvents", Json::Arr(rows))])
        } else {
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("trace", Json::str(obs::trace_id_string(id))),
                ("spans", Json::Arr(rows)),
            ])
        }
    }
}

/// One request → response cycle against one worker, relaying to the
/// client. Streamed responses relay every line; a worker failure after
/// at least one relayed token line is reported to the client instead of
/// retried (tokens must not replay).
fn attempt_worker(
    worker: &Worker,
    line: &str,
    deadline: Instant,
    streaming: bool,
    client: &mut TcpStream,
) -> Attempt {
    let Some(addr) = worker.addr() else {
        return Attempt::WorkerFailed("worker lost its address".into());
    };
    let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
        return Attempt::TimedOut;
    };
    let stream = match TcpStream::connect_timeout(&addr, remaining.min(Duration::from_secs(5))) {
        Ok(s) => s,
        Err(e) => return Attempt::WorkerFailed(format!("connect to worker {addr}: {e}")),
    };
    if stream.set_write_timeout(Some(remaining)).is_err() {
        return Attempt::WorkerFailed("worker socket setup failed".into());
    }
    let mut wtx = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => return Attempt::WorkerFailed(format!("worker socket clone: {e}")),
    };
    if let Err(e) = wtx.write_all(line.as_bytes()) {
        return Attempt::WorkerFailed(format!("write to worker: {e}"));
    }
    let mut reader = BufReader::new(stream);
    let mut relayed = 0usize;
    let mut buf = String::new();
    loop {
        let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
            return if relayed == 0 {
                Attempt::TimedOut
            } else {
                fail_stream(client, "deadline exceeded mid-stream")
            };
        };
        if reader.get_ref().set_read_timeout(Some(remaining)).is_err() {
            return Attempt::WorkerFailed("worker socket setup failed".into());
        }
        buf.clear();
        match reader.read_line(&mut buf) {
            Ok(0) => {
                // worker closed without a (complete) response — the
                // dropped-connection and crash-mid-request cases
                return if relayed == 0 {
                    Attempt::WorkerFailed("worker closed the connection mid-request".into())
                } else {
                    fail_stream(client, "worker died mid-stream")
                };
            }
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return if relayed == 0 {
                    Attempt::TimedOut
                } else {
                    fail_stream(client, "deadline exceeded mid-stream")
                };
            }
            Err(e) => {
                return if relayed == 0 {
                    Attempt::WorkerFailed(format!("read from worker: {e}"))
                } else {
                    fail_stream(client, "worker connection failed mid-stream")
                };
            }
        }
        if !buf.ends_with('\n') {
            // torn frame (worker died mid-write / truncation fault)
            return if relayed == 0 {
                Attempt::WorkerFailed("truncated response frame from worker".into())
            } else {
                fail_stream(client, "truncated frame mid-stream")
            };
        }
        let Ok(resp) = Json::parse(&buf) else {
            return if relayed == 0 {
                Attempt::WorkerFailed("unparseable response frame from worker".into())
            } else {
                fail_stream(client, "unparseable frame mid-stream")
            };
        };
        match resp.get("ok") {
            None if streaming => {
                // token line: relay and keep reading
                if client.write_all(buf.as_bytes()).is_err() {
                    return Attempt::ClientGone;
                }
                relayed += 1;
            }
            None => {
                return Attempt::WorkerFailed("response frame without 'ok' field".into());
            }
            Some(ok_val) => {
                let ok = ok_val == &Json::Bool(true);
                // a retryable worker error fails over (when nothing has
                // been relayed yet); every other response is final
                let retryable = resp.get("retryable") == Some(&Json::Bool(true));
                if !ok && retryable && relayed == 0 {
                    let msg = resp
                        .get("error")
                        .and_then(|e| e.as_str())
                        .unwrap_or("worker reported a retryable error");
                    return Attempt::WorkerFailed(format!("worker error: {msg}"));
                }
                if client.write_all(buf.as_bytes()).is_err() {
                    return Attempt::ClientGone;
                }
                return Attempt::Served { ok };
            }
        }
    }
}

/// Report a mid-stream failure to the client (tokens were already
/// relayed, so failover would replay them — fail explicitly instead).
fn fail_stream(client: &mut TcpStream, why: &str) -> Attempt {
    let gone = write_line(client, &error_json(why, false)).is_err();
    if gone {
        Attempt::ClientGone
    } else {
        Attempt::Served { ok: false }
    }
}

/// Fetch one worker's `{"cmd":"metrics"}` response.
fn fetch_worker_metrics(addr: SocketAddr, timeout: Duration) -> Option<Json> {
    let req = Json::obj(vec![("cmd", Json::str("metrics"))]);
    fetch_worker_line(addr, &req, timeout)
}

/// Send one control request to a worker and parse its single-line reply
/// (the fan-out primitive behind metrics and trace aggregation).
fn fetch_worker_line(addr: SocketAddr, req: &Json, timeout: Duration) -> Option<Json> {
    let stream = TcpStream::connect_timeout(&addr, timeout).ok()?;
    stream.set_read_timeout(Some(timeout)).ok()?;
    stream.set_write_timeout(Some(timeout)).ok()?;
    let mut writer = stream.try_clone().ok()?;
    writer.write_all(req.render().as_bytes()).ok()?;
    writer.write_all(b"\n").ok()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).ok()?;
    Json::parse(&line).ok()
}

fn error_json(msg: &str, retryable: bool) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
        ("retryable", Json::Bool(retryable)),
    ])
}

fn write_line(writer: &mut impl Write, json: &Json) -> Result<()> {
    writer.write_all(json.render().as_bytes())?;
    writer.write_all(b"\n")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_json_shape() {
        let e = error_json("deadline exceeded", true);
        assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(e.get("retryable"), Some(&Json::Bool(true)));
        assert_eq!(e.get("error").and_then(|v| v.as_str()), Some("deadline exceeded"));
    }

    #[test]
    fn in_flight_guard_is_panic_safe() {
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = counter.clone();
        let _ = std::panic::catch_unwind(move || {
            let _g = InFlightGuard::new(c2);
            panic!("boom");
        });
        assert_eq!(counter.load(Ordering::SeqCst), 0);
    }
}
