//! Coordinator wiring: submit → batching thread → executor thread.
//!
//! The PJRT client is not Send, so a dedicated OS thread owns the
//! [`Runtime`] and all compiled executables; callers talk to it through
//! bounded channels. Backpressure is the bounded submit queue — when the
//! executor falls behind, `submit` blocks on queue capacity instead of
//! piling up unbounded work (the paper-agnostic core of any serving
//! router). The offline build has no tokio (Cargo.toml), so the async
//! surface is expressed with plain threads + channels; the protocol
//! (scheme-keyed dynamic batching with a flush deadline) is identical.
//!
//! When no PJRT runtime is linked (the offline build's `xla` stub), the
//! executor thread falls back to a [`NativeExecutor`]: the same batching
//! protocol served by [`NativeModel`] forwards, with the fused
//! `analysis::quantize_with_report` path at every activation site.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use super::batcher::{BatchAccumulator, ReadyBatch};
use super::metrics::Metrics;
use super::{ActScheme, SchemeKey};
use crate::corpus::CorpusGen;
use crate::model::config::ModelConfig;
use crate::model::{
    ActSite, IdentitySite, NativeModel, QuantPath, QuantSite, QuantizedModel, RemoveKernelSite,
    Weights,
};
use crate::quant::{
    crossquant::cross_delta_field, remove_kernel::RemoveKernel, ActQuantizer, Bits, DeltaField,
};
use crate::runtime::literal::{literal_to_scalar, literal_to_vec, tokens_literal, vec_literal};
use crate::runtime::{ArtifactStore, Runtime};
use crate::tensor::Matrix;
use crate::util::LruCache;
use crate::xla;

/// What a request asks the executor to do with its tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// Score the sequence: per-position NLL (the original workload).
    Score,
    /// Greedy generation: treat the tokens as a prompt, prefill once,
    /// then KV-cached decode of `max_new_tokens` tokens.
    Generate { max_new_tokens: usize },
}

/// One evaluation request: a token sequence under a scheme + weight set.
#[derive(Clone)]
pub struct EvalRequest {
    pub tokens: Vec<u32>,
    pub scheme: ActScheme,
    /// Which registered weight set to run against (e.g. "w16", "w8", "w4g128").
    pub weight_set: String,
    pub kind: RequestKind,
}

impl EvalRequest {
    /// A scoring request (per-position NLL).
    pub fn score(tokens: Vec<u32>, scheme: ActScheme, weight_set: impl Into<String>) -> Self {
        EvalRequest { tokens, scheme, weight_set: weight_set.into(), kind: RequestKind::Score }
    }

    /// A greedy-generation request (`tokens` is the prompt).
    pub fn generate(
        tokens: Vec<u32>,
        scheme: ActScheme,
        weight_set: impl Into<String>,
        max_new_tokens: usize,
    ) -> Self {
        EvalRequest {
            tokens,
            scheme,
            weight_set: weight_set.into(),
            kind: RequestKind::Generate { max_new_tokens },
        }
    }

    /// Batching key: scheme key plus the kind discriminant, so generation
    /// and scoring work under the same scheme never share an execution.
    pub fn key(&self) -> SchemeKey {
        let mut key = self.scheme.key(&self.weight_set);
        key.generate = matches!(self.kind, RequestKind::Generate { .. });
        key
    }
}

/// Per-request result.
#[derive(Clone, Debug)]
pub struct EvalResponse {
    /// Per-position NLL for the request's (unpadded) sequence — empty for
    /// generation requests.
    pub nll: Vec<f32>,
    /// Scheme-reported auxiliary scalar (kernel fraction / removed
    /// fraction), measured over the whole executed batch. 0.0 for FP.
    pub aux: f32,
    /// Greedy-decoded token ids — empty for scoring requests.
    pub generated: Vec<u32>,
}

struct Pending {
    req: EvalRequest,
    resp: SyncSender<Result<EvalResponse>>,
    submitted: Instant,
}

/// Await-able response slot for one submitted request.
pub struct ResponseHandle {
    rx: Receiver<Result<EvalResponse>>,
}

impl ResponseHandle {
    /// Block until the request's batch has executed.
    pub fn wait(self) -> Result<EvalResponse> {
        self.rx.recv().map_err(|_| anyhow!("executor dropped request"))?
    }

    pub fn wait_timeout(self, timeout: Duration) -> Result<EvalResponse> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err(anyhow!("request timed out")),
            Err(RecvTimeoutError::Disconnected) => Err(anyhow!("executor dropped request")),
        }
    }
}

#[derive(Clone)]
pub struct EvalCoordinator {
    tx: SyncSender<Pending>,
    pub metrics: Arc<Metrics>,
    config: ModelConfig,
}

pub struct CoordinatorConfig {
    /// Max requests per executed batch (must equal the artifact batch dim).
    pub batch_size: usize,
    /// Flush partial batches after this delay.
    pub max_batch_delay: Duration,
    /// Bounded submit queue (backpressure limit).
    pub max_queue: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batch_size: 8,
            max_batch_delay: Duration::from_millis(5),
            max_queue: 256,
        }
    }
}

impl EvalCoordinator {
    /// Start the coordinator: spawns the batching thread and the executor
    /// thread. The PJRT client is constructed *inside* the executor thread
    /// (it is not Send). `weight_sets` registers every flat weight vector
    /// requests may reference (each is uploaded as a literal once).
    pub fn start(
        store: ArtifactStore,
        model_config: ModelConfig,
        weight_sets: Vec<(String, Vec<f32>)>,
        cfg: CoordinatorConfig,
    ) -> EvalCoordinator {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = std::sync::mpsc::sync_channel::<Pending>(cfg.max_queue);
        let (batch_tx, batch_rx) = std::sync::mpsc::sync_channel::<ReadyBatch<Pending>>(16);

        let m1 = metrics.clone();
        let batch_size = cfg.batch_size;
        let max_delay = cfg.max_batch_delay;
        std::thread::Builder::new()
            .name("cq-batcher".into())
            .spawn(move || batch_loop(rx, batch_tx, batch_size, max_delay, m1))
            .expect("spawn batcher");

        let m2 = metrics.clone();
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_loop(store, model_config, weight_sets, batch_rx, m2))
            .expect("spawn executor");

        EvalCoordinator { tx, metrics, config: model_config }
    }

    /// Submit one request; returns a handle resolving when its batch has
    /// executed. Blocks when the submit queue is full (backpressure).
    pub fn submit(&self, req: EvalRequest) -> Result<ResponseHandle> {
        match req.kind {
            RequestKind::Score => anyhow::ensure!(
                req.tokens.len() >= 2 && req.tokens.len() <= self.config.seq_len,
                "sequence length {} out of range",
                req.tokens.len()
            ),
            RequestKind::Generate { max_new_tokens } => {
                anyhow::ensure!(!req.tokens.is_empty(), "generation needs a non-empty prompt");
                anyhow::ensure!(max_new_tokens >= 1, "max_new_tokens must be >= 1");
                anyhow::ensure!(
                    req.tokens.len() + max_new_tokens <= self.config.seq_len,
                    "prompt length {} + max_new_tokens {max_new_tokens} exceeds model \
                     context {}",
                    req.tokens.len(),
                    self.config.seq_len
                );
            }
        }
        let (resp_tx, resp_rx) = std::sync::mpsc::sync_channel(1);
        self.metrics.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .send(Pending { req, resp: resp_tx, submitted: Instant::now() })
            .map_err(|_| anyhow!("coordinator shut down"))?;
        Ok(ResponseHandle { rx: resp_rx })
    }

    /// Convenience: evaluate a set of sequences (pipelined through the
    /// batcher) and return (mean NLL, mean aux) — the building block of the
    /// PJRT eval path.
    pub fn evaluate_stream(
        &self,
        sequences: Vec<Vec<u32>>,
        scheme: ActScheme,
        weight_set: &str,
    ) -> Result<(f64, f32)> {
        let handles: Vec<ResponseHandle> = sequences
            .into_iter()
            .map(|tokens| self.submit(EvalRequest::score(tokens, scheme, weight_set)))
            .collect::<Result<_>>()?;
        let mut total = 0.0f64;
        let mut count = 0usize;
        let mut aux = 0.0f32;
        let mut n_resp = 0usize;
        for h in handles {
            let r = h.wait()?;
            total += r.nll.iter().map(|&v| v as f64).sum::<f64>();
            count += r.nll.len();
            aux += r.aux;
            n_resp += 1;
        }
        Ok((total / count.max(1) as f64, aux / n_resp.max(1) as f32))
    }
}

fn batch_loop(
    rx: Receiver<Pending>,
    batch_tx: SyncSender<ReadyBatch<Pending>>,
    batch_size: usize,
    max_delay: Duration,
    metrics: Arc<Metrics>,
) {
    let mut acc: BatchAccumulator<Pending> = BatchAccumulator::new(batch_size, max_delay);
    loop {
        let timeout = acc
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_secs(3600));
        match rx.recv_timeout(timeout) {
            Ok(p) => {
                let key = p.req.key();
                metrics.queue_depth.store(
                    acc.pending_requests() as u64 + 1,
                    std::sync::atomic::Ordering::Relaxed,
                );
                if let Some(batch) = acc.push(key, p, Instant::now()) {
                    dispatch(&batch_tx, batch, &metrics);
                }
            }
            Err(RecvTimeoutError::Timeout) => { /* deadline tick */ }
            Err(RecvTimeoutError::Disconnected) => {
                for batch in acc.flush_all() {
                    dispatch(&batch_tx, batch, &metrics);
                }
                return; // all senders dropped
            }
        }
        for batch in acc.flush_expired(Instant::now()) {
            dispatch(&batch_tx, batch, &metrics);
        }
    }
}

fn dispatch(
    tx: &SyncSender<ReadyBatch<Pending>>,
    batch: ReadyBatch<Pending>,
    metrics: &Metrics,
) {
    metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    metrics
        .batched_requests
        .fetch_add(batch.requests.len() as u64, std::sync::atomic::Ordering::Relaxed);
    // sync_channel send blocks when the executor is saturated —
    // intended backpressure toward the batcher.
    let _ = tx.send(batch);
}

fn executor_loop(
    store: ArtifactStore,
    cfg: ModelConfig,
    weight_sets: Vec<(String, Vec<f32>)>,
    rx: Receiver<ReadyBatch<Pending>>,
    metrics: Arc<Metrics>,
) {
    match Runtime::new(store) {
        Ok(mut runtime) => {
            // the static-scale scheme and the generation kind have no AOT
            // artifact (the lowered graphs are fixed-shape scoring), so
            // even a PJRT-linked executor serves them through the native
            // models — every protocol request works on every build. The
            // native executor is built lazily from the retained literals
            // on the first such batch, so plain fp/crossquant scoring
            // never holds a second f32 copy of the weights.
            let weights: HashMap<String, xla::Literal> =
                weight_sets.into_iter().map(|(k, v)| (k, vec_literal(&v))).collect();
            let mut native: Option<NativeExecutor> = None;
            while let Ok(batch) = rx.recv() {
                let req0 = &batch.requests[0].req;
                let serve_native = matches!(req0.scheme, ActScheme::CrossQuantStatic { .. })
                    || matches!(req0.kind, RequestKind::Generate { .. });
                let result = if serve_native {
                    native_for_fallback(&mut native, cfg, &weights)
                        .and_then(|n| n.execute_batch(&batch))
                } else {
                    execute_batch(&mut runtime, cfg, &weights, &batch)
                };
                metrics.executions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                respond(batch, result, &metrics);
            }
        }
        Err(e) => {
            // No PJRT runtime linked: serve the same protocol with the
            // native executor instead of failing every request.
            eprintln!(
                "coordinator: PJRT unavailable ({e}); falling back to the native executor"
            );
            let mut native = NativeExecutor::new(cfg, weight_sets);
            while let Ok(batch) = rx.recv() {
                let result = native.execute_batch(&batch);
                metrics.executions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                respond(batch, result, &metrics);
            }
        }
    }
}

/// Lazily build the PJRT branch's sidecar [`NativeExecutor`] from the
/// already-uploaded weight literals — paid only on the first
/// `CrossQuantStatic` or generation batch, never for plain PJRT scoring.
fn native_for_fallback<'a>(
    native: &'a mut Option<NativeExecutor>,
    cfg: ModelConfig,
    weights: &HashMap<String, xla::Literal>,
) -> Result<&'a mut NativeExecutor> {
    if native.is_none() {
        let sets = weights
            .iter()
            .map(|(k, v)| Ok((k.clone(), literal_to_vec(v)?)))
            .collect::<Result<Vec<_>>>()?;
        *native = Some(NativeExecutor::new(cfg, sets));
    }
    Ok(native.as_mut().expect("initialised above"))
}

/// Fan a batch result out to its requests (success and failure paths
/// shared by the PJRT and native executors).
fn respond(batch: ReadyBatch<Pending>, result: Result<Vec<EvalResponse>>, metrics: &Metrics) {
    match result {
        Ok(responses) => {
            for (p, resp) in batch.requests.into_iter().zip(responses) {
                metrics.completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                metrics.record_latency(p.submitted.elapsed().as_micros() as u64);
                let _ = p.resp.send(Ok(resp));
            }
        }
        Err(e) => {
            let msg = format!("batch execution failed: {e}");
            for p in batch.requests {
                metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = p.resp.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// CrossQuant with a *runtime* qmax — the AOT artifacts take (α, qmax) as
/// scalar inputs rather than a `Bits` enum, so the native fallback
/// mirrors that surface exactly (α = 1 is per-token, matching
/// `ActScheme`'s contract).
struct RuntimeCrossQuant {
    alpha: f32,
    qmax: f32,
}

impl ActQuantizer for RuntimeCrossQuant {
    fn name(&self) -> String {
        format!("crossquant[α={},qmax={}]", self.alpha, self.qmax)
    }

    fn delta_field(&self, x: &Matrix) -> DeltaField {
        crate::quant::debug_assert_finite(x, "RuntimeCrossQuant");
        cross_delta_field(x, self.alpha, self.qmax)
    }

    fn qmax(&self) -> f32 {
        self.qmax
    }
}

/// Builds the [`ActSite`] for one native scheme and reports its
/// batch-level aux scalar — scheme validation and aux accounting live in
/// exactly one place, shared by the scoring and generation paths.
enum SchemeSite {
    Identity(IdentitySite),
    Cross(QuantSite<RuntimeCrossQuant>),
    Remove(RemoveKernelSite),
}

impl SchemeSite {
    fn build(scheme: ActScheme) -> Result<SchemeSite> {
        match scheme {
            ActScheme::Fp => Ok(SchemeSite::Identity(IdentitySite)),
            // the native forward has no separate fused-graph variant —
            // both artifact flavours share one implementation here
            ActScheme::CrossQuant { alpha, qmax }
            | ActScheme::CrossQuantFused { alpha, qmax } => {
                // guard malformed client scalars: qmax ≤ 0 makes
                // clamp(-qmax, qmax) panic (min > max) inside the executor
                // thread, and a non-finite alpha yields NaN scale fields
                ensure!(
                    qmax.is_finite() && qmax > 0.0,
                    "crossquant qmax must be finite and > 0, got {qmax}"
                );
                ensure!(alpha.is_finite(), "crossquant alpha must be finite, got {alpha}");
                Ok(SchemeSite::Cross(QuantSite::new(RuntimeCrossQuant { alpha, qmax })))
            }
            ActScheme::RemoveKernel { theta } => {
                // guard before RemoveKernel::new: its assert would panic
                // the executor thread on a malformed client request
                ensure!(theta >= 0.0, "remove-kernel theta must be >= 0, got {theta}");
                Ok(SchemeSite::Remove(RemoveKernelSite::new(RemoveKernel::new(theta))))
            }
            ActScheme::CrossQuantStatic { .. } => {
                unreachable!("static scheme is served by the integer model")
            }
        }
    }

    fn site(&mut self) -> &mut dyn ActSite {
        match self {
            SchemeSite::Identity(s) => s,
            SchemeSite::Cross(s) => s,
            SchemeSite::Remove(s) => s,
        }
    }

    fn aux(&self) -> f32 {
        match self {
            SchemeSite::Identity(_) => 0.0,
            SchemeSite::Cross(s) => s.kernel_fraction(),
            SchemeSite::Remove(s) => s.removed_fraction(),
        }
    }
}

/// The offline executor: reconstructs each registered weight set into a
/// [`NativeModel`] (lazily, cached per set) and runs batches through the
/// native forward pass — scoring and KV-cached greedy generation.
/// Activation sites use the fused `quantize_with_report` sweep via
/// [`QuantSite`], and `aux` is measured over the whole executed batch —
/// the same batch-level scalar the PJRT artifacts emit.
struct NativeExecutor {
    cfg: ModelConfig,
    weight_sets: HashMap<String, Vec<f32>>,
    models: HashMap<String, NativeModel>,
    /// Calibrated static-scale integer models, keyed by (weight set, α in
    /// micro-units). Calibration runs once per cached key; the cache is
    /// genuine LRU, so an α sweep displaces the coldest model, never a
    /// hot one.
    static_models: LruCache<(String, i64), QuantizedModel>,
}

/// α is client-supplied: bound the static-model cache so an α sweep
/// cannot grow it without limit. Each entry is a full integer model that
/// also retains its dynamic-path state (FP weights + unfolded panels) —
/// the accepted cost of switching back, kept bounded by the cap.
const MAX_STATIC_MODELS: usize = 8;

impl NativeExecutor {
    fn new(cfg: ModelConfig, weight_sets: Vec<(String, Vec<f32>)>) -> NativeExecutor {
        NativeExecutor {
            cfg,
            weight_sets: weight_sets.into_iter().collect(),
            models: HashMap::new(),
            static_models: LruCache::new(MAX_STATIC_MODELS),
        }
    }

    fn model_for(&mut self, name: &str) -> Result<&NativeModel> {
        if !self.models.contains_key(name) {
            let flat = self
                .weight_sets
                .get(name)
                .ok_or_else(|| anyhow!("unknown weight set {name}"))?;
            let weights = Weights::from_config_flat(self.cfg, flat.clone())?;
            self.models.insert(name.to_string(), NativeModel::new(weights));
        }
        Ok(self.models.get(name).expect("inserted above"))
    }

    /// Lazily build + calibrate the integer static-scale model for one
    /// (weight set, α). Calibration runs the dynamic path over a fixed
    /// deterministic synthetic stream — the offline stand-in for a
    /// held-out calibration corpus — then folds the scales once; every
    /// subsequent request on this key is pure per-token-cost serving.
    fn static_model_for(&mut self, name: &str, alpha: f32) -> Result<&QuantizedModel> {
        let key = (name.to_string(), (alpha as f64 * 1e6).round() as i64);
        if !self.static_models.contains(&key) {
            let flat = self
                .weight_sets
                .get(name)
                .ok_or_else(|| anyhow!("unknown weight set {name}"))?;
            let weights = Weights::from_config_flat(self.cfg, flat.clone())?;
            let mut qm = QuantizedModel::new(
                &weights,
                Bits::Int8,
                Bits::Int8,
                QuantPath::CrossQuant { alpha },
            )?;
            let mut gen = CorpusGen::new(self.cfg.vocab, 0x5CA1E);
            let calib: Vec<Vec<u32>> = (0..8).map(|_| gen.sequence(self.cfg.seq_len)).collect();
            qm.calibrate_static(alpha, &calib)?;
            // LruCache::insert evicts the least-recently-used model once
            // the cap is reached — a re-requested hot α never re-pays its
            // calibration just because a sweep walked past it
            self.static_models.insert(key.clone(), qm);
        }
        Ok(self.static_models.get(&key).expect("inserted above"))
    }

    fn execute_batch(&mut self, batch: &ReadyBatch<Pending>) -> Result<Vec<EvalResponse>> {
        let vocab = self.cfg.vocab;
        for p in &batch.requests {
            ensure!(
                p.req.tokens.iter().all(|&t| (t as usize) < vocab),
                "token id out of range (vocab {vocab})"
            );
        }
        // requests in a batch share a key, so scheme and kind are uniform
        let scheme = batch.requests[0].req.scheme;
        if let ActScheme::CrossQuantStatic { alpha, qmax } = scheme {
            ensure!(alpha.is_finite() && (0.0..=1.0).contains(&alpha), "bad alpha {alpha}");
            // the integer model quantizes on the Bits grid; the native
            // static path serves INT8 activations (qmax 127) only
            ensure!(
                (qmax - 127.0).abs() < 0.5,
                "native static path serves the INT8 grid (qmax 127), got {qmax}"
            );
            let model = self.static_model_for(&batch.key.weight_set, alpha)?;
            let mut responses = Vec::with_capacity(batch.requests.len());
            for p in &batch.requests {
                // the integer path reports no kernel statistic (aux = 0)
                responses.push(match p.req.kind {
                    RequestKind::Score => EvalResponse {
                        nll: model.forward_nll(&p.req.tokens)?,
                        aux: 0.0,
                        generated: Vec::new(),
                    },
                    RequestKind::Generate { max_new_tokens } => EvalResponse {
                        nll: Vec::new(),
                        aux: 0.0,
                        generated: model.generate_greedy(&p.req.tokens, max_new_tokens)?,
                    },
                });
            }
            return Ok(responses);
        }
        let mut site = SchemeSite::build(scheme)?;
        let model = self.model_for(&batch.key.weight_set)?;
        let mut rows = Vec::with_capacity(batch.requests.len());
        for p in &batch.requests {
            rows.push(match p.req.kind {
                RequestKind::Score => (model.forward_nll(&p.req.tokens, site.site())?, Vec::new()),
                RequestKind::Generate { max_new_tokens } => (
                    Vec::new(),
                    model.generate_greedy(&p.req.tokens, max_new_tokens, site.site())?,
                ),
            });
        }
        let aux = site.aux();
        Ok(rows.into_iter().map(|(nll, generated)| EvalResponse { nll, aux, generated }).collect())
    }
}

fn execute_batch(
    runtime: &mut Runtime,
    cfg: ModelConfig,
    weights: &HashMap<String, xla::Literal>,
    batch: &ReadyBatch<Pending>,
) -> Result<Vec<EvalResponse>> {
    let key: &SchemeKey = &batch.key;
    let w = weights
        .get(&key.weight_set)
        .ok_or_else(|| anyhow!("unknown weight set {}", key.weight_set))?;

    // Assemble the fixed-size token batch; pad missing rows by repeating
    // the last request (their outputs are discarded).
    let mut rows: Vec<Vec<u32>> = batch.requests.iter().map(|p| p.req.tokens.clone()).collect();
    while rows.len() < cfg.eval_batch {
        rows.push(rows.last().expect("non-empty batch").clone());
    }
    anyhow::ensure!(rows.len() == cfg.eval_batch, "batch overflow: {}", rows.len());
    let tokens = tokens_literal(&rows, cfg.seq_len, 0)?;

    let scheme = batch.requests[0].req.scheme;
    let mut inputs = vec![tokens, w.clone()];
    for s in scheme.scalars() {
        inputs.push(crate::runtime::literal::scalar_literal(s));
    }
    let outputs = runtime.execute(key.artifact, &inputs)?;

    let nll_flat = literal_to_vec(&outputs[0])?;
    let aux = if outputs.len() > 1 { literal_to_scalar(&outputs[1])? } else { 0.0 };
    let per_row = cfg.seq_len - 1;
    let responses = batch
        .requests
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let row = &nll_flat[i * per_row..(i + 1) * per_row];
            // positions beyond the request's own length are padding
            let keep = p.req.tokens.len() - 1;
            EvalResponse { nll: row[..keep].to_vec(), aux, generated: Vec::new() }
        })
        .collect();
    Ok(responses)
}
