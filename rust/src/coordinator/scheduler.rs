//! Coordinator wiring: submit → batching thread → executor thread.
//!
//! The PJRT client is not Send, so a dedicated OS thread owns the
//! [`Runtime`] and all compiled executables; callers talk to it through
//! bounded channels. Backpressure is the bounded submit queue — when the
//! executor falls behind, `submit` blocks on queue capacity instead of
//! piling up unbounded work (the paper-agnostic core of any serving
//! router). The offline build has no tokio (Cargo.toml), so the async
//! surface is expressed with plain threads + channels; the protocol
//! (scheme-keyed dynamic batching with a flush deadline) is identical.
//!
//! Scoring requests execute as fixed-shape batches exactly as before.
//! **Generation requests route to the continuous-batching
//! [`Engine`](super::engine::Engine)**: the executor polls its channel
//! non-blockingly while sequences are active and runs one batched decode
//! step between polls, so late-arriving generations join the running
//! batch at step granularity instead of waiting behind earlier requests
//! (the serial PR 3 behaviour). Per-token streaming is exposed through
//! [`EvalCoordinator::submit_streaming`].
//!
//! When no PJRT runtime is linked (the offline build's `xla` stub), the
//! executor serves the same protocol through a [`NativeExecutor`]; a
//! PJRT-linked executor still routes static-scale scoring and all
//! generation through a lazily built native sidecar.
//!
//! [`EvalCoordinator::shutdown`] drains in-flight work (including active
//! engine sequences) and joins both threads; dropping every coordinator
//! clone triggers the same drain, so the threads are never leaked.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use super::batcher::{BatchAccumulator, ReadyBatch};
use super::engine::{Engine, EngineConfig, EngineModels, GenEvent, GenRequest};
use super::metrics::{Metrics, PRIORITY_DEFAULT};
use super::{ActScheme, SchemeKey};
use crate::corpus::CorpusGen;
use crate::model::config::ModelConfig;
use crate::model::{
    ActSite, IdentitySite, NativeModel, QuantSite, QuantizedModel, RemoveKernelSite, Weights,
};
use crate::obs::{self, KernelTelemetry, Span, SpanKind};
use crate::quant::artifact::Artifact;
use crate::quant::registry::{self, StaticSpec};
use crate::quant::{
    crossquant::cross_delta_field, remove_kernel::RemoveKernel, ActQuantizer, Bits, DeltaField,
};
use crate::runtime::literal::{literal_to_scalar, literal_to_vec, tokens_literal, vec_literal};
use crate::runtime::{ArtifactStore, Runtime};
use crate::tensor::Matrix;
use crate::util::LruCache;
use crate::xla;

/// What a request asks the executor to do with its tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// Score the sequence: per-position NLL (the original workload).
    Score,
    /// Greedy generation: treat the tokens as a prompt, prefill once,
    /// then KV-cached decode of `max_new_tokens` tokens.
    Generate { max_new_tokens: usize },
}

/// One evaluation request: a token sequence under a scheme + weight set.
#[derive(Clone)]
pub struct EvalRequest {
    pub tokens: Vec<u32>,
    pub scheme: ActScheme,
    /// Which registered weight set to run against (e.g. "w16", "w8", "w4g128").
    pub weight_set: String,
    pub kind: RequestKind,
    /// Trace id (0 = untraced). Assigned at the router or supplied via the
    /// `"trace"` wire field; every stage span records under this id.
    pub trace: u64,
    /// Scheduling class (0 = best-effort … 3 = interactive). Under
    /// overload the engine sheds lowest-priority-first; within a class,
    /// admission stays FIFO.
    pub priority: u8,
}

impl EvalRequest {
    /// A scoring request (per-position NLL).
    pub fn score(tokens: Vec<u32>, scheme: ActScheme, weight_set: impl Into<String>) -> Self {
        EvalRequest {
            tokens,
            scheme,
            weight_set: weight_set.into(),
            kind: RequestKind::Score,
            trace: 0,
            priority: PRIORITY_DEFAULT,
        }
    }

    /// A greedy-generation request (`tokens` is the prompt).
    pub fn generate(
        tokens: Vec<u32>,
        scheme: ActScheme,
        weight_set: impl Into<String>,
        max_new_tokens: usize,
    ) -> Self {
        EvalRequest {
            tokens,
            scheme,
            weight_set: weight_set.into(),
            kind: RequestKind::Generate { max_new_tokens },
            trace: 0,
            priority: PRIORITY_DEFAULT,
        }
    }

    /// Attach a trace id so per-stage spans record under it.
    pub fn with_trace(mut self, trace: u64) -> Self {
        self.trace = trace;
        self
    }

    /// Set the scheduling class (clamped to the highest defined class).
    pub fn with_priority(mut self, priority: u8) -> Self {
        self.priority = priority.min(super::metrics::NUM_PRIORITIES as u8 - 1);
        self
    }

    /// Batching key: scheme key plus the kind discriminant, so generation
    /// and scoring work under the same scheme never share an execution.
    pub fn key(&self) -> SchemeKey {
        let mut key = self.scheme.key(&self.weight_set);
        key.generate = matches!(self.kind, RequestKind::Generate { .. });
        key
    }
}

/// Per-request result.
#[derive(Clone, Debug)]
pub struct EvalResponse {
    /// Per-position NLL for the request's (unpadded) sequence — empty for
    /// generation requests.
    pub nll: Vec<f32>,
    /// Scheme-reported auxiliary scalar (kernel fraction / removed
    /// fraction). Batch-level for scoring; per-sequence for engine
    /// generation. 0.0 for FP and the integer path.
    pub aux: f32,
    /// Greedy-decoded token ids — empty for scoring requests.
    pub generated: Vec<u32>,
}

pub(crate) struct Pending {
    req: EvalRequest,
    resp: SyncSender<Result<EvalResponse>>,
    /// Streaming sink: one [`GenEvent`] per decoded token (generation
    /// requests submitted through `submit_streaming`).
    events: Option<Sender<GenEvent>>,
    /// Cooperative cancellation: set by [`ResponseHandle::cancel`] when
    /// the client disconnects; the engine reaps the sequence at the next
    /// tick and releases its KV slot.
    cancel: Arc<AtomicBool>,
    submitted: Instant,
}

impl Pending {
    fn into_gen_request(self) -> GenRequest {
        let max_new = match self.req.kind {
            RequestKind::Generate { max_new_tokens } => max_new_tokens,
            RequestKind::Score => unreachable!("scoring batches never route to the engine"),
        };
        let key = self.req.key();
        GenRequest {
            tokens: self.req.tokens,
            scheme: self.req.scheme,
            key,
            max_new,
            resp: self.resp,
            events: self.events,
            cancel: self.cancel,
            submitted: self.submitted,
            trace: self.req.trace,
            priority: self.req.priority,
        }
    }
}

/// Submit-side message: a request, or the shutdown marker that tells the
/// batcher to flush and exit (forwarded to the executor so it drains).
enum Msg {
    Req(Pending),
    Shutdown,
}

/// Batcher → executor message.
enum ExecMsg {
    Batch(ReadyBatch<Pending>),
    Shutdown,
}

/// Why the batcher/executor threads exited — recorded by a drop guard in
/// each thread so a client whose response sender vanished can report the
/// *cause* ("executor exited: executor thread panicked") instead of
/// blocking forever or guessing. A panic always overwrites a previously
/// recorded graceful exit; a graceful exit never overwrites a panic.
#[derive(Default)]
pub(crate) struct Epitaph(Mutex<Option<String>>);

impl Epitaph {
    fn record(&self, msg: String, force: bool) {
        let mut slot = match self.0.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if force || slot.is_none() {
            *slot = Some(msg);
        }
    }

    fn get(&self) -> Option<String> {
        match self.0.lock() {
            Ok(g) => g.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }
}

/// Drop guard owned by each coordinator thread: records how the thread
/// exited, panics included — `Drop` runs during unwinding, which is the
/// only hook that observes a panic from inside the dying thread.
struct ThreadExitGuard {
    epitaph: Arc<Epitaph>,
    thread: &'static str,
}

impl Drop for ThreadExitGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.epitaph.record(format!("{} thread panicked", self.thread), true);
        } else {
            self.epitaph.record(format!("{} thread shut down", self.thread), false);
        }
    }
}

/// Await-able response slot for one submitted request.
pub struct ResponseHandle {
    rx: Receiver<Result<EvalResponse>>,
    epitaph: Arc<Epitaph>,
    cancel: Arc<AtomicBool>,
}

impl ResponseHandle {
    fn executor_exited(&self) -> anyhow::Error {
        match self.epitaph.get() {
            Some(cause) => anyhow!("executor exited: {cause}"),
            None => anyhow!("executor exited: response channel dropped without a recorded cause"),
        }
    }

    /// Block until the request's batch has executed. If the executor died
    /// and dropped the response sender, returns a structured "executor
    /// exited" error instead of blocking the connection forever.
    pub fn wait(self) -> Result<EvalResponse> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(self.executor_exited()),
        }
    }

    pub fn wait_timeout(self, timeout: Duration) -> Result<EvalResponse> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err(anyhow!("request timed out")),
            Err(RecvTimeoutError::Disconnected) => Err(self.executor_exited()),
        }
    }

    /// Ask the engine to stop decoding this request (client went away).
    /// The sequence is reaped at the next engine tick, releasing its KV
    /// slot instead of decoding the rest of `max_new_tokens` for nobody.
    pub fn cancel(&self) {
        self.cancel.store(true, std::sync::atomic::Ordering::SeqCst);
    }
}

#[derive(Clone)]
pub struct EvalCoordinator {
    tx: SyncSender<Msg>,
    pub metrics: Arc<Metrics>,
    config: ModelConfig,
    /// Why the coordinator threads exited, for structured client errors.
    epitaph: Arc<Epitaph>,
    /// Batcher + executor handles, joined by [`EvalCoordinator::shutdown`].
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

pub struct CoordinatorConfig {
    /// Max requests per executed batch (must equal the artifact batch dim).
    pub batch_size: usize,
    /// Flush partial batches after this delay.
    pub max_batch_delay: Duration,
    /// Bounded submit queue (backpressure limit).
    pub max_queue: usize,
    /// Continuous-batching engine knobs (KV pool size, admission queue).
    pub engine: EngineConfig,
    /// Mounted `.cqa` deployment artifacts: (weight-set name, path). A
    /// static-scheme request whose (set, scheme, α) matches a mount is
    /// served from the artifact — mmap load, no FP weights, no
    /// calibration — replacing the lazy registry-build path for that key.
    pub artifacts: Vec<(String, PathBuf)>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batch_size: 8,
            max_batch_delay: Duration::from_millis(5),
            max_queue: 256,
            engine: EngineConfig::default(),
            artifacts: Vec::new(),
        }
    }
}

impl EvalCoordinator {
    /// Start the coordinator: spawns the batching thread and the executor
    /// thread. The PJRT client is constructed *inside* the executor thread
    /// (it is not Send). `weight_sets` registers every flat weight vector
    /// requests may reference (each is uploaded as a literal once).
    pub fn start(
        store: ArtifactStore,
        model_config: ModelConfig,
        weight_sets: Vec<(String, Vec<f32>)>,
        cfg: CoordinatorConfig,
    ) -> EvalCoordinator {
        let metrics = Arc::new(Metrics::new());
        let epitaph = Arc::new(Epitaph::default());
        let (tx, rx) = std::sync::mpsc::sync_channel::<Msg>(cfg.max_queue);
        let (batch_tx, batch_rx) = std::sync::mpsc::sync_channel::<ExecMsg>(16);

        let m1 = metrics.clone();
        let e1 = epitaph.clone();
        let batch_size = cfg.batch_size;
        let max_delay = cfg.max_batch_delay;
        let batcher = std::thread::Builder::new()
            .name("cq-batcher".into())
            .spawn(move || {
                let _exit = ThreadExitGuard { epitaph: e1, thread: "batcher" };
                batch_loop(rx, batch_tx, batch_size, max_delay, m1)
            })
            .expect("spawn batcher");

        let m2 = metrics.clone();
        let e2 = epitaph.clone();
        let engine_cfg = cfg.engine;
        let artifacts = cfg.artifacts;
        let executor = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let _exit = ThreadExitGuard { epitaph: e2, thread: "executor" };
                executor_loop(store, model_config, weight_sets, artifacts, batch_rx, m2, engine_cfg)
            })
            .expect("spawn executor");

        EvalCoordinator {
            tx,
            metrics,
            config: model_config,
            epitaph,
            threads: Arc::new(Mutex::new(vec![batcher, executor])),
        }
    }

    fn validate(&self, req: &EvalRequest) -> Result<()> {
        match req.kind {
            RequestKind::Score => ensure!(
                req.tokens.len() >= 2 && req.tokens.len() <= self.config.seq_len,
                "sequence length {} out of range",
                req.tokens.len()
            ),
            RequestKind::Generate { max_new_tokens } => {
                ensure!(!req.tokens.is_empty(), "generation needs a non-empty prompt");
                ensure!(max_new_tokens >= 1, "max_new_tokens must be >= 1");
                ensure!(
                    req.tokens.len() + max_new_tokens <= self.config.seq_len,
                    "prompt length {} + max_new_tokens {max_new_tokens} exceeds model \
                     context {}",
                    req.tokens.len(),
                    self.config.seq_len
                );
            }
        }
        Ok(())
    }

    fn send(
        &self,
        req: EvalRequest,
        events: Option<Sender<GenEvent>>,
    ) -> Result<ResponseHandle> {
        self.validate(&req)?;
        let (resp_tx, resp_rx) = std::sync::mpsc::sync_channel(1);
        let cancel = Arc::new(AtomicBool::new(false));
        self.metrics.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .send(Msg::Req(Pending {
                req,
                resp: resp_tx,
                events,
                cancel: cancel.clone(),
                submitted: Instant::now(),
            }))
            .map_err(|_| anyhow!("coordinator shut down"))?;
        Ok(ResponseHandle { rx: resp_rx, epitaph: self.epitaph.clone(), cancel })
    }

    /// Submit one request; returns a handle resolving when its batch has
    /// executed. Blocks when the submit queue is full (backpressure).
    pub fn submit(&self, req: EvalRequest) -> Result<ResponseHandle> {
        self.send(req, None)
    }

    /// Submit a generation request with per-token streaming: every decoded
    /// token arrives as a [`GenEvent`] on the returned receiver (which
    /// closes when the sequence finishes or fails), and the final
    /// [`EvalResponse`] resolves on the handle as usual. The stream is
    /// unbounded, so a slow consumer never stalls the engine's step loop.
    pub fn submit_streaming(
        &self,
        req: EvalRequest,
    ) -> Result<(Receiver<GenEvent>, ResponseHandle)> {
        ensure!(
            matches!(req.kind, RequestKind::Generate { .. }),
            "streaming requires a generation request"
        );
        let (ev_tx, ev_rx) = std::sync::mpsc::channel();
        let handle = self.send(req, Some(ev_tx))?;
        Ok((ev_rx, handle))
    }

    /// Graceful shutdown: flush pending batches, drain in-flight engine
    /// sequences (every accepted request still gets its response), and
    /// join the batcher and executor threads. Idempotent; later `submit`s
    /// fail with "coordinator shut down".
    pub fn shutdown(&self) {
        // a thread that panicked while holding the lock must not turn a
        // graceful shutdown into a second panic — take the poisoned guard
        let mut threads = match self.threads.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if threads.is_empty() {
            return; // already shut down
        }
        let _ = self.tx.send(Msg::Shutdown);
        for h in threads.drain(..) {
            let _ = h.join();
        }
    }

    /// Convenience: evaluate a set of sequences (pipelined through the
    /// batcher) and return (mean NLL, mean aux) — the building block of the
    /// PJRT eval path.
    pub fn evaluate_stream(
        &self,
        sequences: Vec<Vec<u32>>,
        scheme: ActScheme,
        weight_set: &str,
    ) -> Result<(f64, f32)> {
        let handles: Vec<ResponseHandle> = sequences
            .into_iter()
            .map(|tokens| self.submit(EvalRequest::score(tokens, scheme, weight_set)))
            .collect::<Result<_>>()?;
        let mut total = 0.0f64;
        let mut count = 0usize;
        let mut aux = 0.0f32;
        let mut n_resp = 0usize;
        for h in handles {
            let r = h.wait()?;
            total += r.nll.iter().map(|&v| v as f64).sum::<f64>();
            count += r.nll.len();
            aux += r.aux;
            n_resp += 1;
        }
        Ok((total / count.max(1) as f64, aux / n_resp.max(1) as f32))
    }
}

fn batch_loop(
    rx: Receiver<Msg>,
    batch_tx: SyncSender<ExecMsg>,
    batch_size: usize,
    max_delay: Duration,
    metrics: Arc<Metrics>,
) {
    let mut acc: BatchAccumulator<Pending> = BatchAccumulator::new(batch_size, max_delay);
    loop {
        let timeout = acc
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_secs(3600));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Req(p)) => {
                let key = p.req.key();
                if key.generate {
                    // continuous batching: the engine re-batches decode at
                    // step granularity, so holding generation requests for
                    // the dynamic-batching deadline would only add
                    // admission latency — dispatch immediately
                    dispatch(&batch_tx, ReadyBatch { key, requests: vec![p] }, &metrics);
                } else {
                    metrics.queue_depth.store(
                        acc.pending_requests() as u64 + 1,
                        std::sync::atomic::Ordering::Relaxed,
                    );
                    if let Some(batch) = acc.push(key, p, Instant::now()) {
                        dispatch(&batch_tx, batch, &metrics);
                    }
                }
            }
            Ok(Msg::Shutdown) => {
                for batch in acc.flush_all() {
                    dispatch(&batch_tx, batch, &metrics);
                }
                let _ = batch_tx.send(ExecMsg::Shutdown);
                return;
            }
            Err(RecvTimeoutError::Timeout) => { /* deadline tick */ }
            Err(RecvTimeoutError::Disconnected) => {
                // all coordinator clones dropped: same drain as shutdown
                for batch in acc.flush_all() {
                    dispatch(&batch_tx, batch, &metrics);
                }
                let _ = batch_tx.send(ExecMsg::Shutdown);
                return;
            }
        }
        for batch in acc.flush_expired(Instant::now()) {
            dispatch(&batch_tx, batch, &metrics);
        }
    }
}

fn dispatch(tx: &SyncSender<ExecMsg>, batch: ReadyBatch<Pending>, metrics: &Metrics) {
    metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    metrics
        .batched_requests
        .fetch_add(batch.requests.len() as u64, std::sync::atomic::Ordering::Relaxed);
    // sync_channel send blocks when the executor is saturated —
    // intended backpressure toward the batcher.
    let _ = tx.send(ExecMsg::Batch(batch));
}

/// The executor's model backend: PJRT runtime with a lazily built native
/// sidecar, or the native executor alone (offline builds). The sidecar is
/// built from the retained weight literals on first use, so plain PJRT
/// scoring never holds a second f32 copy of the weights.
enum Backend {
    Pjrt {
        runtime: Runtime,
        literals: HashMap<String, xla::Literal>,
        native: Option<NativeExecutor>,
        /// Handed to the native sidecar at its lazy construction.
        artifacts: Vec<(String, PathBuf)>,
        metrics: Arc<Metrics>,
    },
    Native(NativeExecutor),
}

impl Backend {
    fn native_mut(&mut self, cfg: ModelConfig) -> Result<&mut NativeExecutor> {
        match self {
            Backend::Native(n) => Ok(n),
            Backend::Pjrt { literals, native, artifacts, metrics, .. } => {
                if native.is_none() {
                    let sets = literals
                        .iter()
                        .map(|(k, v)| Ok((k.clone(), literal_to_vec(v)?)))
                        .collect::<Result<Vec<_>>>()?;
                    *native =
                        Some(NativeExecutor::new(cfg, sets, artifacts.clone(), metrics.clone()));
                }
                native.as_mut().ok_or_else(|| anyhow!("native sidecar failed to initialise"))
            }
        }
    }

    /// Execute one scoring batch on the right path: PJRT for artifact
    /// schemes, the native executor for static-scale scoring and for
    /// every scheme on offline builds.
    fn execute_scoring(
        &mut self,
        cfg: ModelConfig,
        batch: &ReadyBatch<Pending>,
    ) -> Result<Vec<EvalResponse>> {
        let first =
            batch.requests.first().ok_or_else(|| anyhow!("empty batch dispatched"))?;
        let needs_native = first.req.scheme.static_spec().is_some();
        if needs_native {
            return self.native_mut(cfg)?.execute_batch(batch);
        }
        match self {
            Backend::Native(n) => n.execute_batch(batch),
            Backend::Pjrt { runtime, literals, .. } => execute_batch(runtime, cfg, literals, batch),
        }
    }
}

/// The executor thread: scoring batches execute as they arrive; generation
/// batches are admitted into the continuous-batching engine, which is
/// ticked between channel polls. While sequences are decoding the channel
/// is polled non-blockingly, so a request arriving mid-generation joins
/// the very next batched step.
fn executor_loop(
    store: ArtifactStore,
    cfg: ModelConfig,
    weight_sets: Vec<(String, Vec<f32>)>,
    artifacts: Vec<(String, PathBuf)>,
    rx: Receiver<ExecMsg>,
    metrics: Arc<Metrics>,
    engine_cfg: EngineConfig,
) {
    let mut engine = Engine::new(engine_cfg, cfg, metrics.clone());
    let mut backend = match Runtime::new(store) {
        Ok(runtime) => {
            let literals: HashMap<String, xla::Literal> =
                weight_sets.into_iter().map(|(k, v)| (k, vec_literal(&v))).collect();
            Backend::Pjrt { runtime, literals, native: None, artifacts, metrics: metrics.clone() }
        }
        Err(e) => {
            // No PJRT runtime linked: serve the same protocol with the
            // native executor instead of failing every request.
            obs::log::warn(
                "executor",
                "PJRT unavailable; falling back to the native executor",
                &[("error", format!("{e}"))],
            );
            Backend::Native(NativeExecutor::new(cfg, weight_sets, artifacts, metrics.clone()))
        }
    };
    let mut draining = false;
    loop {
        let msg = if engine.is_idle() {
            if draining {
                return; // drained and told to stop
            }
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => return, // channel closed, nothing in flight
            }
        } else {
            match rx.try_recv() {
                Ok(m) => Some(m),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => {
                    draining = true;
                    None
                }
            }
        };
        match msg {
            Some(ExecMsg::Batch(batch)) => {
                if batch.key.generate {
                    for p in batch.requests {
                        engine.submit(p.into_gen_request());
                    }
                } else {
                    // queue wait ends here: the batch reached the executor
                    for p in &batch.requests {
                        let wait_us = p.submitted.elapsed().as_micros() as u64;
                        metrics.queue_wait.record_us(wait_us);
                        if p.req.trace != 0 {
                            metrics.spans.record(Span {
                                trace: p.req.trace,
                                kind: SpanKind::QueueWait,
                                start_us: obs::now_us().saturating_sub(wait_us),
                                dur_us: wait_us,
                                aux: 0,
                            });
                        }
                    }
                    let traced = batch.requests.iter().any(|p| p.req.trace != 0);
                    if traced {
                        crate::quant::gemm::gemm_timing_enable(true);
                    }
                    let t0 = Instant::now();
                    let result = backend.execute_scoring(cfg, &batch);
                    let fwd_us = t0.elapsed().as_micros() as u64;
                    metrics.batch_forward.record_us(fwd_us);
                    if traced {
                        let (gemm_calls, gemm_ns) = crate::quant::gemm::gemm_timing_take();
                        crate::quant::gemm::gemm_timing_enable(false);
                        let start_us = obs::now_us().saturating_sub(fwd_us);
                        let rows = batch.requests.len() as u64;
                        for p in batch.requests.iter().filter(|p| p.req.trace != 0) {
                            metrics.spans.record(Span {
                                trace: p.req.trace,
                                kind: SpanKind::BatchForward,
                                start_us,
                                dur_us: fwd_us,
                                aux: rows,
                            });
                            if gemm_calls > 0 {
                                metrics.spans.record(Span {
                                    trace: p.req.trace,
                                    kind: SpanKind::Gemm,
                                    start_us,
                                    dur_us: gemm_ns / 1_000,
                                    aux: gemm_calls,
                                });
                            }
                        }
                    }
                    metrics.executions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    respond(batch, result, &metrics);
                }
            }
            Some(ExecMsg::Shutdown) => draining = true,
            None => {}
        }
        if !engine.is_idle() {
            match backend.native_mut(cfg) {
                Ok(native) => engine.tick(native),
                Err(e) => engine.fail_all(&format!("engine models unavailable: {e}")),
            }
        }
    }
}

/// Fan a batch result out to its requests (success and failure paths
/// shared by the PJRT and native executors).
fn respond(batch: ReadyBatch<Pending>, result: Result<Vec<EvalResponse>>, metrics: &Metrics) {
    match result {
        Ok(responses) => {
            for (p, resp) in batch.requests.into_iter().zip(responses) {
                metrics.mark_completed();
                metrics.record_latency(p.submitted.elapsed().as_micros() as u64);
                let _ = p.resp.send(Ok(resp));
            }
        }
        Err(e) => {
            let msg = format!("batch execution failed: {e}");
            for p in batch.requests {
                metrics.mark_failed();
                let _ = p.resp.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// CrossQuant with a *runtime* qmax — the AOT artifacts take (α, qmax) as
/// scalar inputs rather than a `Bits` enum, so the native fallback
/// mirrors that surface exactly (α = 1 is per-token, matching
/// `ActScheme`'s contract).
struct RuntimeCrossQuant {
    alpha: f32,
    qmax: f32,
}

impl ActQuantizer for RuntimeCrossQuant {
    fn name(&self) -> String {
        format!("crossquant[α={},qmax={}]", self.alpha, self.qmax)
    }

    fn delta_field(&self, x: &Matrix) -> DeltaField {
        crate::quant::debug_assert_finite(x, "RuntimeCrossQuant");
        cross_delta_field(x, self.alpha, self.qmax)
    }

    fn qmax(&self) -> f32 {
        self.qmax
    }
}

/// Builds the [`ActSite`] for one native scheme and reports its aux
/// scalar — scheme validation and aux accounting live in exactly one
/// place, shared by the scoring path and the engine (which keeps one
/// site per sequence, so aux is per-sequence under continuous batching).
pub(crate) enum SchemeSite {
    Identity(IdentitySite),
    Cross(QuantSite<RuntimeCrossQuant>),
    Remove(RemoveKernelSite),
}

impl SchemeSite {
    /// `telemetry` (when given) attaches live quantization-kernel
    /// sampling to dynamic-scheme sites — a no-op unless the shared
    /// [`KernelTelemetry`] has been enabled via `--kernel-telemetry`.
    pub(crate) fn build(
        scheme: ActScheme,
        telemetry: Option<Arc<KernelTelemetry>>,
    ) -> Result<SchemeSite> {
        match scheme {
            ActScheme::Fp => Ok(SchemeSite::Identity(IdentitySite)),
            // the native forward has no separate fused-graph variant —
            // both artifact flavours share one implementation here
            ActScheme::CrossQuant { alpha, qmax }
            | ActScheme::CrossQuantFused { alpha, qmax } => {
                // guard malformed client scalars: qmax ≤ 0 makes
                // clamp(-qmax, qmax) panic (min > max) inside the executor
                // thread, and a non-finite alpha yields NaN scale fields
                ensure!(
                    qmax.is_finite() && qmax > 0.0,
                    "crossquant qmax must be finite and > 0, got {qmax}"
                );
                ensure!(alpha.is_finite(), "crossquant alpha must be finite, got {alpha}");
                let mut site = QuantSite::new(RuntimeCrossQuant { alpha, qmax });
                if let Some(t) = telemetry {
                    site = site.with_telemetry(t);
                }
                Ok(SchemeSite::Cross(site))
            }
            ActScheme::RemoveKernel { theta } => {
                // guard before RemoveKernel::new: its assert would panic
                // the executor thread on a malformed client request
                ensure!(theta >= 0.0, "remove-kernel theta must be >= 0, got {theta}");
                Ok(SchemeSite::Remove(RemoveKernelSite::new(RemoveKernel::new(theta))))
            }
            ActScheme::CrossQuantStatic { .. }
            | ActScheme::SmoothQuant { .. }
            | ActScheme::Awq { .. }
            | ActScheme::Gptq { .. }
            | ActScheme::Lorc { .. } => {
                unreachable!("static scheme is served by the integer model")
            }
        }
    }

    pub(crate) fn site(&mut self) -> &mut dyn ActSite {
        match self {
            SchemeSite::Identity(s) => s,
            SchemeSite::Cross(s) => s,
            SchemeSite::Remove(s) => s,
        }
    }

    pub(crate) fn aux(&self) -> f32 {
        match self {
            SchemeSite::Identity(_) => 0.0,
            SchemeSite::Cross(s) => s.kernel_fraction(),
            SchemeSite::Remove(s) => s.removed_fraction(),
        }
    }
}

/// The offline executor: reconstructs each registered weight set into a
/// [`NativeModel`] (lazily, cached per set) and runs scoring batches
/// through the native forward pass; the continuous-batching engine
/// borrows its models through [`EngineModels`] for generation.
/// Activation sites use the fused `quantize_with_report` sweep via
/// [`QuantSite`], and scoring `aux` is measured over the whole executed
/// batch — the same batch-level scalar the PJRT artifacts emit.
pub(crate) struct NativeExecutor {
    cfg: ModelConfig,
    weight_sets: HashMap<String, Vec<f32>>,
    models: HashMap<String, NativeModel>,
    /// Calibrated static-scale integer models, keyed by (weight set,
    /// registry spec key) — scheme id, α in micro-units, LoRC rank. The
    /// registry build runs once per cached key; the cache is genuine LRU,
    /// so a scheme/α sweep displaces the coldest model, never a hot one.
    /// Artifact-backed models share the cache under the same keys — a
    /// mounted artifact is just a much cheaper way to fill it.
    static_models: LruCache<(String, (u16, i64, usize)), QuantizedModel>,
    /// The artifact repository, keyed by weight-set name. Static requests
    /// hitting a matching (set, scheme, α) rebuild the model from the
    /// retained mapping — no FP weights, no calibration — instead of the
    /// lazy registry-build path.
    artifacts: HashMap<String, MountState>,
    metrics: Arc<Metrics>,
}

/// One mounted `.cqa`: the artifact is opened (and CRC-verified) once at
/// mount and retained, so request-time model builds are pure struct
/// rebuilds over the already-validated mapping — no re-read, no window
/// for the file to change or vanish between mount and first request.
struct MountedArtifact {
    alpha_micro: i64,
    path: PathBuf,
    artifact: Artifact,
}

/// A mount slot: the retained validated artifact, or the reason the
/// mount failed — kept so requests against a broken mount get that
/// precise error instead of a generic "unknown weight set".
enum MountState {
    Ready(MountedArtifact),
    Failed(String),
}

/// The (weight set, α) cache key's α quantization — one definition shared
/// by the mount table and the request path, so the two can never drift
/// into silently missing each other.
fn alpha_micro(alpha: f32) -> i64 {
    (alpha as f64 * 1e6).round() as i64
}

/// α is client-supplied: bound the static-model cache so an α sweep
/// cannot grow it without limit. Each entry is a full integer model that
/// also retains its dynamic-path state (FP weights + unfolded panels) —
/// the accepted cost of switching back, kept bounded by the cap.
const MAX_STATIC_MODELS: usize = 8;

impl NativeExecutor {
    fn new(
        cfg: ModelConfig,
        weight_sets: Vec<(String, Vec<f32>)>,
        artifact_mounts: Vec<(String, PathBuf)>,
        metrics: Arc<Metrics>,
    ) -> NativeExecutor {
        // mount artifacts up front: the one full open validates every CRC
        // (a corrupt file surfaces at startup as one structured log line)
        // and the parsed artifact is retained for request-time rebuilds
        let mut artifacts = HashMap::new();
        for (name, path) in artifact_mounts {
            let state = match Artifact::open(&path) {
                Ok(artifact) => {
                    metrics
                        .artifacts_mounted
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let am = alpha_micro(artifact.alpha);
                    MountState::Ready(MountedArtifact { alpha_micro: am, path, artifact })
                }
                Err(e) => {
                    obs::log::error(
                        "executor",
                        "failed to mount artifact",
                        &[
                            ("path", path.display().to_string()),
                            ("weight_set", name.clone()),
                            ("error", format!("{e:#}")),
                        ],
                    );
                    MountState::Failed(format!("{e:#}"))
                }
            };
            artifacts.insert(name, state);
        }
        NativeExecutor {
            cfg,
            weight_sets: weight_sets.into_iter().collect(),
            models: HashMap::new(),
            static_models: LruCache::new(MAX_STATIC_MODELS),
            artifacts,
            metrics,
        }
    }

    /// Structured "no such set" error, aware of artifact-only mounts.
    fn unknown_set(&self, name: &str) -> anyhow::Error {
        match self.artifacts.get(name) {
            Some(MountState::Ready(m)) => anyhow!(
                "weight set {name} is artifact-only (mounted at α={}): only the \
                 artifact's own scheme at that α is served without FP weights",
                m.alpha_micro as f64 / 1e6
            ),
            Some(MountState::Failed(e)) => {
                anyhow!("weight set {name}'s mounted artifact failed to load: {e}")
            }
            None => anyhow!("unknown weight set {name}"),
        }
    }

    fn model_for(&mut self, name: &str) -> Result<&NativeModel> {
        if !self.models.contains_key(name) {
            let flat = self.weight_sets.get(name).ok_or_else(|| self.unknown_set(name))?;
            let weights = Weights::from_config_flat(self.cfg, flat.clone())?;
            self.models.insert(name.to_string(), NativeModel::new(weights));
        }
        self.models.get(name).ok_or_else(|| anyhow!("model cache lost entry for {name}"))
    }

    /// Lazily build the integer static-scale model for one (weight set,
    /// registry spec). A mounted artifact with a matching (set, scheme,
    /// α) is loaded in place (mmap — the deployment fast path); otherwise
    /// the registry pipeline quantizes + calibrates over a fixed
    /// deterministic synthetic stream — the offline stand-in for a
    /// held-out calibration corpus — and folds the scales once. Either
    /// way every subsequent request on this key is pure per-token-cost
    /// serving.
    fn static_model_for(&mut self, name: &str, spec: &StaticSpec) -> Result<&QuantizedModel> {
        let key = (name.to_string(), spec.cache_key());
        if !self.static_models.contains(&key) {
            let qm = self.build_static_model(name, spec)?;
            // LruCache::insert evicts the least-recently-used model once
            // the cap is reached — a re-requested hot scheme never
            // re-pays its calibration (or artifact load) just because a
            // sweep walked past it
            self.static_models.insert(key.clone(), qm);
        }
        self.static_models
            .get(&key)
            .ok_or_else(|| anyhow!("static model cache lost entry for {name}"))
    }

    fn build_static_model(&mut self, name: &str, spec: &StaticSpec) -> Result<QuantizedModel> {
        if let Some(MountState::Ready(m)) = self.artifacts.get(name) {
            // the artifact pins the scheme that produced it (header scheme
            // id) and the α it was calibrated at — serve it only for that
            // exact request shape, never as a stand-in for another scheme
            let eff_alpha = alpha_micro(registry::effective_alpha(spec.id, spec.alpha));
            if m.artifact.scheme == spec.id.artifact_code() && m.alpha_micro == eff_alpha {
                let t0 = Instant::now();
                // rebuild over the mapping retained at mount — no re-read,
                // no re-validation, no window for the file to have changed
                let qm = QuantizedModel::from_artifact(&m.artifact)
                    .with_context(|| format!("loading mounted artifact {}", m.path.display()))?;
                ensure!(
                    qm.config == self.cfg,
                    "artifact config {:?} does not match the serving config {:?}",
                    qm.config,
                    self.cfg
                );
                let rl = std::sync::atomic::Ordering::Relaxed;
                let load_us = t0.elapsed().as_micros() as u64;
                self.metrics.artifact_loads.fetch_add(1, rl);
                self.metrics.artifact_load_us.fetch_add(load_us, rl);
                // trace 0: a cold load is shared work, visible in the full
                // ring dump rather than attributed to one request
                self.metrics.spans.record(Span {
                    trace: 0,
                    kind: SpanKind::ArtifactLoad,
                    start_us: obs::now_us().saturating_sub(load_us),
                    dur_us: load_us,
                    aux: 0,
                });
                return Ok(qm);
            }
        }
        let flat = self.weight_sets.get(name).ok_or_else(|| self.unknown_set(name))?;
        let weights = Weights::from_config_flat(self.cfg, flat.clone())?;
        let mut gen = CorpusGen::new(self.cfg.vocab, 0x5CA1E);
        let calib: Vec<Vec<u32>> = (0..8).map(|_| gen.sequence(self.cfg.seq_len)).collect();
        let qm = registry::build_static_model(&weights, Bits::Int8, Bits::Int8, spec, &calib)?;
        self.metrics.static_calibrations.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(qm)
    }

    fn execute_batch(&mut self, batch: &ReadyBatch<Pending>) -> Result<Vec<EvalResponse>> {
        ensure!(!batch.key.generate, "generation batches are served by the engine");
        let vocab = self.cfg.vocab;
        for p in &batch.requests {
            ensure!(
                p.req.tokens.iter().all(|&t| (t as usize) < vocab),
                "token id out of range (vocab {vocab})"
            );
        }
        // requests in a batch share a key, so the scheme is uniform
        let scheme = batch
            .requests
            .first()
            .ok_or_else(|| anyhow!("empty batch dispatched"))?
            .req
            .scheme;
        if let Some((spec, qmax)) = scheme.static_spec() {
            let alpha = spec.alpha;
            ensure!(alpha.is_finite() && (0.0..=1.0).contains(&alpha), "bad alpha {alpha}");
            // the integer model quantizes on the Bits grid; the native
            // static path serves INT8 activations (qmax 127) only
            ensure!(
                (qmax - 127.0).abs() < 0.5,
                "native static path serves the INT8 grid (qmax 127), got {qmax}"
            );
            let model = self.static_model_for(&batch.key.weight_set, &spec)?;
            return batch
                .requests
                .iter()
                .map(|p| {
                    // the integer path reports no kernel statistic (aux = 0)
                    Ok(EvalResponse {
                        nll: model.forward_nll(&p.req.tokens)?,
                        aux: 0.0,
                        generated: Vec::new(),
                    })
                })
                .collect();
        }
        let mut site = SchemeSite::build(scheme, Some(self.metrics.kernel.clone()))?;
        let model = self.model_for(&batch.key.weight_set)?;
        let mut rows = Vec::with_capacity(batch.requests.len());
        for p in &batch.requests {
            rows.push(model.forward_nll(&p.req.tokens, site.site())?);
        }
        let aux = site.aux();
        Ok(rows.into_iter().map(|nll| EvalResponse { nll, aux, generated: Vec::new() }).collect())
    }
}

impl EngineModels for NativeExecutor {
    fn native_model(&mut self, weight_set: &str) -> Result<&NativeModel> {
        self.model_for(weight_set)
    }

    fn static_model(&mut self, weight_set: &str, spec: &StaticSpec) -> Result<&QuantizedModel> {
        self.static_model_for(weight_set, spec)
    }
}

fn execute_batch(
    runtime: &mut Runtime,
    cfg: ModelConfig,
    weights: &HashMap<String, xla::Literal>,
    batch: &ReadyBatch<Pending>,
) -> Result<Vec<EvalResponse>> {
    let key: &SchemeKey = &batch.key;
    let w = weights
        .get(&key.weight_set)
        .ok_or_else(|| anyhow!("unknown weight set {}", key.weight_set))?;

    // Assemble the fixed-size token batch; pad missing rows by repeating
    // the last request (their outputs are discarded).
    let mut rows: Vec<Vec<u32>> = batch.requests.iter().map(|p| p.req.tokens.clone()).collect();
    let pad = rows.last().cloned().ok_or_else(|| anyhow!("empty batch dispatched"))?;
    while rows.len() < cfg.eval_batch {
        rows.push(pad.clone());
    }
    anyhow::ensure!(rows.len() == cfg.eval_batch, "batch overflow: {}", rows.len());
    let tokens = tokens_literal(&rows, cfg.seq_len, 0)?;

    let scheme = batch.requests[0].req.scheme;
    let mut inputs = vec![tokens, w.clone()];
    for s in scheme.scalars() {
        inputs.push(crate::runtime::literal::scalar_literal(s));
    }
    let outputs = runtime.execute(key.artifact, &inputs)?;

    let nll_flat = literal_to_vec(&outputs[0])?;
    let aux = if outputs.len() > 1 { literal_to_scalar(&outputs[1])? } else { 0.0 };
    let per_row = cfg.seq_len - 1;
    let responses = batch
        .requests
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let row = &nll_flat[i * per_row..(i + 1) * per_row];
            // positions beyond the request's own length are padding
            let keep = p.req.tokens.len() - 1;
            EvalResponse { nll: row[..keep].to_vec(), aux, generated: Vec::new() }
        })
        .collect();
    Ok(responses)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn orphan_handle(epitaph: Arc<Epitaph>) -> ResponseHandle {
        // build a handle whose sender is already gone — the state a client
        // is left in when the executor dies mid-request
        let (tx, rx) = std::sync::mpsc::sync_channel::<Result<EvalResponse>>(1);
        drop(tx);
        ResponseHandle { rx, epitaph, cancel: Arc::new(AtomicBool::new(false)) }
    }

    #[test]
    fn wait_reports_executor_panic_instead_of_blocking() {
        let epitaph = Arc::new(Epitaph::default());
        epitaph.record("executor thread panicked".into(), true);
        let err = orphan_handle(epitaph).wait().unwrap_err().to_string();
        assert!(err.contains("executor exited"), "got: {err}");
        assert!(err.contains("panicked"), "got: {err}");
    }

    #[test]
    fn wait_timeout_reports_disconnect_cause() {
        let epitaph = Arc::new(Epitaph::default());
        epitaph.record("executor thread shut down".into(), false);
        let err = orphan_handle(epitaph)
            .wait_timeout(Duration::from_millis(50))
            .unwrap_err()
            .to_string();
        assert!(err.contains("executor exited"), "got: {err}");
        assert!(err.contains("shut down"), "got: {err}");
    }

    #[test]
    fn epitaph_panic_outranks_graceful_exit() {
        let e = Epitaph::default();
        e.record("batcher thread shut down".into(), false);
        e.record("executor thread panicked".into(), true);
        e.record("executor thread shut down".into(), false);
        assert_eq!(e.get().as_deref(), Some("executor thread panicked"));
    }

    #[test]
    fn exit_guard_records_graceful_exit() {
        let epitaph = Arc::new(Epitaph::default());
        let e = epitaph.clone();
        std::thread::spawn(move || {
            let _exit = ThreadExitGuard { epitaph: e, thread: "executor" };
        })
        .join()
        .unwrap();
        assert_eq!(epitaph.get().as_deref(), Some("executor thread shut down"));
    }

    #[test]
    fn exit_guard_records_panic() {
        let epitaph = Arc::new(Epitaph::default());
        let e = epitaph.clone();
        let res = std::thread::spawn(move || {
            let _exit = ThreadExitGuard { epitaph: e, thread: "executor" };
            panic!("boom");
        })
        .join();
        assert!(res.is_err());
        assert_eq!(epitaph.get().as_deref(), Some("executor thread panicked"));
    }
}
