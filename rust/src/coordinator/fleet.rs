//! The worker-fleet supervisor: spawns N `repro serve --worker`
//! processes (each mmap-ing the same `.cqa` artifact through the
//! zero-copy loader, so the page cache is shared across the fleet),
//! health-checks them with heartbeat pings, and restarts crashed or
//! wedged workers with exponential backoff plus a crash-loop circuit
//! breaker.
//!
//! Worker lifecycle:
//!
//! * spawn → the worker binds `127.0.0.1:0` and prints
//!   `CROSSQUANT_WORKER_READY addr=<ip:port>` on stdout; a per-spawn
//!   reader thread parses that line and publishes the address.
//! * alive → the supervisor pings `{"cmd":"ping"}` every heartbeat
//!   interval; [`FleetConfig::heartbeat_misses`] consecutive failures
//!   mean the worker is wedged and it is killed (the next tick sees the
//!   exit and schedules the restart).
//! * crashed → restart after an exponential backoff, reset when the
//!   process had been up longer than the breaker window; more than
//!   [`FleetConfig::breaker_crashes`] crashes inside the window trips
//!   the circuit breaker and the worker stays down (the router sheds or
//!   retries around it) instead of burning CPU on a crash loop.
//!
//! The supervisor never touches request traffic — that is
//! [`super::router::Router`]'s job; the two share [`Worker`] state
//! (address, health, in-flight count) through atomics.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::metrics::FleetMetrics;
use crate::obs::log as olog;

/// The stdout line a worker prints once its listener is bound.
pub const READY_PREFIX: &str = "CROSSQUANT_WORKER_READY addr=";

#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Worker processes to keep alive.
    pub num_workers: usize,
    /// Worker executable (normally `std::env::current_exe()`).
    pub worker_cmd: PathBuf,
    /// Arguments for every worker (e.g. `serve --worker --artifact …`).
    pub worker_args: Vec<String>,
    /// Environment applied to every worker.
    pub worker_env: Vec<(String, String)>,
    /// Extra per-index environment (e.g. a `CROSSQUANT_FAULT` plan on
    /// worker 0 only); indexes beyond the vec get nothing extra.
    pub per_worker_env: Vec<Vec<(String, String)>>,
    /// Heartbeat / supervision tick interval.
    pub heartbeat_interval: Duration,
    /// Per-ping connect/read timeout.
    pub heartbeat_timeout: Duration,
    /// Consecutive failed pings before a worker is declared wedged.
    pub heartbeat_misses: u32,
    /// First restart delay after a crash.
    pub initial_backoff: Duration,
    /// Backoff cap.
    pub max_backoff: Duration,
    /// Crash-counting window for the circuit breaker (also the uptime
    /// after which the backoff resets to `initial_backoff`).
    pub breaker_window: Duration,
    /// Crashes within the window that trip the breaker.
    pub breaker_crashes: usize,
    /// How long a freshly spawned worker may take to print its ready
    /// line before it is treated as wedged.
    pub ready_timeout: Duration,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            num_workers: 2,
            worker_cmd: PathBuf::new(),
            worker_args: Vec::new(),
            worker_env: Vec::new(),
            per_worker_env: Vec::new(),
            heartbeat_interval: Duration::from_millis(250),
            heartbeat_timeout: Duration::from_millis(1000),
            heartbeat_misses: 3,
            initial_backoff: Duration::from_millis(100),
            max_backoff: Duration::from_secs(5),
            breaker_window: Duration::from_secs(10),
            breaker_crashes: 5,
            ready_timeout: Duration::from_secs(30),
        }
    }
}

/// Shared per-worker state: written by the supervisor, read (and
/// in-flight-counted) by the router.
pub struct Worker {
    index: usize,
    addr: Mutex<Option<SocketAddr>>,
    healthy: AtomicBool,
    breaker_open: AtomicBool,
    in_flight: AtomicUsize,
    restarts: AtomicU64,
    pid: AtomicU32,
}

/// Point-in-time snapshot of one worker (metrics / tests).
#[derive(Clone, Debug)]
pub struct WorkerStatus {
    pub index: usize,
    pub healthy: bool,
    pub addr: Option<SocketAddr>,
    pub in_flight: usize,
    pub restarts: u64,
    pub breaker_open: bool,
    pub pid: Option<u32>,
}

impl Worker {
    fn new(index: usize) -> Worker {
        Worker {
            index,
            addr: Mutex::new(None),
            healthy: AtomicBool::new(false),
            breaker_open: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            restarts: AtomicU64::new(0),
            pid: AtomicU32::new(0),
        }
    }

    pub fn index(&self) -> usize {
        self.index
    }

    pub fn addr(&self) -> Option<SocketAddr> {
        match self.addr.lock() {
            Ok(g) => *g,
            Err(poisoned) => *poisoned.into_inner(),
        }
    }

    fn set_addr(&self, addr: Option<SocketAddr>) {
        match self.addr.lock() {
            Ok(mut g) => *g = addr,
            Err(poisoned) => *poisoned.into_inner() = addr,
        }
    }

    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::SeqCst)
    }

    pub fn breaker_open(&self) -> bool {
        self.breaker_open.load(Ordering::SeqCst)
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::SeqCst)
    }

    /// OS pid of the current process incarnation (tests `kill -9` it).
    pub fn pid(&self) -> Option<u32> {
        match self.pid.load(Ordering::SeqCst) {
            0 => None,
            p => Some(p),
        }
    }

    /// Router-side load accounting around one dispatched request.
    pub fn begin_request(&self) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
    }

    pub fn end_request(&self) {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
    }

    pub fn status(&self) -> WorkerStatus {
        WorkerStatus {
            index: self.index,
            healthy: self.is_healthy(),
            addr: self.addr(),
            in_flight: self.in_flight(),
            restarts: self.restarts(),
            breaker_open: self.breaker_open(),
            pid: self.pid(),
        }
    }
}

/// Restart scheduling: exponential backoff with reset-on-stable-uptime,
/// plus the crash-loop circuit breaker. Pure bookkeeping, unit-tested
/// without processes.
struct RestartPolicy {
    backoff: Duration,
    initial: Duration,
    max: Duration,
    window: Duration,
    limit: usize,
    crashes: VecDeque<Instant>,
}

impl RestartPolicy {
    fn new(cfg: &FleetConfig) -> RestartPolicy {
        RestartPolicy {
            backoff: cfg.initial_backoff,
            initial: cfg.initial_backoff,
            max: cfg.max_backoff,
            window: cfg.breaker_window,
            limit: cfg.breaker_crashes.max(1),
            crashes: VecDeque::new(),
        }
    }

    /// Record a crash observed at `now` after `uptime` of running.
    /// Returns the delay before the next restart attempt, or `None` when
    /// the crash-loop breaker trips.
    fn on_crash(&mut self, now: Instant, uptime: Duration) -> Option<Duration> {
        if uptime > self.window {
            // the process was stable; this is a fresh failure, not a loop
            self.backoff = self.initial;
            self.crashes.clear();
        }
        self.crashes.push_back(now);
        while let Some(&front) = self.crashes.front() {
            if now.duration_since(front) > self.window {
                self.crashes.pop_front();
            } else {
                break;
            }
        }
        if self.crashes.len() >= self.limit {
            return None;
        }
        let delay = self.backoff;
        self.backoff = (self.backoff * 2).min(self.max);
        Some(delay)
    }
}

/// Supervisor-private state for one worker slot.
struct Slot {
    worker: Arc<Worker>,
    child: Option<Child>,
    spawned_at: Instant,
    /// When the next spawn attempt may run (`None` = spawn immediately
    /// unless the breaker is open).
    restart_at: Option<Instant>,
    policy: RestartPolicy,
    hb_misses: u32,
    /// Set once this incarnation printed its ready line.
    ready_seen: Arc<AtomicBool>,
}

pub struct Fleet {
    workers: Vec<Arc<Worker>>,
    metrics: Arc<FleetMetrics>,
    shutdown: Arc<AtomicBool>,
    supervisor: Mutex<Option<JoinHandle<()>>>,
}

impl Fleet {
    /// Spawn the fleet and its supervision thread. Workers come up
    /// asynchronously — use [`Fleet::wait_ready`] to block until they
    /// are serving.
    pub fn start(cfg: FleetConfig, metrics: Arc<FleetMetrics>) -> Result<Fleet> {
        anyhow::ensure!(cfg.num_workers >= 1, "a fleet needs at least one worker");
        anyhow::ensure!(
            !cfg.worker_cmd.as_os_str().is_empty(),
            "fleet config has no worker command"
        );
        let workers: Vec<Arc<Worker>> =
            (0..cfg.num_workers).map(|i| Arc::new(Worker::new(i))).collect();
        let shutdown = Arc::new(AtomicBool::new(false));
        let sup_workers = workers.clone();
        let sup_shutdown = shutdown.clone();
        let sup_metrics = metrics.clone();
        let supervisor = std::thread::Builder::new()
            .name("cq-fleet".into())
            .spawn(move || supervise(cfg, sup_workers, sup_metrics, sup_shutdown))
            .context("spawning fleet supervisor")?;
        Ok(Fleet { workers, metrics, shutdown, supervisor: Mutex::new(Some(supervisor)) })
    }

    pub fn workers(&self) -> &[Arc<Worker>] {
        &self.workers
    }

    pub fn metrics(&self) -> &Arc<FleetMetrics> {
        &self.metrics
    }

    pub fn status(&self) -> Vec<WorkerStatus> {
        self.workers.iter().map(|w| w.status()).collect()
    }

    /// Block until every worker is healthy (or `timeout` elapses).
    pub fn wait_ready(&self, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            if self.workers.iter().all(|w| w.is_healthy()) {
                return Ok(());
            }
            if Instant::now() > deadline {
                let down: Vec<usize> = self
                    .workers
                    .iter()
                    .filter(|w| !w.is_healthy())
                    .map(|w| w.index())
                    .collect();
                return Err(anyhow!("fleet not ready after {timeout:?}: workers {down:?} down"));
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Stop supervising and kill every worker. Idempotent.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let handle = match self.supervisor.lock() {
            Ok(mut g) => g.take(),
            Err(poisoned) => poisoned.into_inner().take(),
        };
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Parse a worker's ready line into its socket address.
fn parse_ready_line(line: &str) -> Option<SocketAddr> {
    line.trim().strip_prefix(READY_PREFIX)?.trim().parse().ok()
}

/// One `{"cmd":"ping"}` round-trip against a worker. Control frames only
/// — heartbeats must never advance a worker's fault-injection counter.
fn ping(addr: SocketAddr, timeout: Duration) -> bool {
    let Ok(stream) = TcpStream::connect_timeout(&addr, timeout) else {
        return false;
    };
    if stream.set_read_timeout(Some(timeout)).is_err()
        || stream.set_write_timeout(Some(timeout)).is_err()
    {
        return false;
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return false,
    };
    if writer.write_all(b"{\"cmd\": \"ping\"}\n").is_err() {
        return false;
    }
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    matches!(reader.read_line(&mut line), Ok(n) if n > 0 && line.contains("\"ok\": true"))
}

fn spawn_worker(cfg: &FleetConfig, slot: &mut Slot, first_spawn: bool) {
    let index = slot.worker.index();
    let mut cmd = Command::new(&cfg.worker_cmd);
    cmd.args(&cfg.worker_args)
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    for (k, v) in &cfg.worker_env {
        cmd.env(k, v);
    }
    if let Some(extra) = cfg.per_worker_env.get(index) {
        for (k, v) in extra {
            cmd.env(k, v);
        }
    }
    cmd.env("CROSSQUANT_WORKER_INDEX", index.to_string());
    slot.worker.set_addr(None);
    slot.worker.healthy.store(false, Ordering::SeqCst);
    slot.hb_misses = 0;
    slot.ready_seen = Arc::new(AtomicBool::new(false));
    match cmd.spawn() {
        Ok(mut child) => {
            slot.worker.pid.store(child.id(), Ordering::SeqCst);
            if !first_spawn {
                slot.worker.restarts.fetch_add(1, Ordering::SeqCst);
            }
            if let Some(stdout) = child.stdout.take() {
                // per-spawn reader: publishes the ready line's address,
                // then drains stdout until the process dies
                let worker = slot.worker.clone();
                let ready = slot.ready_seen.clone();
                let _ = std::thread::Builder::new()
                    .name(format!("cq-worker-{index}-out"))
                    .spawn(move || {
                        for line in BufReader::new(stdout).lines() {
                            let Ok(line) = line else { break };
                            if let Some(addr) = parse_ready_line(&line) {
                                worker.set_addr(Some(addr));
                                worker.healthy.store(true, Ordering::SeqCst);
                                ready.store(true, Ordering::SeqCst);
                            } else if !line.trim().is_empty() {
                                eprintln!("[worker {index}] {line}");
                            }
                        }
                    });
            }
            slot.child = Some(child);
            slot.spawned_at = Instant::now();
            slot.restart_at = None;
        }
        Err(e) => {
            olog::error(
                "fleet",
                "spawning worker failed",
                &[("worker", index.to_string()), ("err", e.to_string())],
            );
            // treat a failed spawn like a crash so the backoff applies
            let now = Instant::now();
            match slot.policy.on_crash(now, Duration::ZERO) {
                Some(delay) => slot.restart_at = Some(now + delay),
                None => {
                    slot.worker.breaker_open.store(true, Ordering::SeqCst);
                    slot.restart_at = None;
                }
            }
        }
    }
}

fn kill_slot(slot: &mut Slot) {
    if let Some(child) = &mut slot.child {
        let _ = child.kill();
        let _ = child.wait();
    }
    slot.child = None;
    slot.worker.healthy.store(false, Ordering::SeqCst);
    slot.worker.set_addr(None);
    slot.worker.pid.store(0, Ordering::SeqCst);
}

/// The supervision loop: one tick per heartbeat interval.
fn supervise(
    cfg: FleetConfig,
    workers: Vec<Arc<Worker>>,
    metrics: Arc<FleetMetrics>,
    shutdown: Arc<AtomicBool>,
) {
    let mut slots: Vec<Slot> = workers
        .into_iter()
        .map(|worker| Slot {
            worker,
            child: None,
            spawned_at: Instant::now(),
            restart_at: None,
            policy: RestartPolicy::new(&cfg),
            hb_misses: 0,
            ready_seen: Arc::new(AtomicBool::new(false)),
        })
        .collect();
    for slot in &mut slots {
        spawn_worker(&cfg, slot, true);
    }
    while !shutdown.load(Ordering::SeqCst) {
        for slot in &mut slots {
            tick_slot(&cfg, slot, &metrics);
        }
        std::thread::sleep(cfg.heartbeat_interval);
    }
    for slot in &mut slots {
        kill_slot(slot);
    }
}

fn tick_slot(cfg: &FleetConfig, slot: &mut Slot, metrics: &FleetMetrics) {
    let Some(child) = &mut slot.child else {
        // down: restart when the backoff expires (never past the breaker)
        if slot.worker.breaker_open() {
            return;
        }
        if slot.restart_at.map_or(true, |t| Instant::now() >= t) {
            spawn_worker(cfg, slot, false);
        }
        return;
    };
    match child.try_wait() {
        Ok(Some(status)) => {
            // the process is gone — crashed, killed, or exited on its own
            let uptime = slot.spawned_at.elapsed();
            olog::warn(
                "fleet",
                "worker exited",
                &[
                    ("worker", slot.worker.index().to_string()),
                    ("pid", slot.worker.pid().unwrap_or(0).to_string()),
                    ("status", status.to_string()),
                    ("uptime", format!("{uptime:?}")),
                ],
            );
            metrics.worker_crashes.fetch_add(1, Ordering::SeqCst);
            slot.child = None;
            slot.worker.healthy.store(false, Ordering::SeqCst);
            slot.worker.set_addr(None);
            slot.worker.pid.store(0, Ordering::SeqCst);
            let now = Instant::now();
            match slot.policy.on_crash(now, uptime) {
                Some(delay) => {
                    metrics.worker_restarts.fetch_add(1, Ordering::SeqCst);
                    slot.restart_at = Some(now + delay);
                }
                None => {
                    olog::error(
                        "fleet",
                        "worker crash-looping, circuit breaker open",
                        &[("worker", slot.worker.index().to_string())],
                    );
                    metrics.breaker_trips.fetch_add(1, Ordering::SeqCst);
                    slot.worker.breaker_open.store(true, Ordering::SeqCst);
                    slot.restart_at = None;
                }
            }
        }
        Ok(None) => {
            // alive: heartbeat once it is ready, enforce the ready timeout
            if slot.ready_seen.load(Ordering::SeqCst) {
                if let Some(addr) = slot.worker.addr() {
                    if ping(addr, cfg.heartbeat_timeout) {
                        slot.hb_misses = 0;
                        slot.worker.healthy.store(true, Ordering::SeqCst);
                    } else {
                        slot.hb_misses += 1;
                        if slot.hb_misses >= cfg.heartbeat_misses {
                            olog::warn(
                                "fleet",
                                "worker missed heartbeats, killing it",
                                &[
                                    ("worker", slot.worker.index().to_string()),
                                    ("misses", slot.hb_misses.to_string()),
                                ],
                            );
                            metrics.worker_wedged.fetch_add(1, Ordering::SeqCst);
                            kill_slot(slot);
                        } else {
                            // degrade immediately: the router stops
                            // dispatching while the worker is suspect
                            slot.worker.healthy.store(false, Ordering::SeqCst);
                        }
                    }
                }
            } else if slot.spawned_at.elapsed() > cfg.ready_timeout {
                olog::warn(
                    "fleet",
                    "worker never became ready, killing it",
                    &[("worker", slot.worker.index().to_string())],
                );
                metrics.worker_wedged.fetch_add(1, Ordering::SeqCst);
                kill_slot(slot);
            }
        }
        Err(e) => {
            olog::warn(
                "fleet",
                "try_wait on worker failed",
                &[("worker", slot.worker.index().to_string()), ("err", e.to_string())],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(initial_ms: u64, max_ms: u64, window_ms: u64, limit: usize) -> RestartPolicy {
        RestartPolicy::new(&FleetConfig {
            initial_backoff: Duration::from_millis(initial_ms),
            max_backoff: Duration::from_millis(max_ms),
            breaker_window: Duration::from_millis(window_ms),
            breaker_crashes: limit,
            ..FleetConfig::default()
        })
    }

    #[test]
    fn ready_line_parses() {
        assert_eq!(
            parse_ready_line("CROSSQUANT_WORKER_READY addr=127.0.0.1:8421\n"),
            Some("127.0.0.1:8421".parse().unwrap())
        );
        assert_eq!(parse_ready_line("starting up..."), None);
        assert_eq!(parse_ready_line("CROSSQUANT_WORKER_READY addr=not-an-addr"), None);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let mut p = policy(100, 400, 60_000, 100);
        let t0 = Instant::now();
        assert_eq!(p.on_crash(t0, Duration::ZERO), Some(Duration::from_millis(100)));
        assert_eq!(p.on_crash(t0, Duration::ZERO), Some(Duration::from_millis(200)));
        assert_eq!(p.on_crash(t0, Duration::ZERO), Some(Duration::from_millis(400)));
        // capped
        assert_eq!(p.on_crash(t0, Duration::ZERO), Some(Duration::from_millis(400)));
    }

    #[test]
    fn stable_uptime_resets_backoff() {
        let mut p = policy(100, 6_400, 1_000, 100);
        let t0 = Instant::now();
        assert_eq!(p.on_crash(t0, Duration::ZERO), Some(Duration::from_millis(100)));
        assert_eq!(p.on_crash(t0, Duration::ZERO), Some(Duration::from_millis(200)));
        // the worker then ran for longer than the window before dying
        assert_eq!(
            p.on_crash(t0, Duration::from_millis(5_000)),
            Some(Duration::from_millis(100))
        );
    }

    #[test]
    fn breaker_trips_on_crash_loop() {
        let mut p = policy(10, 100, 10_000, 3);
        let t0 = Instant::now();
        assert!(p.on_crash(t0, Duration::ZERO).is_some());
        assert!(p.on_crash(t0 + Duration::from_millis(20), Duration::ZERO).is_some());
        // third crash inside the window: breaker
        assert!(p.on_crash(t0 + Duration::from_millis(40), Duration::ZERO).is_none());
    }

    #[test]
    fn crashes_outside_window_do_not_trip() {
        let mut p = policy(10, 100, 50, 3);
        let t0 = Instant::now();
        // spaced crashes fall out of the 50ms window before the count hits 3
        assert!(p.on_crash(t0, Duration::ZERO).is_some());
        assert!(p.on_crash(t0 + Duration::from_millis(100), Duration::ZERO).is_some());
        assert!(p.on_crash(t0 + Duration::from_millis(200), Duration::ZERO).is_some());
        assert!(p.on_crash(t0 + Duration::from_millis(300), Duration::ZERO).is_some());
    }

    #[test]
    fn worker_status_snapshot() {
        let w = Worker::new(3);
        assert!(!w.is_healthy());
        w.begin_request();
        w.begin_request();
        w.end_request();
        let s = w.status();
        assert_eq!((s.index, s.in_flight, s.healthy, s.pid), (3, 1, false, None));
    }
}
