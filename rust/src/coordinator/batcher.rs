//! Scheme-keyed dynamic batching — the **scoring** path's policy.
//!
//! Requests targeting the same (artifact, scalars, weight-set) key are
//! accumulated until the batch reaches the artifact's fixed batch size or a
//! deadline elapses — the standard dynamic-batching policy of LLM serving
//! routers, scaled to this evaluation workload. Pure logic (time injected),
//! fully unit-testable.
//!
//! Generation requests bypass the accumulator entirely (see the batch
//! loop in `coordinator::scheduler`): the continuous-batching engine
//! re-batches decode work at *step* granularity, so holding a generation
//! request back for the flush deadline would only add admission latency
//! without improving its batching.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::SchemeKey;

/// A batched unit of work, ready for the executor.
pub struct ReadyBatch<R> {
    pub key: SchemeKey,
    pub requests: Vec<R>,
}

pub struct BatchAccumulator<R> {
    batch_size: usize,
    max_delay: Duration,
    pending: HashMap<SchemeKey, (Instant, Vec<R>)>,
}

impl<R> BatchAccumulator<R> {
    pub fn new(batch_size: usize, max_delay: Duration) -> Self {
        assert!(batch_size > 0);
        BatchAccumulator { batch_size, max_delay, pending: HashMap::new() }
    }

    /// Add a request; returns a full batch if the key just filled up.
    pub fn push(&mut self, key: SchemeKey, req: R, now: Instant) -> Option<ReadyBatch<R>> {
        let entry = self.pending.entry(key.clone()).or_insert_with(|| (now, Vec::new()));
        entry.1.push(req);
        if entry.1.len() >= self.batch_size {
            let (_, requests) = self.pending.remove(&key).expect("present");
            Some(ReadyBatch { key, requests })
        } else {
            None
        }
    }

    /// Flush batches whose oldest request has waited past the deadline.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<ReadyBatch<R>> {
        let expired: Vec<SchemeKey> = self
            .pending
            .iter()
            .filter(|(_, (t0, _))| now.duration_since(*t0) >= self.max_delay)
            .map(|(k, _)| k.clone())
            .collect();
        expired
            .into_iter()
            .map(|key| {
                let (_, requests) = self.pending.remove(&key).expect("present");
                ReadyBatch { key, requests }
            })
            .collect()
    }

    /// Flush everything (shutdown path).
    pub fn flush_all(&mut self) -> Vec<ReadyBatch<R>> {
        self.pending
            .drain()
            .map(|(key, (_, requests))| ReadyBatch { key, requests })
            .collect()
    }

    /// Earliest deadline among pending batches (for sleep scheduling).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending.values().map(|(t0, _)| *t0 + self.max_delay).min()
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.values().map(|(_, v)| v.len()).sum()
    }

    /// Number of keys with a partially-filled batch pending.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// The keys currently holding pending requests (observability /
    /// test surface; arbitrary order).
    pub fn pending_keys(&self) -> Vec<SchemeKey> {
        self.pending.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ActScheme;

    fn key(alpha: f32) -> SchemeKey {
        ActScheme::CrossQuant { alpha, qmax: 127.0 }.key("base")
    }

    #[test]
    fn fills_at_batch_size() {
        let mut acc = BatchAccumulator::new(3, Duration::from_millis(10));
        let now = Instant::now();
        assert!(acc.push(key(0.15), 1u32, now).is_none());
        assert!(acc.push(key(0.15), 2, now).is_none());
        let batch = acc.push(key(0.15), 3, now).expect("full");
        assert_eq!(batch.requests, vec![1, 2, 3]);
        assert_eq!(acc.pending_requests(), 0);
    }

    #[test]
    fn keys_batch_independently() {
        let mut acc = BatchAccumulator::new(2, Duration::from_millis(10));
        let now = Instant::now();
        acc.push(key(0.15), 1u32, now);
        acc.push(key(0.45), 2, now);
        assert_eq!(acc.pending_requests(), 2);
        assert!(acc.push(key(0.15), 3, now).is_some());
        assert_eq!(acc.pending_requests(), 1);
    }

    #[test]
    fn expiry_flushes_partial() {
        let mut acc = BatchAccumulator::new(10, Duration::from_millis(5));
        let t0 = Instant::now();
        acc.push(key(0.15), 1u32, t0);
        assert!(acc.flush_expired(t0 + Duration::from_millis(1)).is_empty());
        let flushed = acc.flush_expired(t0 + Duration::from_millis(6));
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].requests, vec![1]);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut acc = BatchAccumulator::new(10, Duration::from_millis(5));
        let t0 = Instant::now();
        assert!(acc.next_deadline().is_none());
        acc.push(key(0.15), 1u32, t0);
        acc.push(key(0.45), 2, t0 + Duration::from_millis(2));
        assert_eq!(acc.next_deadline(), Some(t0 + Duration::from_millis(5)));
    }

    #[test]
    fn fill_exactly_at_deadline_emits_once() {
        // regression: a key whose batch fills at the very instant its
        // deadline elapses must be emitted by `push` alone — the
        // subsequent `flush_expired` sweep at the same instant must not
        // emit it a second time (the batch loop always runs both).
        let mut acc = BatchAccumulator::new(2, Duration::from_millis(5));
        let t0 = Instant::now();
        let deadline = t0 + Duration::from_millis(5);
        assert!(acc.push(key(0.15), 1u32, t0).is_none());
        let batch = acc.push(key(0.15), 2, deadline).expect("fills at the deadline");
        assert_eq!(batch.requests, vec![1, 2]);
        assert!(acc.flush_expired(deadline).is_empty(), "emitted batch must not duplicate");
        assert!(acc.is_empty());
        assert_eq!(acc.pending_requests(), 0);
    }

    #[test]
    fn len_and_pending_keys_track_partial_batches() {
        let mut acc = BatchAccumulator::new(3, Duration::from_millis(5));
        let now = Instant::now();
        assert!(acc.is_empty());
        acc.push(key(0.15), 1u32, now);
        acc.push(key(0.45), 2, now);
        assert_eq!(acc.len(), 2);
        let mut keys = acc.pending_keys();
        keys.sort_by_key(|k| k.s0);
        assert_eq!(keys, vec![key(0.15), key(0.45)]);
        acc.push(key(0.15), 3, now);
        assert_eq!(acc.len(), 2, "same key stays one pending batch");
        assert_eq!(acc.pending_requests(), 3);
    }

    #[test]
    fn order_preserved_within_batch() {
        let mut acc = BatchAccumulator::new(4, Duration::from_millis(5));
        let now = Instant::now();
        for i in 0..3 {
            acc.push(key(0.15), i, now);
        }
        let b = acc.push(key(0.15), 3u32, now).unwrap();
        assert_eq!(b.requests, vec![0, 1, 2, 3]);
    }
}
