//! Coordinator metrics: lock-free counters + a fixed-bucket latency
//! histogram, printable as a one-line summary or a detailed report.

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency buckets in microseconds.
const BUCKETS_US: [u64; 10] =
    [100, 500, 1_000, 5_000, 10_000, 50_000, 100_000, 500_000, 1_000_000, 5_000_000];

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub executions: AtomicU64,
    pub queue_depth: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS_US.len() + 1],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency(&self, micros: u64) {
        let idx = BUCKETS_US.iter().position(|&b| micros <= b).unwrap_or(BUCKETS_US.len());
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(micros, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile from the histogram (upper bucket bound).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return BUCKETS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} failed={} batches={} mean_batch={:.2} mean_lat={:.1}ms p90={:.1}ms",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us() / 1000.0,
            match self.latency_quantile_us(0.9) {
                u64::MAX => f64::INFINITY,
                v => v as f64 / 1000.0,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_quantiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(400);
        }
        for _ in 0..10 {
            m.record_latency(400_000);
        }
        for _ in 0..100 {
            m.completed.fetch_add(1, Ordering::Relaxed);
        }
        assert_eq!(m.latency_quantile_us(0.5), 500);
        assert_eq!(m.latency_quantile_us(0.95), 500_000);
        assert!(m.mean_latency_us() > 0.0);
    }

    #[test]
    fn mean_batch_size() {
        let m = Metrics::new();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(30, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 7.5).abs() < 1e-9);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::new();
        assert!(m.summary().contains("submitted=0"));
    }
}
