//! Coordinator metrics: lock-free counters, log-bucketed latency
//! histograms with rolling windows (`obs::hist`), the per-stage span ring
//! (`obs::trace`), and live quantization-kernel telemetry
//! (`obs::kernel`) — rendered as structured JSON for the
//! `{"cmd": "metrics"}` wire command and as Prometheus text for
//! `{"cmd": "metrics", "format": "prometheus"}`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::obs::prom::PromWriter;
use crate::obs::slo::SloInputs;
use crate::obs::{KernelTelemetry, LatencyTrack, RollingCount, SloPolicy, SloReport, SpanRing};
use crate::util::Json;

/// Priority classes a request can carry on the wire: 0 = best-effort,
/// 1 = low, 2 = normal (the default), 3 = interactive. Shedding always
/// victimizes the lowest class first.
pub const NUM_PRIORITIES: usize = 4;
pub const PRIORITY_DEFAULT: u8 = 2;

/// Flat wire keys for the per-priority shed counters — shared by worker
/// `counters` and router `router_json` so fleet aggregation sums them.
const SHED_KEYS: [&str; NUM_PRIORITIES] = ["shed_p0", "shed_p1", "shed_p2", "shed_p3"];

fn shed_priority_fields(sheds: &[AtomicU64; NUM_PRIORITIES]) -> Vec<(&'static str, Json)> {
    SHED_KEYS
        .iter()
        .zip(sheds)
        .map(|(k, v)| (*k, Json::num(v.load(Ordering::Relaxed) as f64)))
        .collect()
}

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub executions: AtomicU64,
    pub queue_depth: AtomicU64,
    // --- continuous-batching engine ---
    /// Executed engine steps (one batched forward per scheme group).
    pub engine_steps: AtomicU64,
    /// Sequences stepped, summed over steps (occupancy numerator).
    pub engine_stepped_seqs: AtomicU64,
    /// Tokens decoded by the engine (excludes prefill).
    pub engine_decoded_tokens: AtomicU64,
    /// Wall time spent inside batched decode steps, microseconds.
    pub engine_decode_time_us: AtomicU64,
    /// Gauge: sequences currently decoding.
    pub engine_active_seqs: AtomicU64,
    /// Gauge: sequences waiting in the admission queue.
    pub engine_queue_depth: AtomicU64,
    /// Requests rejected because the admission queue was full.
    pub engine_rejected: AtomicU64,
    /// Sequences cancelled before finishing (client disconnected
    /// mid-stream); their KV slots were released early.
    pub engine_cancelled: AtomicU64,
    // --- KV pool ---
    /// Gauge: total preallocated KV slots.
    pub kv_pool_slots: AtomicU64,
    /// Gauge: slots currently leased to sequences.
    pub kv_pool_in_use: AtomicU64,
    /// Gauge: bytes of one slot (= `DecodeState::memory_bytes()`).
    pub kv_pool_slot_bytes: AtomicU64,
    // --- deployment artifacts ---
    /// Artifacts successfully mounted at executor startup.
    pub artifacts_mounted: AtomicU64,
    /// Static models served from a mounted `.cqa` artifact (mmap load —
    /// no FP weights, no calibration).
    pub artifact_loads: AtomicU64,
    /// Wall time spent loading artifacts, microseconds.
    pub artifact_load_us: AtomicU64,
    /// Static models built by the lazy FP-load + calibrate path (the
    /// cold-start cost a mounted artifact avoids).
    pub static_calibrations: AtomicU64,
    // --- latency tracks (lifetime histogram + 1s/10s/60s windows) ---
    /// Whole-request latency (submit → respond), every request kind.
    pub request_latency: LatencyTrack,
    /// Time-to-first-token for engine generate requests.
    pub ttft: LatencyTrack,
    /// Inter-token latency: previous token emit → this token emit.
    pub inter_token: LatencyTrack,
    /// Submit → executor/engine pickup.
    pub queue_wait: LatencyTrack,
    /// One batched forward (scoring batch or engine step group).
    pub batch_forward: LatencyTrack,
    // --- tracing & paper-metric telemetry ---
    /// Per-stage span ring for traced requests (`{"cmd":"trace"}`).
    pub spans: SpanRing,
    /// Live quantization-kernel sampling (shared into activation sites).
    pub kernel: Arc<KernelTelemetry>,
    // --- SLO burn-rate signals ---
    /// Windowed successful-request events (SLO error-rate burn input).
    pub ok_events: RollingCount,
    /// Windowed failed-request events (SLO error-rate burn input).
    pub err_events: RollingCount,
    /// Requests shed at admission, by priority class (flat `shed_pN`
    /// counters on the wire — lowest-priority-first shedding evidence).
    pub shed_by_priority: [AtomicU64; NUM_PRIORITIES],
    /// The live SLO spec (`--slo-*` flags), read on every evaluation.
    pub slo: SloPolicy,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one whole-request latency observation.
    pub fn record_latency(&self, micros: u64) {
        self.request_latency.record_us(micros);
    }

    /// A request finished ok — bumps the lifetime counter *and* the
    /// windowed event stream the SLO error-rate burn reads. Every
    /// `completed` increment must come through here so the windowed and
    /// lifetime views can't drift.
    pub fn mark_completed(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.ok_events.record();
    }

    /// A request failed — lifetime counter plus the windowed error
    /// stream (the other half of the SLO error-rate burn).
    pub fn mark_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.err_events.record();
    }

    /// Count a shed against its priority class.
    pub fn mark_shed(&self, priority: u8) {
        let p = (priority as usize).min(NUM_PRIORITIES - 1);
        self.shed_by_priority[p].fetch_add(1, Ordering::Relaxed);
    }

    /// Evaluate the configured SLO spec over the live rolling signals.
    pub fn slo_report(&self) -> SloReport {
        self.slo.spec().evaluate(&SloInputs {
            ttft: &self.ttft.rolling,
            inter_token: &self.inter_token.rolling,
            ok: &self.ok_events,
            err: &self.err_events,
        })
    }

    /// The `{"cmd":"slo"}` payload.
    pub fn slo_json(&self) -> Json {
        self.slo_report().json()
    }

    /// `{"cmd":"metrics_reset"}`: zero every *accumulating* counter and
    /// latency track so a load-test run starts from clean telemetry.
    /// Deliberately untouched: live gauges (queue depths, active
    /// sequences, KV-pool occupancy/config), `artifacts_mounted` (a
    /// startup fact), the span ring (trace history has its own
    /// capacity-bounded lifecycle), kernel telemetry (paper-metric
    /// accounting, not load telemetry), and the SLO spec itself.
    pub fn reset(&self) {
        for c in [
            &self.submitted,
            &self.completed,
            &self.failed,
            &self.batches,
            &self.batched_requests,
            &self.executions,
            &self.engine_steps,
            &self.engine_stepped_seqs,
            &self.engine_decoded_tokens,
            &self.engine_decode_time_us,
            &self.engine_rejected,
            &self.engine_cancelled,
            &self.artifact_loads,
            &self.artifact_load_us,
            &self.static_calibrations,
        ] {
            c.store(0, Ordering::Relaxed);
        }
        for c in &self.shed_by_priority {
            c.store(0, Ordering::Relaxed);
        }
        for t in [
            &self.request_latency,
            &self.ttft,
            &self.inter_token,
            &self.queue_wait,
            &self.batch_forward,
        ] {
            t.reset();
        }
        self.ok_events.reset();
        self.err_events.reset();
    }

    /// Mean request latency over the histogram's **own** observation
    /// count — the seed divided by `completed`, which skewed the mean
    /// whenever a failed request had also recorded a latency.
    pub fn mean_latency_us(&self) -> f64 {
        self.request_latency.total.mean_us()
    }

    /// Approximate request-latency quantile (upper bucket bound, ≤6.25%
    /// relative error). Clamps to the last finite bucket bound instead of
    /// the seed's `u64::MAX` sentinel (1.8e19 µs once serialized);
    /// [`Self::latency_overflow_count`] says whether clamping happened.
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        self.request_latency.total.quantile_us(q)
    }

    /// Observations past the histogram's finite range — the explicit
    /// signal the old overflow sentinel stood in for.
    pub fn latency_overflow_count(&self) -> u64 {
        self.request_latency.total.overflow_count()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Mean sequences per executed engine step — the continuous-batching
    /// win in one number (1.0 = the serial pre-engine behaviour).
    pub fn batch_occupancy(&self) -> f64 {
        let steps = self.engine_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.engine_stepped_seqs.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// Aggregate decode throughput across all engine sequences, tokens/s.
    pub fn engine_decode_tok_s(&self) -> f64 {
        let us = self.engine_decode_time_us.load(Ordering::Relaxed);
        if us == 0 {
            return 0.0;
        }
        self.engine_decoded_tokens.load(Ordering::Relaxed) as f64 / (us as f64 / 1e6)
    }

    /// Engine + KV-pool state as structured JSON — the `{"cmd":
    /// "metrics"}` payload's `"engine"` object (the PR 3 gap: KV
    /// `memory_bytes()` accounting existed but never crossed the wire).
    pub fn engine_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let slot_bytes = load(&self.kv_pool_slot_bytes);
        Json::obj(vec![
            ("active_seqs", Json::num(load(&self.engine_active_seqs))),
            ("queue_depth", Json::num(load(&self.engine_queue_depth))),
            ("rejected", Json::num(load(&self.engine_rejected))),
            ("cancelled", Json::num(load(&self.engine_cancelled))),
            ("steps", Json::num(load(&self.engine_steps))),
            ("decoded_tokens", Json::num(load(&self.engine_decoded_tokens))),
            ("batch_occupancy", Json::num(self.batch_occupancy())),
            ("decode_tok_s", Json::num(self.engine_decode_tok_s())),
            (
                "kv_pool",
                Json::obj(vec![
                    ("slots", Json::num(load(&self.kv_pool_slots))),
                    ("slots_in_use", Json::num(load(&self.kv_pool_in_use))),
                    ("bytes_per_seq", Json::num(slot_bytes)),
                    ("bytes", Json::num(load(&self.kv_pool_slots) * slot_bytes)),
                    (
                        "bytes_in_use",
                        Json::num(load(&self.kv_pool_in_use) * slot_bytes),
                    ),
                ]),
            ),
        ])
    }

    /// Deployment-artifact accounting as structured JSON — the `{"cmd":
    /// "metrics"}` payload's `"artifacts"` object.
    pub fn artifact_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        Json::obj(vec![
            ("mounted", Json::num(load(&self.artifacts_mounted))),
            ("loads", Json::num(load(&self.artifact_loads))),
            ("load_ms_total", Json::num(load(&self.artifact_load_us) / 1000.0)),
            ("calibrations", Json::num(load(&self.static_calibrations))),
            // process-wide, not per-coordinator: mapped panel sections that
            // failed the PANEL_ALIGN check and were copied instead of
            // borrowed (zero-copy lost, results unchanged)
            (
                "unaligned_panel_copies",
                Json::num(crate::quant::gemm::unaligned_panel_copies() as f64),
            ),
        ])
    }

    /// All five latency tracks — the `{"cmd": "metrics"}` payload's
    /// `"latency"` object. Each track carries the lifetime summary
    /// (count/mean/p50/p95/p99/p999/max/overflow) plus `w1s`/`w10s`/
    /// `w60s` windowed quantiles, so dashboards read *now* and autopsies
    /// read the whole run.
    pub fn latency_json(&self) -> Json {
        Json::obj(vec![
            ("request", self.request_latency.json()),
            ("ttft", self.ttft.json()),
            ("inter_token", self.inter_token.json()),
            ("queue_wait", self.queue_wait.json()),
            ("batch_forward", self.batch_forward.json()),
        ])
    }

    /// Flat numeric counters — the shape the fleet router sums across
    /// workers when aggregating `{"cmd": "metrics"}` responses. Every
    /// field must stay a plain number for that summation to hold.
    ///
    /// `deadline_exceeded` and `shed` are router-level failures, so a
    /// worker always reports 0; `shed_p0`..`shed_p3` count priority
    /// sheds that happen on *both* levels (engine admission and router
    /// dispatch), so the router folds its own counts into the worker
    /// sum. These are the only keys intentionally shared with
    /// [`FleetMetrics`]; pinned by
    /// `fleet_and_counter_keys_only_collide_deliberately`.
    pub fn counters_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let mut fields = vec![
            ("submitted", Json::num(load(&self.submitted))),
            ("completed", Json::num(load(&self.completed))),
            ("failed", Json::num(load(&self.failed))),
            ("batches", Json::num(load(&self.batches))),
            ("executions", Json::num(load(&self.executions))),
            ("engine_rejected", Json::num(load(&self.engine_rejected))),
            ("engine_cancelled", Json::num(load(&self.engine_cancelled))),
            ("decoded_tokens", Json::num(load(&self.engine_decoded_tokens))),
            ("deadline_exceeded", Json::num(0.0)),
            ("shed", Json::num(0.0)),
        ];
        fields.extend(shed_priority_fields(&self.shed_by_priority));
        Json::obj(fields)
    }

    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} failed={} batches={} mean_batch={:.2} mean_lat={:.1}ms p90={:.1}ms",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us() / 1000.0,
            self.latency_quantile_us(0.9) as f64 / 1000.0,
        )
    }

    /// Worker-side Prometheus exposition body (text format 0.0.4) — the
    /// `{"cmd": "metrics", "format": "prometheus"}` payload.
    pub fn prometheus(&self) -> String {
        let mut w = PromWriter::new();
        self.prom_into(&mut w);
        w.finish()
    }

    pub fn prom_into(&self, w: &mut PromWriter) {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let decoded = load(&self.engine_decoded_tokens);
        let counters: [(&str, &str, f64); 8] = [
            ("cq_requests_submitted_total", "Requests accepted.", load(&self.submitted)),
            ("cq_requests_completed_total", "Requests answered ok.", load(&self.completed)),
            ("cq_requests_failed_total", "Requests answered with an error.", load(&self.failed)),
            ("cq_batches_total", "Scoring batches flushed.", load(&self.batches)),
            ("cq_executions_total", "Executor invocations.", load(&self.executions)),
            ("cq_engine_rejected_total", "Rejected at admission.", load(&self.engine_rejected)),
            ("cq_engine_cancelled_total", "Cancelled mid-stream.", load(&self.engine_cancelled)),
            ("cq_decoded_tokens_total", "Engine-decoded tokens.", decoded),
        ];
        for (name, help, v) in counters {
            w.write(name, "counter", help, &[], v);
        }
        let slot_bytes = load(&self.kv_pool_slot_bytes);
        let kv_bytes_in_use = load(&self.kv_pool_in_use) * slot_bytes;
        let gauges: [(&str, &str, f64); 6] = [
            ("cq_engine_active_seqs", "Sequences decoding now.", load(&self.engine_active_seqs)),
            ("cq_engine_queue_depth", "Admission queue depth.", load(&self.engine_queue_depth)),
            ("cq_batch_occupancy", "Mean sequences per engine step.", self.batch_occupancy()),
            ("cq_decode_tok_s", "Decode throughput, tok/s.", self.engine_decode_tok_s()),
            ("cq_kv_pool_slots_in_use", "KV slots leased.", load(&self.kv_pool_in_use)),
            ("cq_kv_pool_bytes_in_use", "KV bytes leased.", kv_bytes_in_use),
        ];
        for (name, help, v) in gauges {
            w.write(name, "gauge", help, &[], v);
        }
        let tracks: [(&str, &LatencyTrack); 5] = [
            ("request", &self.request_latency),
            ("ttft", &self.ttft),
            ("inter_token", &self.inter_token),
            ("queue_wait", &self.queue_wait),
            ("batch_forward", &self.batch_forward),
        ];
        for (track, t) in tracks {
            let labels: &[(&str, &str)] = &[("track", track)];
            w.write(
                "cq_latency_count_total",
                "counter",
                "Latency observations per track.",
                labels,
                t.total.count() as f64,
            );
            w.write(
                "cq_latency_overflow_total",
                "counter",
                "Observations past the histogram's finite range.",
                labels,
                t.total.overflow_count() as f64,
            );
            w.write(
                "cq_latency_mean_us",
                "gauge",
                "Lifetime mean latency, microseconds.",
                labels,
                t.total.mean_us(),
            );
            for (q, qs) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99"), (0.999, "0.999")] {
                w.write(
                    "cq_latency_us",
                    "gauge",
                    "Lifetime latency quantile, microseconds.",
                    &[("track", track), ("quantile", qs)],
                    t.total.quantile_us(q) as f64,
                );
            }
            let w60 = t.rolling.window(60);
            for (q, qs) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                w.write(
                    "cq_latency_w60s_us",
                    "gauge",
                    "Last-60s latency quantile, microseconds.",
                    &[("track", track), ("quantile", qs)],
                    w60.quantile_us(q) as f64,
                );
            }
        }
        w.write(
            "cq_spans_recorded_total",
            "counter",
            "Spans recorded into the trace ring.",
            &[],
            self.spans.recorded() as f64,
        );
        for (i, c) in self.shed_by_priority.iter().enumerate() {
            let p = i.to_string();
            w.write(
                "cq_shed_by_priority_total",
                "counter",
                "Requests shed at admission, by priority class.",
                &[("priority", &p)],
                c.load(Ordering::Relaxed) as f64,
            );
        }
        let slo = self.slo_report();
        for win in &slo.windows {
            let ws = win.window_s.to_string();
            for (objective, burn) in [
                ("ttft_p99", win.ttft_burn),
                ("inter_token_p99", win.inter_token_burn),
                ("error_rate", win.error_burn),
            ] {
                w.write(
                    "cq_slo_burn_rate",
                    "gauge",
                    "Error-budget burn rate per objective and window.",
                    &[("objective", objective), ("window_s", &ws)],
                    burn,
                );
            }
        }
        for (which, on) in [("fast", slo.fast_alert), ("slow", slo.slow_alert)] {
            w.write(
                "cq_slo_alert",
                "gauge",
                "1 when the window class is burning past threshold.",
                &[("window", which)],
                if on { 1.0 } else { 0.0 },
            );
        }
        w.write(
            "cq_slo_shedding",
            "gauge",
            "1 when burn-rate shedding is active (fast AND slow alert).",
            &[],
            if slo.shedding { 1.0 } else { 0.0 },
        );
        self.kernel.prom(w);
    }
}

/// Counters for the sharded serving tier (supervisor + router). Shared
/// between the fleet supervision thread and the router's connection
/// threads; surfaced under `"router"` / `"fleet"` in the aggregated
/// `{"cmd": "metrics"}` response.
#[derive(Default)]
pub struct FleetMetrics {
    /// Data requests the router accepted for dispatch.
    pub requests: AtomicU64,
    /// Requests that ultimately returned `ok: true` to the client.
    pub succeeded: AtomicU64,
    /// Requests retried on another worker after a mid-request failure.
    pub retried: AtomicU64,
    /// Requests that exhausted their deadline.
    pub deadline_exceeded: AtomicU64,
    /// Requests shed because no healthy worker was available.
    pub shed: AtomicU64,
    /// Router-level sheds by priority class (same `shed_pN` wire keys as
    /// the worker counters, so aggregation folds both levels together).
    pub shed_by_priority: [AtomicU64; NUM_PRIORITIES],
    /// Malformed client frames refused with a structured error.
    pub malformed: AtomicU64,
    /// Worker processes observed dead (crash or kill).
    pub worker_crashes: AtomicU64,
    /// Worker restarts performed by the supervisor.
    pub worker_restarts: AtomicU64,
    /// Workers killed for missing heartbeats (wedged, not crashed).
    pub worker_wedged: AtomicU64,
    /// Crash-loop circuit breakers tripped.
    pub breaker_trips: AtomicU64,
    /// Router-side spans: one [`crate::obs::SpanKind::Dispatch`] span per
    /// completed data request (aux = worker index that served it).
    pub spans: SpanRing,
}

impl FleetMetrics {
    pub fn new() -> FleetMetrics {
        FleetMetrics::default()
    }

    pub fn router_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let mut fields = vec![
            ("requests", Json::num(load(&self.requests))),
            ("succeeded", Json::num(load(&self.succeeded))),
            ("retried", Json::num(load(&self.retried))),
            ("deadline_exceeded", Json::num(load(&self.deadline_exceeded))),
            ("shed", Json::num(load(&self.shed))),
            ("malformed", Json::num(load(&self.malformed))),
        ];
        fields.extend(shed_priority_fields(&self.shed_by_priority));
        Json::obj(fields)
    }

    /// Count a router-level shed against its priority class (alongside
    /// the total `shed` counter, which the caller still bumps).
    pub fn mark_shed(&self, priority: u8) {
        let p = (priority as usize).min(NUM_PRIORITIES - 1);
        self.shed_by_priority[p].fetch_add(1, Ordering::Relaxed);
    }

    /// Zero the router/fleet counters (`{"cmd":"metrics_reset"}` fanned
    /// out across the fleet). The span ring keeps its own lifecycle.
    pub fn reset(&self) {
        for c in [
            &self.requests,
            &self.succeeded,
            &self.retried,
            &self.deadline_exceeded,
            &self.shed,
            &self.malformed,
            &self.worker_crashes,
            &self.worker_restarts,
            &self.worker_wedged,
            &self.breaker_trips,
        ] {
            c.store(0, Ordering::Relaxed);
        }
        for c in &self.shed_by_priority {
            c.store(0, Ordering::Relaxed);
        }
    }

    pub fn fleet_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        Json::obj(vec![
            ("worker_crashes", Json::num(load(&self.worker_crashes))),
            ("worker_restarts", Json::num(load(&self.worker_restarts))),
            ("worker_wedged", Json::num(load(&self.worker_wedged))),
            ("breaker_trips", Json::num(load(&self.breaker_trips))),
        ])
    }

    /// Router-side Prometheus samples (the worker bodies are appended by
    /// the router after re-labeling, so names here must not collide with
    /// worker metric names).
    pub fn prom_into(&self, w: &mut PromWriter) {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let deadline = load(&self.deadline_exceeded);
        let wedged = load(&self.worker_wedged);
        let counters: [(&str, &str, f64); 10] = [
            ("cq_router_requests_total", "Requests dispatched.", load(&self.requests)),
            ("cq_router_succeeded_total", "Requests answered ok.", load(&self.succeeded)),
            ("cq_router_retried_total", "Requests retried.", load(&self.retried)),
            ("cq_router_deadline_exceeded_total", "Deadlines exhausted.", deadline),
            ("cq_router_shed_total", "Requests shed.", load(&self.shed)),
            ("cq_router_malformed_total", "Malformed frames refused.", load(&self.malformed)),
            ("cq_fleet_worker_crashes_total", "Workers observed dead.", load(&self.worker_crashes)),
            ("cq_fleet_worker_restarts_total", "Worker restarts.", load(&self.worker_restarts)),
            ("cq_fleet_worker_wedged_total", "Workers killed as wedged.", wedged),
            ("cq_fleet_breaker_trips_total", "Breakers tripped.", load(&self.breaker_trips)),
        ];
        for (name, help, v) in counters {
            w.write(name, "counter", help, &[], v);
        }
        for (i, c) in self.shed_by_priority.iter().enumerate() {
            let p = i.to_string();
            w.write(
                "cq_router_shed_by_priority_total",
                "counter",
                "Router-level sheds by priority class.",
                &[("priority", &p)],
                c.load(Ordering::Relaxed) as f64,
            );
        }
        w.write(
            "cq_router_spans_recorded_total",
            "counter",
            "Dispatch spans recorded by the router.",
            &[],
            self.spans.recorded() as f64,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_quantiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(400);
        }
        for _ in 0..10 {
            m.record_latency(400_000);
        }
        // p50 lands in 400's bucket: within 6.25% above the value
        let p50 = m.latency_quantile_us(0.5);
        assert!((400..=426).contains(&p50), "p50={p50}");
        // p95 lands in 400_000's bucket, tightened to the observed max
        assert_eq!(m.latency_quantile_us(0.95), 400_000);
        // mean divides by the histogram's own count, not `completed`
        // (which is still 0 here — the seed bug made this 0.0 or worse)
        let expect = (90.0 * 400.0 + 10.0 * 400_000.0) / 100.0;
        assert!((m.mean_latency_us() - expect).abs() < 1e-9);
        assert_eq!(m.latency_overflow_count(), 0);
    }

    #[test]
    fn overflow_is_reported_not_sentineled() {
        let m = Metrics::new();
        m.record_latency(u64::MAX);
        assert_eq!(m.latency_overflow_count(), 1);
        let p99 = m.latency_quantile_us(0.99);
        assert!(p99 < u64::MAX, "quantile clamps instead of returning the sentinel");
        // and the summary line stays finite
        assert!(!m.summary().contains("inf"));
    }

    #[test]
    fn mean_batch_size() {
        let m = Metrics::new();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(30, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 7.5).abs() < 1e-9);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::new();
        assert!(m.summary().contains("submitted=0"));
    }

    #[test]
    fn artifact_accounting_json() {
        let m = Metrics::new();
        m.artifacts_mounted.store(1, Ordering::Relaxed);
        m.artifact_loads.store(2, Ordering::Relaxed);
        m.artifact_load_us.store(1500, Ordering::Relaxed);
        m.static_calibrations.store(3, Ordering::Relaxed);
        let j = m.artifact_json();
        assert_eq!(j.get("mounted").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("loads").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(j.get("load_ms_total").and_then(|v| v.as_f64()), Some(1.5));
        assert_eq!(j.get("calibrations").and_then(|v| v.as_f64()), Some(3.0));
    }

    #[test]
    fn engine_gauges_and_occupancy() {
        let m = Metrics::new();
        assert_eq!(m.batch_occupancy(), 0.0);
        assert_eq!(m.engine_decode_tok_s(), 0.0);
        m.engine_steps.store(4, Ordering::Relaxed);
        m.engine_stepped_seqs.store(10, Ordering::Relaxed);
        m.engine_decoded_tokens.store(10, Ordering::Relaxed);
        m.engine_decode_time_us.store(2_000_000, Ordering::Relaxed);
        assert!((m.batch_occupancy() - 2.5).abs() < 1e-9);
        assert!((m.engine_decode_tok_s() - 5.0).abs() < 1e-9);
        m.kv_pool_slots.store(4, Ordering::Relaxed);
        m.kv_pool_in_use.store(3, Ordering::Relaxed);
        m.kv_pool_slot_bytes.store(1024, Ordering::Relaxed);
        let j = m.engine_json();
        let kv = j.get("kv_pool").expect("kv_pool object");
        assert_eq!(kv.get("bytes").and_then(|v| v.as_f64()), Some(4096.0));
        assert_eq!(kv.get("bytes_in_use").and_then(|v| v.as_f64()), Some(3072.0));
        assert_eq!(j.get("batch_occupancy").and_then(|v| v.as_f64()), Some(2.5));
    }

    #[test]
    fn counters_json_is_flat_numeric() {
        let m = Metrics::new();
        m.submitted.store(7, Ordering::Relaxed);
        m.engine_cancelled.store(2, Ordering::Relaxed);
        let j = m.counters_json();
        match &j {
            Json::Obj(fields) => {
                for (k, v) in fields {
                    assert!(v.as_f64().is_some(), "counter `{k}` is not numeric");
                }
            }
            other => panic!("expected object, got {other:?}"),
        }
        assert_eq!(j.get("submitted").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(j.get("engine_cancelled").and_then(|v| v.as_f64()), Some(2.0));
        // router-level failures exist in the flat shape so aggregation can
        // sum them — a worker must always report zero
        assert_eq!(j.get("deadline_exceeded").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(j.get("shed").and_then(|v| v.as_f64()), Some(0.0));
    }

    /// The fleet aggregation contract: `FleetMetrics` keys and the flat
    /// worker `counters` keys may only collide on the counters the
    /// router deliberately folds into the worker sum —
    /// `deadline_exceeded` and `shed` (router-level, always 0 on
    /// workers) plus the per-priority `shed_pN` counters (real on both
    /// levels, summed into one honest total). Any other collision would
    /// double-count in the aggregated `{"cmd":"metrics"}` view.
    #[test]
    fn fleet_and_counter_keys_only_collide_deliberately() {
        let keys = |j: &Json| -> Vec<String> {
            match j {
                Json::Obj(fields) => fields.keys().cloned().collect(),
                other => panic!("expected object, got {other:?}"),
            }
        };
        let m = Metrics::new();
        let f = FleetMetrics::new();
        let counters = keys(&m.counters_json());
        let mut fleet_keys = keys(&f.router_json());
        fleet_keys.extend(keys(&f.fleet_json()));
        let collisions: Vec<&String> =
            fleet_keys.iter().filter(|k| counters.contains(k)).collect();
        assert_eq!(
            collisions,
            vec!["deadline_exceeded", "shed", "shed_p0", "shed_p1", "shed_p2", "shed_p3"],
            "unexpected key collision between FleetMetrics and worker counters"
        );
    }

    #[test]
    fn outcome_marks_feed_both_lifetime_and_windowed_views() {
        let m = Metrics::new();
        m.mark_completed();
        m.mark_completed();
        m.mark_failed();
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.ok_events.window(60), 2);
        assert_eq!(m.err_events.window(60), 1);
    }

    #[test]
    fn shed_counters_are_flat_and_clamped() {
        let m = Metrics::new();
        m.mark_shed(0);
        m.mark_shed(3);
        m.mark_shed(200); // out-of-range clamps into the top class
        let j = m.counters_json();
        assert_eq!(j.get("shed_p0").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("shed_p1").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(j.get("shed_p3").and_then(|v| v.as_f64()), Some(2.0));
    }

    #[test]
    fn slo_json_reports_windows_and_alerts() {
        let m = Metrics::new();
        m.ttft.record_us(1_000);
        let j = m.slo_json();
        assert!(j.get("spec").is_some());
        assert_eq!(j.get("windows").and_then(|w| w.as_arr()).map(|w| w.len()), Some(3));
        assert_eq!(j.get("shedding"), Some(&Json::Bool(false)));
    }

    #[test]
    fn reset_clears_accumulators_but_keeps_gauges_and_spec() {
        let m = Metrics::new();
        m.submitted.store(5, Ordering::Relaxed);
        m.mark_completed();
        m.mark_shed(1);
        m.record_latency(1_000);
        m.kv_pool_slots.store(8, Ordering::Relaxed);
        m.engine_active_seqs.store(2, Ordering::Relaxed);
        let spec = m.slo.spec();
        m.reset();
        assert_eq!(m.submitted.load(Ordering::Relaxed), 0);
        assert_eq!(m.completed.load(Ordering::Relaxed), 0);
        assert_eq!(m.shed_by_priority[1].load(Ordering::Relaxed), 0);
        assert_eq!(m.request_latency.total.count(), 0);
        assert_eq!(m.ok_events.window(60), 0);
        // gauges and configuration survive
        assert_eq!(m.kv_pool_slots.load(Ordering::Relaxed), 8);
        assert_eq!(m.engine_active_seqs.load(Ordering::Relaxed), 2);
        assert_eq!(m.slo.spec(), spec);
    }

    #[test]
    fn fleet_reset_zeroes_router_counters() {
        let f = FleetMetrics::new();
        f.requests.store(9, Ordering::Relaxed);
        f.shed.store(2, Ordering::Relaxed);
        f.mark_shed(0);
        f.reset();
        assert_eq!(f.requests.load(Ordering::Relaxed), 0);
        assert_eq!(f.shed.load(Ordering::Relaxed), 0);
        assert_eq!(f.shed_by_priority[0].load(Ordering::Relaxed), 0);
    }

    #[test]
    fn latency_json_has_all_tracks_and_windows() {
        let m = Metrics::new();
        m.record_latency(2_000);
        m.ttft.record_us(1_000);
        m.inter_token.record_us(50);
        let j = m.latency_json();
        for track in ["request", "ttft", "inter_token", "queue_wait", "batch_forward"] {
            let t = j.get(track).unwrap_or_else(|| panic!("missing track {track}"));
            assert!(t.get("p99_us").is_some());
            assert!(t.get("overflow").is_some());
            assert!(t.get("w60s").is_some());
        }
        assert_eq!(j.get("ttft").unwrap().get("count").and_then(|v| v.as_f64()), Some(1.0));
    }

    #[test]
    fn prometheus_body_renders_counters_and_quantiles() {
        let m = Metrics::new();
        m.submitted.store(3, Ordering::Relaxed);
        m.record_latency(1_000);
        let body = m.prometheus();
        assert!(body.contains("# TYPE cq_requests_submitted_total counter"));
        assert!(body.contains("cq_requests_submitted_total 3\n"));
        assert!(body.contains("cq_latency_us{track=\"request\",quantile=\"0.99\"}"));
        assert!(body.contains("cq_latency_count_total{track=\"request\"} 1\n"));
    }

    #[test]
    fn fleet_metrics_json() {
        let f = FleetMetrics::new();
        f.requests.store(10, Ordering::Relaxed);
        f.retried.store(3, Ordering::Relaxed);
        f.worker_restarts.store(1, Ordering::Relaxed);
        let r = f.router_json();
        assert_eq!(r.get("requests").and_then(|v| v.as_f64()), Some(10.0));
        assert_eq!(r.get("retried").and_then(|v| v.as_f64()), Some(3.0));
        let fl = f.fleet_json();
        assert_eq!(fl.get("worker_restarts").and_then(|v| v.as_f64()), Some(1.0));
        let mut w = crate::obs::prom::PromWriter::new();
        f.prom_into(&mut w);
        assert!(w.finish().contains("cq_router_requests_total 10\n"));
    }
}
