//! Coordinator metrics: lock-free counters + a fixed-bucket latency
//! histogram, printable as a one-line summary or a detailed report, plus
//! the continuous-batching engine's gauges (batch occupancy, admission
//! queue depth, KV-pool utilisation, aggregate decode throughput) —
//! rendered as structured JSON for the `{"cmd": "metrics"}` wire command.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::Json;

/// Latency buckets in microseconds.
const BUCKETS_US: [u64; 10] =
    [100, 500, 1_000, 5_000, 10_000, 50_000, 100_000, 500_000, 1_000_000, 5_000_000];

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub executions: AtomicU64,
    pub queue_depth: AtomicU64,
    // --- continuous-batching engine ---
    /// Executed engine steps (one batched forward per scheme group).
    pub engine_steps: AtomicU64,
    /// Sequences stepped, summed over steps (occupancy numerator).
    pub engine_stepped_seqs: AtomicU64,
    /// Tokens decoded by the engine (excludes prefill).
    pub engine_decoded_tokens: AtomicU64,
    /// Wall time spent inside batched decode steps, microseconds.
    pub engine_decode_time_us: AtomicU64,
    /// Gauge: sequences currently decoding.
    pub engine_active_seqs: AtomicU64,
    /// Gauge: sequences waiting in the admission queue.
    pub engine_queue_depth: AtomicU64,
    /// Requests rejected because the admission queue was full.
    pub engine_rejected: AtomicU64,
    /// Sequences cancelled before finishing (client disconnected
    /// mid-stream); their KV slots were released early.
    pub engine_cancelled: AtomicU64,
    // --- KV pool ---
    /// Gauge: total preallocated KV slots.
    pub kv_pool_slots: AtomicU64,
    /// Gauge: slots currently leased to sequences.
    pub kv_pool_in_use: AtomicU64,
    /// Gauge: bytes of one slot (= `DecodeState::memory_bytes()`).
    pub kv_pool_slot_bytes: AtomicU64,
    // --- deployment artifacts ---
    /// Artifacts successfully mounted at executor startup.
    pub artifacts_mounted: AtomicU64,
    /// Static models served from a mounted `.cqa` artifact (mmap load —
    /// no FP weights, no calibration).
    pub artifact_loads: AtomicU64,
    /// Wall time spent loading artifacts, microseconds.
    pub artifact_load_us: AtomicU64,
    /// Static models built by the lazy FP-load + calibrate path (the
    /// cold-start cost a mounted artifact avoids).
    pub static_calibrations: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS_US.len() + 1],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency(&self, micros: u64) {
        let idx = BUCKETS_US.iter().position(|&b| micros <= b).unwrap_or(BUCKETS_US.len());
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(micros, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile from the histogram (upper bucket bound).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return BUCKETS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Mean sequences per executed engine step — the continuous-batching
    /// win in one number (1.0 = the serial pre-engine behaviour).
    pub fn batch_occupancy(&self) -> f64 {
        let steps = self.engine_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.engine_stepped_seqs.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// Aggregate decode throughput across all engine sequences, tokens/s.
    pub fn engine_decode_tok_s(&self) -> f64 {
        let us = self.engine_decode_time_us.load(Ordering::Relaxed);
        if us == 0 {
            return 0.0;
        }
        self.engine_decoded_tokens.load(Ordering::Relaxed) as f64 / (us as f64 / 1e6)
    }

    /// Engine + KV-pool state as structured JSON — the `{"cmd":
    /// "metrics"}` payload's `"engine"` object (the PR 3 gap: KV
    /// `memory_bytes()` accounting existed but never crossed the wire).
    pub fn engine_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let slot_bytes = load(&self.kv_pool_slot_bytes);
        Json::obj(vec![
            ("active_seqs", Json::num(load(&self.engine_active_seqs))),
            ("queue_depth", Json::num(load(&self.engine_queue_depth))),
            ("rejected", Json::num(load(&self.engine_rejected))),
            ("cancelled", Json::num(load(&self.engine_cancelled))),
            ("steps", Json::num(load(&self.engine_steps))),
            ("decoded_tokens", Json::num(load(&self.engine_decoded_tokens))),
            ("batch_occupancy", Json::num(self.batch_occupancy())),
            ("decode_tok_s", Json::num(self.engine_decode_tok_s())),
            (
                "kv_pool",
                Json::obj(vec![
                    ("slots", Json::num(load(&self.kv_pool_slots))),
                    ("slots_in_use", Json::num(load(&self.kv_pool_in_use))),
                    ("bytes_per_seq", Json::num(slot_bytes)),
                    ("bytes", Json::num(load(&self.kv_pool_slots) * slot_bytes)),
                    (
                        "bytes_in_use",
                        Json::num(load(&self.kv_pool_in_use) * slot_bytes),
                    ),
                ]),
            ),
        ])
    }

    /// Deployment-artifact accounting as structured JSON — the `{"cmd":
    /// "metrics"}` payload's `"artifacts"` object.
    pub fn artifact_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        Json::obj(vec![
            ("mounted", Json::num(load(&self.artifacts_mounted))),
            ("loads", Json::num(load(&self.artifact_loads))),
            ("load_ms_total", Json::num(load(&self.artifact_load_us) / 1000.0)),
            ("calibrations", Json::num(load(&self.static_calibrations))),
            // process-wide, not per-coordinator: mapped panel sections that
            // failed the PANEL_ALIGN check and were copied instead of
            // borrowed (zero-copy lost, results unchanged)
            (
                "unaligned_panel_copies",
                Json::num(crate::quant::gemm::unaligned_panel_copies() as f64),
            ),
        ])
    }

    /// Flat numeric counters — the shape the fleet router sums across
    /// workers when aggregating `{"cmd": "metrics"}` responses. Every
    /// field must stay a plain number for that summation to hold.
    pub fn counters_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        Json::obj(vec![
            ("submitted", Json::num(load(&self.submitted))),
            ("completed", Json::num(load(&self.completed))),
            ("failed", Json::num(load(&self.failed))),
            ("batches", Json::num(load(&self.batches))),
            ("executions", Json::num(load(&self.executions))),
            ("engine_rejected", Json::num(load(&self.engine_rejected))),
            ("engine_cancelled", Json::num(load(&self.engine_cancelled))),
            ("decoded_tokens", Json::num(load(&self.engine_decoded_tokens))),
        ])
    }

    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} failed={} batches={} mean_batch={:.2} mean_lat={:.1}ms p90={:.1}ms",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us() / 1000.0,
            match self.latency_quantile_us(0.9) {
                u64::MAX => f64::INFINITY,
                v => v as f64 / 1000.0,
            },
        )
    }
}

/// Counters for the sharded serving tier (supervisor + router). Shared
/// between the fleet supervision thread and the router's connection
/// threads; surfaced under `"router"` / `"fleet"` in the aggregated
/// `{"cmd": "metrics"}` response.
#[derive(Default)]
pub struct FleetMetrics {
    /// Data requests the router accepted for dispatch.
    pub requests: AtomicU64,
    /// Requests that ultimately returned `ok: true` to the client.
    pub succeeded: AtomicU64,
    /// Requests retried on another worker after a mid-request failure.
    pub retried: AtomicU64,
    /// Requests that exhausted their deadline.
    pub deadline_exceeded: AtomicU64,
    /// Requests shed because no healthy worker was available.
    pub shed: AtomicU64,
    /// Malformed client frames refused with a structured error.
    pub malformed: AtomicU64,
    /// Worker processes observed dead (crash or kill).
    pub worker_crashes: AtomicU64,
    /// Worker restarts performed by the supervisor.
    pub worker_restarts: AtomicU64,
    /// Workers killed for missing heartbeats (wedged, not crashed).
    pub worker_wedged: AtomicU64,
    /// Crash-loop circuit breakers tripped.
    pub breaker_trips: AtomicU64,
}

impl FleetMetrics {
    pub fn new() -> FleetMetrics {
        FleetMetrics::default()
    }

    pub fn router_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        Json::obj(vec![
            ("requests", Json::num(load(&self.requests))),
            ("succeeded", Json::num(load(&self.succeeded))),
            ("retried", Json::num(load(&self.retried))),
            ("deadline_exceeded", Json::num(load(&self.deadline_exceeded))),
            ("shed", Json::num(load(&self.shed))),
            ("malformed", Json::num(load(&self.malformed))),
        ])
    }

    pub fn fleet_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        Json::obj(vec![
            ("worker_crashes", Json::num(load(&self.worker_crashes))),
            ("worker_restarts", Json::num(load(&self.worker_restarts))),
            ("worker_wedged", Json::num(load(&self.worker_wedged))),
            ("breaker_trips", Json::num(load(&self.breaker_trips))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_quantiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(400);
        }
        for _ in 0..10 {
            m.record_latency(400_000);
        }
        for _ in 0..100 {
            m.completed.fetch_add(1, Ordering::Relaxed);
        }
        assert_eq!(m.latency_quantile_us(0.5), 500);
        assert_eq!(m.latency_quantile_us(0.95), 500_000);
        assert!(m.mean_latency_us() > 0.0);
    }

    #[test]
    fn mean_batch_size() {
        let m = Metrics::new();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(30, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 7.5).abs() < 1e-9);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::new();
        assert!(m.summary().contains("submitted=0"));
    }

    #[test]
    fn artifact_accounting_json() {
        let m = Metrics::new();
        m.artifacts_mounted.store(1, Ordering::Relaxed);
        m.artifact_loads.store(2, Ordering::Relaxed);
        m.artifact_load_us.store(1500, Ordering::Relaxed);
        m.static_calibrations.store(3, Ordering::Relaxed);
        let j = m.artifact_json();
        assert_eq!(j.get("mounted").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("loads").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(j.get("load_ms_total").and_then(|v| v.as_f64()), Some(1.5));
        assert_eq!(j.get("calibrations").and_then(|v| v.as_f64()), Some(3.0));
    }

    #[test]
    fn engine_gauges_and_occupancy() {
        let m = Metrics::new();
        assert_eq!(m.batch_occupancy(), 0.0);
        assert_eq!(m.engine_decode_tok_s(), 0.0);
        m.engine_steps.store(4, Ordering::Relaxed);
        m.engine_stepped_seqs.store(10, Ordering::Relaxed);
        m.engine_decoded_tokens.store(10, Ordering::Relaxed);
        m.engine_decode_time_us.store(2_000_000, Ordering::Relaxed);
        assert!((m.batch_occupancy() - 2.5).abs() < 1e-9);
        assert!((m.engine_decode_tok_s() - 5.0).abs() < 1e-9);
        m.kv_pool_slots.store(4, Ordering::Relaxed);
        m.kv_pool_in_use.store(3, Ordering::Relaxed);
        m.kv_pool_slot_bytes.store(1024, Ordering::Relaxed);
        let j = m.engine_json();
        let kv = j.get("kv_pool").expect("kv_pool object");
        assert_eq!(kv.get("bytes").and_then(|v| v.as_f64()), Some(4096.0));
        assert_eq!(kv.get("bytes_in_use").and_then(|v| v.as_f64()), Some(3072.0));
        assert_eq!(j.get("batch_occupancy").and_then(|v| v.as_f64()), Some(2.5));
    }

    #[test]
    fn counters_json_is_flat_numeric() {
        let m = Metrics::new();
        m.submitted.store(7, Ordering::Relaxed);
        m.engine_cancelled.store(2, Ordering::Relaxed);
        let j = m.counters_json();
        match &j {
            Json::Obj(fields) => {
                for (k, v) in fields {
                    assert!(v.as_f64().is_some(), "counter `{k}` is not numeric");
                }
            }
            other => panic!("expected object, got {other:?}"),
        }
        assert_eq!(j.get("submitted").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(j.get("engine_cancelled").and_then(|v| v.as_f64()), Some(2.0));
    }

    #[test]
    fn fleet_metrics_json() {
        let f = FleetMetrics::new();
        f.requests.store(10, Ordering::Relaxed);
        f.retried.store(3, Ordering::Relaxed);
        f.worker_restarts.store(1, Ordering::Relaxed);
        let r = f.router_json();
        assert_eq!(r.get("requests").and_then(|v| v.as_f64()), Some(10.0));
        assert_eq!(r.get("retried").and_then(|v| v.as_f64()), Some(3.0));
        let fl = f.fleet_json();
        assert_eq!(fl.get("worker_restarts").and_then(|v| v.as_f64()), Some(1.0));
    }
}
