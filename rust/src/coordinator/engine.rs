//! The continuous-batching generation engine (vLLM's idea at this
//! system's scale): instead of running each `generate` request's decode
//! loop alone at M=1 on the executor thread, active sequences share one
//! batched transformer step per token — late-arriving requests join the
//! running batch at *step* granularity instead of waiting for earlier
//! generations to finish.
//!
//! Three pieces:
//!
//! * [`KvPool`] — a bounded arena of preallocated per-layer K/V slots
//!   ([`DecodeState`]s), leased to sequences and reset on release, with
//!   `memory_bytes()` accounting. Replaces the one-fresh-allocation-per-
//!   request behaviour of the serial path and bounds decode memory.
//! * the sequence manager — admission queue (`waiting`) plus the active
//!   set: prompt-prefill pending → decoding → finished, with admission
//!   control that queues when the pool is exhausted and rejects with a
//!   structured error when the queue itself is full.
//! * the step loop ([`Engine::tick`]) — admits what fits, then stacks all
//!   active sequences' next tokens into one M=N matrix per scheme group
//!   and drives `forward_step_batched` (native or true-integer), sampling
//!   one token per sequence per step and streaming it to the client.
//!
//! Bit-exactness contract: a sequence decoded by the engine produces
//! exactly the tokens `generate_greedy` would have produced alone, for
//! every served scheme — the batched step applies activation-site
//! transforms per row and all shared math is per-row deterministic (see
//! `model::block::forward_step_batched`). Pinned by rust/tests/engine.rs.
//!
//! The engine is owned and ticked by the coordinator's executor thread
//! (models are not Sync); [`EngineModels`] is the narrow accessor the
//! executor exposes for model lookup/calibration.

use std::collections::VecDeque;
use std::sync::atomic::AtomicBool;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc::{Sender, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use super::metrics::Metrics;
use super::scheduler::{EvalResponse, SchemeSite};
use super::{ActScheme, SchemeKey};
use crate::model::block::{self, DecodeState};
use crate::model::{ActSite, ModelConfig, NativeModel, QuantizedModel};
use crate::obs::{self, Span, SpanKind};
use crate::quant::gemm::{gemm_timing_enable, gemm_timing_take};
use crate::quant::registry::StaticSpec;
use crate::tensor::Matrix;

/// One streamed decode event: sequence `seq` produced `token`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GenEvent {
    pub seq: u64,
    pub token: u32,
}

/// Engine knobs, surfaced as `repro serve --max-active-seqs` /
/// `--kv-pool-mb` / `--admission-queue`.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Upper bound on concurrently decoding sequences (the step-batch M).
    pub max_active_seqs: usize,
    /// Byte budget for the KV arena; the pool holds
    /// `min(max_active_seqs, budget / slot_bytes)` slots (at least one).
    /// `None` sizes the pool to `max_active_seqs` slots.
    pub kv_pool_bytes: Option<usize>,
    /// Admission-queue bound: sequences waiting for a KV slot beyond this
    /// are rejected with a structured error instead of queueing unbounded.
    /// Clamped to ≥ 1 — every submission passes through the queue on its
    /// way to a slot, so a zero-length queue could admit nothing.
    pub max_waiting: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { max_active_seqs: 32, kv_pool_bytes: None, max_waiting: 256 }
    }
}

/// A bounded arena of preallocated KV-cache slots. Leasing pops a slot
/// (reset to an empty prefix); releasing returns it. All slots are
/// allocated up front, so `memory_bytes()` is both the current and the
/// peak footprint of engine decode state.
pub struct KvPool {
    free: Vec<DecodeState>,
    slots: usize,
    slot_bytes: usize,
}

impl KvPool {
    pub fn new(slots: usize, model: ModelConfig) -> KvPool {
        assert!(slots >= 1, "a KV pool needs at least one slot");
        let free: Vec<DecodeState> = (0..slots)
            .map(|_| DecodeState::new(model.n_layers, model.seq_len, model.d_model))
            .collect();
        let slot_bytes = free[0].memory_bytes();
        KvPool { free, slots, slot_bytes }
    }

    /// Pool sized from an [`EngineConfig`]: `max_active_seqs` slots,
    /// shrunk to fit the byte budget (clamped to one slot — a pool that
    /// can serve nothing would deadlock admission).
    pub fn with_config(cfg: &EngineConfig, model: ModelConfig) -> KvPool {
        let slot_bytes =
            DecodeState::memory_bytes_for(model.n_layers, model.seq_len, model.d_model);
        let by_budget = cfg
            .kv_pool_bytes
            .map(|b| (b / slot_bytes.max(1)).max(1))
            .unwrap_or(usize::MAX);
        KvPool::new(cfg.max_active_seqs.max(1).min(by_budget), model)
    }

    /// Lease a slot, reset to an empty prefix. `None` when exhausted —
    /// the caller queues or rejects.
    pub fn lease(&mut self) -> Option<DecodeState> {
        self.free.pop().map(|mut s| {
            s.reset();
            s
        })
    }

    /// Return a slot to the pool.
    pub fn release(&mut self, state: DecodeState) {
        debug_assert!(self.free.len() < self.slots, "released more slots than exist");
        self.free.push(state);
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn in_use(&self) -> usize {
        self.slots - self.free.len()
    }

    /// Bytes of one slot (one sequence's full-stack KV capacity).
    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    /// Total arena bytes (allocation is up-front, so also the peak).
    pub fn memory_bytes(&self) -> usize {
        self.slots * self.slot_bytes
    }
}

/// What the executor hands the engine for one generation request.
pub(crate) struct GenRequest {
    pub tokens: Vec<u32>,
    pub scheme: ActScheme,
    pub key: SchemeKey,
    pub max_new: usize,
    pub resp: SyncSender<Result<EvalResponse>>,
    pub events: Option<Sender<GenEvent>>,
    /// Set when the client disconnects; the engine reaps the sequence at
    /// the next tick and releases its KV slot.
    pub cancel: Arc<AtomicBool>,
    pub submitted: Instant,
    /// Request trace id (0 = untraced). Traced sequences emit queue-wait,
    /// admission, prefill, and per-token decode spans into the span ring.
    pub trace: u64,
}

/// Per-sequence activation-site state: native schemes carry their own
/// [`SchemeSite`] (so aux accounting and batch-coupled scale fields stay
/// per-sequence); the integer static path quantizes inside its GEMMs.
enum SeqSite {
    Native(SchemeSite),
    Integer,
}

/// One decoding sequence (prefill already done).
struct GenSeq {
    id: u64,
    scheme: ActScheme,
    key: SchemeKey,
    max_new: usize,
    generated: Vec<u32>,
    state: DecodeState,
    site: SeqSite,
    /// Last sampled token — the input to the next batched step.
    next: u32,
    resp: SyncSender<Result<EvalResponse>>,
    events: Option<Sender<GenEvent>>,
    cancel: Arc<AtomicBool>,
    submitted: Instant,
    trace: u64,
    /// When the previous token was streamed — the anchor for inter-token
    /// latency and per-token decode spans.
    last_token_at: Instant,
}

/// Narrow model accessor the executor exposes to the engine (lazy
/// construction + static-scale calibration live behind it).
pub(crate) trait EngineModels {
    fn native_model(&mut self, weight_set: &str) -> Result<&NativeModel>;
    fn static_model(&mut self, weight_set: &str, spec: &StaticSpec) -> Result<&QuantizedModel>;
}

pub(crate) struct Engine {
    cfg: EngineConfig,
    pool: KvPool,
    /// Admission queue; each entry keeps its enqueue time so admission
    /// wait is measurable per request.
    waiting: VecDeque<(Instant, GenRequest)>,
    active: Vec<GenSeq>,
    next_id: u64,
    metrics: Arc<Metrics>,
}

impl Engine {
    pub(crate) fn new(mut cfg: EngineConfig, model: ModelConfig, metrics: Arc<Metrics>) -> Engine {
        cfg.max_waiting = cfg.max_waiting.max(1);
        let pool = KvPool::with_config(&cfg, model);
        metrics.kv_pool_slots.store(pool.slots() as u64, Relaxed);
        metrics.kv_pool_slot_bytes.store(pool.slot_bytes() as u64, Relaxed);
        Engine { cfg, pool, waiting: VecDeque::new(), active: Vec::new(), next_id: 0, metrics }
    }

    /// No admitted or waiting work — the executor may block for requests.
    pub(crate) fn is_idle(&self) -> bool {
        self.active.is_empty() && self.waiting.is_empty()
    }

    /// Enqueue a generation request. Admission control: the request waits
    /// for a KV slot in a bounded queue; when the queue is full it is
    /// rejected immediately with a structured error (never a panic, never
    /// unbounded memory).
    pub(crate) fn submit(&mut self, req: GenRequest) {
        if self.waiting.len() >= self.cfg.max_waiting {
            self.metrics.engine_rejected.fetch_add(1, Relaxed);
            self.metrics.failed.fetch_add(1, Relaxed);
            let _ = req.resp.send(Err(anyhow!(
                "engine at capacity: {} sequences active, admission queue full ({})",
                self.active.len(),
                self.cfg.max_waiting
            )));
            return;
        }
        let wait_us = req.submitted.elapsed().as_micros() as u64;
        self.metrics.queue_wait.record_us(wait_us);
        if req.trace != 0 {
            self.metrics.spans.record(Span {
                trace: req.trace,
                kind: SpanKind::QueueWait,
                start_us: obs::now_us().saturating_sub(wait_us),
                dur_us: wait_us,
                aux: 0,
            });
        }
        self.waiting.push_back((Instant::now(), req));
        self.update_gauges();
    }

    /// One engine round: admit what fits (prefill runs here), then one
    /// batched decode step per scheme group, then retire finished
    /// sequences. The executor calls this between channel polls, which is
    /// exactly how late arrivals join the running batch.
    pub(crate) fn tick(&mut self, models: &mut dyn EngineModels) {
        self.reap_cancelled();
        self.admit(models);
        self.step(models);
        self.update_gauges();
    }

    /// Retire sequences whose client disconnected: queued requests never
    /// admit, active sequences release their KV slot immediately instead
    /// of decoding the rest of `max_new_tokens` into a closed socket.
    fn reap_cancelled(&mut self) {
        let cancelled_waiting =
            self.waiting.iter().any(|(_, req)| req.cancel.load(Relaxed));
        if cancelled_waiting {
            let mut kept = VecDeque::with_capacity(self.waiting.len());
            for (at, req) in std::mem::take(&mut self.waiting) {
                if req.cancel.load(Relaxed) {
                    self.metrics.engine_cancelled.fetch_add(1, Relaxed);
                    self.metrics.failed.fetch_add(1, Relaxed);
                    let _ = req.resp.send(Err(anyhow!("request cancelled: client disconnected")));
                } else {
                    kept.push_back((at, req));
                }
            }
            self.waiting = kept;
        }
        if self.active.iter().any(|seq| seq.cancel.load(Relaxed)) {
            let mut kept = Vec::with_capacity(self.active.len());
            for seq in std::mem::take(&mut self.active) {
                if seq.cancel.load(Relaxed) {
                    self.metrics.engine_cancelled.fetch_add(1, Relaxed);
                    self.fail(seq, "request cancelled: client disconnected");
                } else {
                    kept.push(seq);
                }
            }
            self.active = kept;
        }
    }

    /// Fail every queued and active sequence (models unavailable).
    pub(crate) fn fail_all(&mut self, why: &str) {
        for (_, req) in std::mem::take(&mut self.waiting) {
            self.metrics.failed.fetch_add(1, Relaxed);
            let _ = req.resp.send(Err(anyhow!("{why}")));
        }
        for seq in std::mem::take(&mut self.active) {
            self.fail(seq, why);
        }
        self.update_gauges();
    }

    fn admit(&mut self, models: &mut dyn EngineModels) {
        while self.active.len() < self.cfg.max_active_seqs && !self.waiting.is_empty() {
            let Some(state) = self.pool.lease() else { break };
            let Some((enqueued, req)) = self.waiting.pop_front() else {
                // unreachable given the loop guard, but a leaked slot is
                // the wrong failure mode if that invariant ever slips
                self.pool.release(state);
                break;
            };
            self.admit_one(models, req, state, enqueued);
        }
    }

    /// Prefill one request into its leased slot and move it to the active
    /// set (or straight to finished when `max_new == 1`).
    fn admit_one(
        &mut self,
        models: &mut dyn EngineModels,
        req: GenRequest,
        mut state: DecodeState,
        enqueued: Instant,
    ) {
        let id = self.next_id;
        self.next_id += 1;
        let adm_us = enqueued.elapsed().as_micros() as u64;
        if req.trace != 0 {
            self.metrics.spans.record(Span {
                trace: req.trace,
                kind: SpanKind::AdmissionWait,
                start_us: obs::now_us().saturating_sub(adm_us),
                dur_us: adm_us,
                aux: 0,
            });
        }
        let kernel = self.metrics.kernel.clone();
        let t0 = Instant::now();
        let run: Result<(SeqSite, Matrix)> = (|| {
            match req.scheme.static_spec() {
                Some((spec, qmax)) => {
                    let alpha = spec.alpha;
                    ensure!(
                        alpha.is_finite() && (0.0..=1.0).contains(&alpha),
                        "bad alpha {alpha}"
                    );
                    ensure!(
                        (qmax - 127.0).abs() < 0.5,
                        "native static path serves the INT8 grid (qmax 127), got {qmax}"
                    );
                    let model = models.static_model(&req.key.weight_set, &spec)?;
                    let logits = model.forward_incremental_with(&req.tokens, &mut state, true)?;
                    Ok((SeqSite::Integer, logits))
                }
                None => {
                    let mut site = SchemeSite::build(req.scheme, Some(kernel))?;
                    let model = models.native_model(&req.key.weight_set)?;
                    let logits =
                        model.forward_incremental_with(&req.tokens, &mut state, site.site(), true)?;
                    Ok((SeqSite::Native(site), logits))
                }
            }
        })();
        match run {
            Err(e) => {
                self.metrics.failed.fetch_add(1, Relaxed);
                let _ = req.resp.send(Err(e));
                self.pool.release(state);
            }
            Ok((site, logits)) => {
                let prefill_us = t0.elapsed().as_micros() as u64;
                self.metrics.ttft.record_us(req.submitted.elapsed().as_micros() as u64);
                if req.trace != 0 {
                    self.metrics.spans.record(Span {
                        trace: req.trace,
                        kind: SpanKind::Prefill,
                        start_us: obs::now_us().saturating_sub(prefill_us),
                        dur_us: prefill_us,
                        aux: req.tokens.len() as u64,
                    });
                }
                let tok = block::argmax(logits.row(logits.rows - 1)) as u32;
                let seq = GenSeq {
                    id,
                    scheme: req.scheme,
                    key: req.key,
                    max_new: req.max_new,
                    generated: vec![tok],
                    state,
                    site,
                    next: tok,
                    resp: req.resp,
                    events: req.events,
                    cancel: req.cancel,
                    submitted: req.submitted,
                    trace: req.trace,
                    last_token_at: Instant::now(),
                };
                if let Some(ev) = &seq.events {
                    let _ = ev.send(GenEvent { seq: id, token: tok });
                }
                if seq.generated.len() >= seq.max_new {
                    self.finish(seq);
                } else {
                    self.active.push(seq);
                }
            }
        }
    }

    /// One batched decode step per scheme group: all sequences sharing a
    /// [`SchemeKey`] stack their next tokens into one M=N forward.
    fn step(&mut self, models: &mut dyn EngineModels) {
        if self.active.is_empty() {
            return;
        }
        // partition the active set by key in one pass (admission order is
        // preserved within each group)
        let mut groups: Vec<(SchemeKey, Vec<GenSeq>)> = Vec::new();
        for seq in std::mem::take(&mut self.active) {
            match groups.iter_mut().find(|(k, _)| *k == seq.key) {
                Some((_, group)) => group.push(seq),
                None => {
                    let key = seq.key.clone();
                    groups.push((key, vec![seq]));
                }
            }
        }
        for (key, mut group) in groups {
            let traced = group.iter().any(|s| s.trace != 0);
            if traced {
                gemm_timing_enable(true);
            }
            let t0 = Instant::now();
            let result = Self::step_group(models, &key, &mut group, &self.metrics);
            let fwd_us = t0.elapsed().as_micros() as u64;
            self.metrics.engine_steps.fetch_add(1, Relaxed);
            self.metrics.engine_stepped_seqs.fetch_add(group.len() as u64, Relaxed);
            self.metrics.engine_decode_time_us.fetch_add(fwd_us, Relaxed);
            self.metrics.batch_forward.record_us(fwd_us);
            if traced {
                let (gemm_calls, gemm_ns) = gemm_timing_take();
                gemm_timing_enable(false);
                if gemm_calls > 0 {
                    let start_us = obs::now_us().saturating_sub(fwd_us);
                    for seq in group.iter().filter(|s| s.trace != 0) {
                        self.metrics.spans.record(Span {
                            trace: seq.trace,
                            kind: SpanKind::Gemm,
                            start_us,
                            dur_us: gemm_ns / 1_000,
                            aux: gemm_calls,
                        });
                    }
                }
            }
            match result {
                Ok(()) => {
                    self.metrics.engine_decoded_tokens.fetch_add(group.len() as u64, Relaxed);
                    for seq in group {
                        if seq.generated.len() >= seq.max_new {
                            self.finish(seq);
                        } else {
                            self.active.push(seq);
                        }
                    }
                }
                Err(e) => {
                    let why = format!("{e}");
                    for seq in group {
                        self.fail(seq, &why);
                    }
                }
            }
        }
    }

    fn step_group(
        models: &mut dyn EngineModels,
        key: &SchemeKey,
        seqs: &mut [GenSeq],
        metrics: &Metrics,
    ) -> Result<()> {
        let scheme = seqs[0].scheme;
        let tokens: Vec<u32> = seqs.iter().map(|s| s.next).collect();
        let logits = match scheme.static_spec() {
            Some((spec, _)) => {
                let model = models.static_model(&key.weight_set, &spec)?;
                let mut states: Vec<&mut DecodeState> =
                    seqs.iter_mut().map(|s| &mut s.state).collect();
                model.forward_step_batched(&tokens, &mut states)?
            }
            None => {
                let model = models.native_model(&key.weight_set)?;
                let (mut states, mut sites): (Vec<&mut DecodeState>, Vec<&mut SeqSite>) =
                    seqs.iter_mut().map(|s| (&mut s.state, &mut s.site)).unzip();
                let mut hook = |row: usize, idx: usize, x: Matrix| match &mut *sites[row] {
                    SeqSite::Native(ss) => ss.site().apply(idx, x),
                    SeqSite::Integer => x,
                };
                // identity sites transform nothing — skip the per-row
                // split on the fp path entirely
                let hook_opt: Option<&mut dyn FnMut(usize, usize, Matrix) -> Matrix> =
                    if matches!(scheme, ActScheme::Fp) { None } else { Some(&mut hook) };
                model.forward_step_batched(&tokens, &mut states, hook_opt)?
            }
        };
        for (i, s) in seqs.iter_mut().enumerate() {
            let tok = block::argmax(logits.row(i)) as u32;
            s.next = tok;
            s.generated.push(tok);
            let gap_us = s.last_token_at.elapsed().as_micros() as u64;
            s.last_token_at = Instant::now();
            metrics.inter_token.record_us(gap_us);
            if s.trace != 0 {
                metrics.spans.record(Span {
                    trace: s.trace,
                    kind: SpanKind::DecodeToken,
                    start_us: obs::now_us().saturating_sub(gap_us),
                    dur_us: gap_us,
                    aux: s.generated.len() as u64 - 1,
                });
            }
            if let Some(ev) = &s.events {
                let _ = ev.send(GenEvent { seq: s.id, token: tok });
            }
        }
        Ok(())
    }

    fn finish(&mut self, seq: GenSeq) {
        let aux = match &seq.site {
            SeqSite::Native(s) => s.aux(),
            SeqSite::Integer => 0.0,
        };
        self.metrics.completed.fetch_add(1, Relaxed);
        self.metrics.record_latency(seq.submitted.elapsed().as_micros() as u64);
        let _ = seq.resp.send(Ok(EvalResponse {
            nll: Vec::new(),
            aux,
            generated: seq.generated,
        }));
        self.pool.release(seq.state);
    }

    fn fail(&mut self, seq: GenSeq, why: &str) {
        self.metrics.failed.fetch_add(1, Relaxed);
        let _ = seq.resp.send(Err(anyhow!("{why}")));
        self.pool.release(seq.state);
    }

    fn update_gauges(&self) {
        self.metrics.engine_active_seqs.store(self.active.len() as u64, Relaxed);
        self.metrics.engine_queue_depth.store(self.waiting.len() as u64, Relaxed);
        self.metrics.kv_pool_in_use.store(self.pool.in_use() as u64, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::{channel, sync_channel, Receiver};

    use std::collections::HashMap;

    use super::*;
    use crate::corpus::CorpusGen;
    use crate::model::weights::synthetic_weights;
    use crate::model::IdentitySite;
    use crate::quant::registry::{self, SchemeId};
    use crate::quant::Bits;

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 24,
            eval_batch: 2,
        }
    }

    /// Minimal [`EngineModels`]: one native model plus a spec-keyed cache
    /// of registry-built static models — mirroring the executor's
    /// calibration stream.
    struct TestModels {
        native: NativeModel,
        static_ms: HashMap<(u16, i64, usize), QuantizedModel>,
    }

    impl TestModels {
        fn new(seed: u64) -> TestModels {
            TestModels {
                native: NativeModel::new(synthetic_weights(cfg(), seed)),
                static_ms: HashMap::new(),
            }
        }
    }

    impl EngineModels for TestModels {
        fn native_model(&mut self, _ws: &str) -> Result<&NativeModel> {
            Ok(&self.native)
        }

        fn static_model(&mut self, _ws: &str, spec: &StaticSpec) -> Result<&QuantizedModel> {
            let key = spec.cache_key();
            if !self.static_ms.contains_key(&key) {
                let mut gen = CorpusGen::new(cfg().vocab, 0x5CA1E);
                let calib: Vec<Vec<u32>> = (0..4).map(|_| gen.sequence(cfg().seq_len)).collect();
                let qm = registry::build_static_model(
                    &self.native.weights,
                    Bits::Int8,
                    Bits::Int8,
                    spec,
                    &calib,
                )?;
                self.static_ms.insert(key, qm);
            }
            Ok(self.static_ms.get(&key).expect("installed above"))
        }
    }

    #[allow(clippy::type_complexity)]
    fn gen_req(
        tokens: Vec<u32>,
        scheme: ActScheme,
        max_new: usize,
    ) -> (GenRequest, Receiver<Result<EvalResponse>>, Receiver<GenEvent>) {
        let (resp_tx, resp_rx) = sync_channel(1);
        let (ev_tx, ev_rx) = channel();
        let key = {
            let mut k = scheme.key("w");
            k.generate = true;
            k
        };
        let req = GenRequest {
            tokens,
            scheme,
            key,
            max_new,
            resp: resp_tx,
            events: Some(ev_tx),
            cancel: Arc::new(AtomicBool::new(false)),
            submitted: Instant::now(),
            trace: 0,
        };
        (req, resp_rx, ev_rx)
    }

    fn engine(max_active: usize, max_waiting: usize, kv_pool_bytes: Option<usize>) -> Engine {
        Engine::new(
            EngineConfig { max_active_seqs: max_active, kv_pool_bytes, max_waiting },
            cfg(),
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn pool_lease_release_accounting() {
        let mut pool = KvPool::new(2, cfg());
        let per_slot = 2 * 2 * 24 * 16 * 4; // 2(K+V) · layers · ctx · d · f32
        assert_eq!(pool.slot_bytes(), per_slot);
        assert_eq!(pool.memory_bytes(), 2 * per_slot);
        let a = pool.lease().expect("slot 0");
        let _b = pool.lease().expect("slot 1");
        assert!(pool.lease().is_none(), "exhausted pool must not lease");
        assert_eq!(pool.in_use(), 2);
        pool.release(a);
        assert_eq!(pool.in_use(), 1);
        let again = pool.lease().expect("released slot is reusable");
        assert!(again.is_empty(), "leased slots start at an empty prefix");
    }

    #[test]
    fn budget_clamps_pool_slots() {
        let per_slot = 2 * 2 * 24 * 16 * 4;
        let ec = EngineConfig {
            max_active_seqs: 8,
            kv_pool_bytes: Some(per_slot * 3 + 10),
            max_waiting: 4,
        };
        assert_eq!(KvPool::with_config(&ec, cfg()).slots(), 3);
        // budget below one slot still yields a working pool
        let tiny = EngineConfig { kv_pool_bytes: Some(1), ..ec };
        assert_eq!(KvPool::with_config(&tiny, cfg()).slots(), 1);
    }

    #[test]
    fn queue_then_reject_when_pool_exhausted() {
        // one slot, queue of one: seq A runs, B queues, C is rejected
        let mut eng = engine(1, 1, None);
        let mut models = TestModels::new(3);
        let reference = |prompt: &[u32], n: usize| {
            models.native.generate_greedy(prompt, n, &mut IdentitySite).unwrap()
        };
        let ra = reference(&[1, 2, 3], 6);
        let rb = reference(&[4, 5], 4);
        let (a, a_rx, a_ev) = gen_req(vec![1, 2, 3], ActScheme::Fp, 6);
        let (b, b_rx, _b_ev) = gen_req(vec![4, 5], ActScheme::Fp, 4);
        let (c, c_rx, _c_ev) = gen_req(vec![6], ActScheme::Fp, 2);
        eng.submit(a);
        eng.tick(&mut models); // A admitted (prefill + first step)
        assert!(!eng.is_idle());
        eng.submit(b); // pool exhausted → queues
        eng.submit(c); // queue full → rejected immediately
        let err = c_rx.recv().expect("rejection must respond").unwrap_err();
        assert!(format!("{err}").contains("admission queue full"), "unexpected: {err}");
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        let resp_a = a_rx.recv().unwrap().unwrap();
        let resp_b = b_rx.recv().unwrap().unwrap();
        assert_eq!(resp_a.generated, ra, "A must match its solo decode");
        assert_eq!(resp_b.generated, rb, "B must match its solo decode");
        // streamed tokens equal the final payload
        let streamed: Vec<u32> = a_ev.try_iter().map(|e| e.token).collect();
        assert_eq!(streamed, resp_a.generated);
        assert_eq!(eng.metrics.engine_rejected.load(Relaxed), 1);
        assert_eq!(eng.metrics.kv_pool_in_use.load(Relaxed), 0);
    }

    #[test]
    fn mid_flight_join_keeps_sequences_bit_exact() {
        let mut eng = engine(4, 8, None);
        let mut models = TestModels::new(7);
        let ra = models.native.generate_greedy(&[1, 2, 3], 8, &mut IdentitySite).unwrap();
        let rb = models.native.generate_greedy(&[9, 9], 5, &mut IdentitySite).unwrap();
        let (a, a_rx, _) = gen_req(vec![1, 2, 3], ActScheme::Fp, 8);
        eng.submit(a);
        eng.tick(&mut models);
        eng.tick(&mut models); // A is mid-decode…
        let (b, b_rx, _) = gen_req(vec![9, 9], ActScheme::Fp, 5);
        eng.submit(b); // …when B joins the running batch
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert_eq!(a_rx.recv().unwrap().unwrap().generated, ra);
        assert_eq!(b_rx.recv().unwrap().unwrap().generated, rb);
        // at least one step ran with both sequences stacked
        assert!(eng.metrics.batch_occupancy() > 1.0, "join must share steps");
    }

    #[test]
    fn scheme_groups_step_independently_and_stay_exact() {
        // fp and crossquant-static sequences decode concurrently; each
        // matches its own solo reference
        let mut eng = engine(4, 8, None);
        let mut models = TestModels::new(11);
        let r_fp = models.native.generate_greedy(&[1, 2, 3, 4], 6, &mut IdentitySite).unwrap();
        let r_st = models
            .static_model("w", &StaticSpec::new(SchemeId::CrossQuantStatic, 0.15, 0))
            .unwrap()
            .generate_greedy(&[1, 2, 3, 4], 6)
            .unwrap();
        let (a, a_rx, _) =
            gen_req(vec![1, 2, 3, 4], ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 }, 6);
        let (b, b_rx, _) = gen_req(vec![1, 2, 3, 4], ActScheme::Fp, 6);
        eng.submit(a);
        eng.submit(b);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert_eq!(a_rx.recv().unwrap().unwrap().generated, r_st);
        assert_eq!(b_rx.recv().unwrap().unwrap().generated, r_fp);
    }

    #[test]
    fn registry_schemes_decode_bit_exact_in_the_engine() {
        // a gptq sequence decoded by the engine matches its solo decode on
        // the same registry-built model
        let mut eng = engine(4, 8, None);
        let mut models = TestModels::new(17);
        let spec = StaticSpec::new(SchemeId::Gptq, 0.15, 0);
        let r = models.static_model("w", &spec).unwrap().generate_greedy(&[2, 3, 4], 5).unwrap();
        let (a, a_rx, _) =
            gen_req(vec![2, 3, 4], ActScheme::Gptq { alpha: 0.15, qmax: 127.0 }, 5);
        eng.submit(a);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert_eq!(a_rx.recv().unwrap().unwrap().generated, r);
    }

    #[test]
    fn cancelled_sequence_is_reaped_and_releases_its_slot() {
        let mut eng = engine(2, 4, None);
        let mut models = TestModels::new(5);
        let (a, a_rx, _a_ev) = gen_req(vec![1, 2, 3], ActScheme::Fp, 16);
        let cancel = a.cancel.clone();
        eng.submit(a);
        eng.tick(&mut models); // admitted, mid-decode
        assert_eq!(eng.pool.in_use(), 1);
        cancel.store(true, Relaxed);
        eng.tick(&mut models); // reaped before the next step
        assert!(eng.is_idle(), "cancelled sequence must leave the active set");
        assert_eq!(eng.pool.in_use(), 0, "cancel must release the KV slot");
        assert_eq!(eng.metrics.engine_cancelled.load(Relaxed), 1);
        let err = a_rx.recv().unwrap().unwrap_err();
        assert!(format!("{err}").contains("cancelled"), "unexpected: {err}");
    }

    #[test]
    fn cancelled_queued_request_never_admits() {
        // one slot: A occupies it, B queues, B's client disconnects
        let mut eng = engine(1, 4, None);
        let mut models = TestModels::new(5);
        let (a, a_rx, _) = gen_req(vec![1, 2, 3], ActScheme::Fp, 6);
        let (b, b_rx, _) = gen_req(vec![4, 5], ActScheme::Fp, 4);
        let cancel_b = b.cancel.clone();
        eng.submit(a);
        eng.tick(&mut models);
        eng.submit(b);
        cancel_b.store(true, Relaxed);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert!(a_rx.recv().unwrap().is_ok(), "A is unaffected by B's cancel");
        let err = b_rx.recv().unwrap().unwrap_err();
        assert!(format!("{err}").contains("cancelled"), "unexpected: {err}");
        assert_eq!(eng.metrics.engine_cancelled.load(Relaxed), 1);
    }

    #[test]
    fn traced_sequence_emits_contiguous_spans() {
        let mut eng = engine(2, 4, None);
        let mut models = TestModels::new(19);
        let (mut a, a_rx, _) = gen_req(vec![1, 2, 3], ActScheme::Fp, 6);
        a.trace = 0xFEED;
        eng.submit(a);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        a_rx.recv().unwrap().unwrap();
        let spans = eng.metrics.spans.for_trace(0xFEED);
        let kind_count =
            |k: SpanKind| spans.iter().filter(|s| s.kind == k).count();
        assert_eq!(kind_count(SpanKind::QueueWait), 1);
        assert_eq!(kind_count(SpanKind::AdmissionWait), 1);
        assert_eq!(kind_count(SpanKind::Prefill), 1);
        // 6 tokens: one at prefill, five decode steps
        assert_eq!(kind_count(SpanKind::DecodeToken), 5);
        // histograms observed alongside the spans
        assert_eq!(eng.metrics.ttft.total.count(), 1);
        assert_eq!(eng.metrics.inter_token.total.count(), 5);
        assert!(eng.metrics.batch_forward.total.count() >= 5);
        // an untraced request leaves the ring untouched
        let before = eng.metrics.spans.recorded();
        let (b, b_rx, _) = gen_req(vec![4, 5], ActScheme::Fp, 3);
        eng.submit(b);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        b_rx.recv().unwrap().unwrap();
        assert_eq!(eng.metrics.spans.recorded(), before);
    }

    #[test]
    fn malformed_static_request_fails_cleanly() {
        let mut eng = engine(2, 4, None);
        let mut models = TestModels::new(13);
        // qmax off the INT8 grid: structured error at admission, slot freed
        let (a, a_rx, _) =
            gen_req(vec![1, 2], ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 50.0 }, 3);
        eng.submit(a);
        eng.tick(&mut models);
        assert!(a_rx.recv().unwrap().is_err());
        assert!(eng.is_idle());
        assert_eq!(eng.pool.in_use(), 0, "failed admission must release its slot");
    }
}
